"""Regenerate the committed model fixtures in this directory.

    PYTHONPATH=src python examples/models/gen_fixtures.py

Produces:
  lenet5.onnx   LeNet-5 as a spec-conformant ONNX ModelProto (Conv/Relu/
                MaxPool/Flatten/Gemm), weights = ``graph.lenet5()``'s
                ``init_params(0)`` — so the golden import test can assert
                structural AND parameter equality against the hand-written
                builder.
  lenet5.json   the same net in the declarative repro-net-v1 format.
  tinynet.json  a small conv-bn-relu-pool-fc net with NO NetGraph builder —
                the end-to-end proof that unseen models compile and serve.

Encoded with ``repro.frontend.protowire`` (no onnx install needed); the
output is standard ONNX — ``onnx.load`` reads it, which the optional
cross-validation test in tests/test_frontend.py checks.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core import graph as G
from repro.frontend.json_importer import FORMAT_ID
from repro.frontend.protowire import (enc_bytes, enc_float, enc_int,
                                      enc_packed_ints, enc_str)

HERE = pathlib.Path(__file__).parent

# AttributeProto.AttributeType enum values (onnx.proto)
_AT_FLOAT, _AT_INT, _AT_INTS = 1, 2, 7


def _attr(name: str, value) -> bytes:
    body = enc_str(1, name)
    if isinstance(value, float):
        body += enc_float(2, value) + enc_int(20, _AT_FLOAT)
    elif isinstance(value, int):
        body += enc_int(3, value) + enc_int(20, _AT_INT)
    else:                                  # list of ints
        body += enc_packed_ints(8, list(value)) + enc_int(20, _AT_INTS)
    return body


def _node(op: str, name: str, inputs, outputs, **attrs) -> bytes:
    body = b"".join(enc_str(1, t) for t in inputs)
    body += b"".join(enc_str(2, t) for t in outputs)
    body += enc_str(3, name) + enc_str(4, op)
    body += b"".join(enc_bytes(5, _attr(k, v)) for k, v in attrs.items())
    return body


def _tensor(name: str, a: np.ndarray) -> bytes:
    a = np.ascontiguousarray(a, np.float32)
    return (enc_packed_ints(1, list(a.shape)) + enc_int(2, 1)  # FLOAT
            + enc_str(8, name) + enc_bytes(9, a.tobytes()))


def _value_info(name: str, dims) -> bytes:
    shape = b"".join(enc_bytes(1, enc_int(1, int(d))) for d in dims)
    tensor_type = enc_int(1, 1) + enc_bytes(2, shape)          # elem FLOAT
    return enc_str(1, name) + enc_bytes(2, enc_bytes(1, tensor_type))


def _model(graph: bytes) -> bytes:
    opset = enc_str(1, "") + enc_int(2, 13)
    return (enc_int(1, 8)                                      # ir_version
            + enc_str(2, "repro.frontend.fixtures")            # producer
            + enc_bytes(7, graph) + enc_bytes(8, opset))


def lenet5_onnx() -> bytes:
    g = G.lenet5()
    params = g.init_params(0)
    body = b""
    body += enc_str(2, "lenet5")
    for lname in ("conv1", "conv2", "fc1", "fc2", "fc3"):
        body += enc_bytes(5, _tensor(f"{lname}.w", params[lname]["w"]))
        body += enc_bytes(5, _tensor(f"{lname}.b", params[lname]["b"]))
    nodes = [
        _node("Conv", "conv1", ["data", "conv1.w", "conv1.b"], ["conv1.y"],
              kernel_shape=[5, 5], strides=[1, 1], pads=[2, 2, 2, 2]),
        _node("Relu", "relu1", ["conv1.y"], ["conv1.out"]),
        _node("MaxPool", "pool1", ["conv1.out"], ["pool1.out"],
              kernel_shape=[2, 2], strides=[2, 2]),
        _node("Conv", "conv2", ["pool1.out", "conv2.w", "conv2.b"],
              ["conv2.y"], kernel_shape=[5, 5], strides=[1, 1],
              pads=[0, 0, 0, 0]),
        _node("Relu", "relu2", ["conv2.y"], ["conv2.out"]),
        _node("MaxPool", "pool2", ["conv2.out"], ["pool2.out"],
              kernel_shape=[2, 2], strides=[2, 2]),
        _node("Flatten", "flat", ["pool2.out"], ["flat.out"], axis=1),
        _node("Gemm", "fc1", ["flat.out", "fc1.w", "fc1.b"], ["fc1.y"],
              alpha=1.0, beta=1.0, transB=1),
        _node("Relu", "relu3", ["fc1.y"], ["fc1.out"]),
        _node("Gemm", "fc2", ["fc1.out", "fc2.w", "fc2.b"], ["fc2.y"],
              alpha=1.0, beta=1.0, transB=1),
        _node("Relu", "relu4", ["fc2.y"], ["fc2.out"]),
        _node("Gemm", "fc3", ["fc2.out", "fc3.w", "fc3.b"], ["fc3.out"],
              alpha=1.0, beta=1.0, transB=1),
    ]
    body += b"".join(enc_bytes(1, n) for n in nodes)
    body += enc_bytes(11, _value_info("data", (1,) + g.input_shape))
    body += enc_bytes(12, _value_info("fc3.out", (1, 10)))
    return _model(body)


def lenet5_json() -> dict:
    return {
        "format": FORMAT_ID,
        "name": "lenet5",
        "input_shape": [1, 28, 28],
        "seed": 0,
        "layers": [
            {"name": "conv1", "type": "conv", "inputs": ["data"],
             "out_channels": 6, "kernel": 5, "pad": 2, "relu": True},
            {"name": "pool1", "type": "pool", "inputs": ["conv1"],
             "kernel": 2, "stride": 2, "mode": "max"},
            {"name": "conv2", "type": "conv", "inputs": ["pool1"],
             "out_channels": 16, "kernel": 5, "relu": True},
            {"name": "pool2", "type": "pool", "inputs": ["conv2"],
             "kernel": 2, "stride": 2, "mode": "max"},
            {"name": "fc1", "type": "fc", "inputs": ["pool2"],
             "out_channels": 120, "relu": True},
            {"name": "fc2", "type": "fc", "inputs": ["fc1"],
             "out_channels": 84, "relu": True},
            {"name": "fc3", "type": "fc", "inputs": ["fc2"],
             "out_channels": 10},
        ],
    }


def tinynet_json() -> dict:
    """A net with no BUILDERS entry: only importable, never hand-built."""
    return {
        "format": FORMAT_ID,
        "name": "tinynet",
        "input_shape": [3, 16, 16],
        "seed": 7,
        "layers": [
            {"name": "conv1", "type": "conv", "inputs": ["data"],
             "out_channels": 8, "kernel": 3, "pad": 1},
            {"name": "bn1", "type": "batchnorm", "inputs": ["conv1"]},
            {"name": "relu1", "type": "relu", "inputs": ["bn1"]},
            {"name": "pool1", "type": "pool", "inputs": ["relu1"],
             "kernel": 2, "stride": 2, "mode": "max"},
            {"name": "conv2", "type": "conv", "inputs": ["pool1"],
             "out_channels": 16, "kernel": 3, "pad": 1, "relu": True},
            {"name": "pool2", "type": "pool", "inputs": ["conv2"],
             "mode": "gap"},
            {"name": "fc1", "type": "fc", "inputs": ["pool2"],
             "out_channels": 10},
        ],
    }


def main() -> None:
    (HERE / "lenet5.onnx").write_bytes(lenet5_onnx())
    (HERE / "lenet5.json").write_text(json.dumps(lenet5_json(), indent=2)
                                      + "\n")
    (HERE / "tinynet.json").write_text(json.dumps(tinynet_json(), indent=2)
                                       + "\n")
    for f in ("lenet5.onnx", "lenet5.json", "tinynet.json"):
        print(f"wrote {HERE / f} ({(HERE / f).stat().st_size} bytes)")


if __name__ == "__main__":
    main()
