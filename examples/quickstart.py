"""Quickstart: the paper's toolflow as a staged pipeline + serving Session.

    PYTHONPATH=src python examples/quickstart.py

Compiler (paper Fig. 1), stage by stage: INT8 calibration -> loadable ->
virtual-platform run (CSB/DBB logs) -> configuration file + weight image ->
RV32I assembly.  The artifact bundle (the paper's three files) is saved to
disk and served back by a Session — batched, multi-backend, and with no
recompilation or VP re-execution.
"""

import tempfile

import numpy as np

from repro.core import graph
from repro.core.pipeline import Artifacts, CompilerPipeline
from repro.runtime import Session, backend_names


def main():
    g = graph.lenet5()
    print(f"model: {g.name}  layers={len(g.layers)}  params={g.num_params():,}  "
          f"MACs={g.macs():,}")

    # -- compiler: run the staged pipeline, inspecting intermediates ---------
    pipe = CompilerPipeline(g)
    cal = pipe.run_stage("calibrate")
    print(f"\n== stage 'calibrate' ==\n  per-layer scales: "
          f"{ {k: round(v, 4) for k, v in list(cal.scales.items())[:4]} } ...")
    vp = pipe.run_stage("vp_run")
    print(f"== stage 'vp_run' ==\n  CSB writes={vp.n_csb_writes}  "
          f"reads={vp.n_csb_reads}  DBB bytes={vp.dbb_bytes:,}")
    art = pipe.run()

    rep = art.storage_report()
    print("\n== bare-metal artifacts (all the SoC needs) ==")
    print(f"  configuration file : {rep['config_file_bytes']:,} B "
          f"({rep['n_write_reg']} write_reg, {rep['n_read_reg']} read_reg)")
    print(f"  RV32I program image: {rep['program_binary_bytes']:,} B")
    print(f"  weight image       : {rep['weight_image_bytes']:,} B (deduped)")
    print(f"  modeled latency    : {art.cost.ms_at_clock:.2f} ms @100MHz "
          f"(paper Table II: 4.8 ms)")

    print("\n== assembly preview ==")
    print("\n".join(art.asm_text.splitlines()[:8]), "\n  ...")

    # -- ship the bundle, serve it back --------------------------------------
    with tempfile.TemporaryDirectory(prefix="lenet5_bundle_") as tmp:
        bundle = art.save(tmp)
        print(f"\n== bundle saved ==\n  {bundle}: "
              f"{', '.join(sorted(f.name for f in bundle.iterdir()))}")
        ses = Session.from_bundle(bundle)        # no recompile, no VP run
        ses.load(Artifacts.load(bundle), name="lenet5-baseline",
                 backend="linuxstack")
    print(f"  backends registered: {', '.join(backend_names())}")
    print(f"  resident networks  : {', '.join(ses.networks)}")

    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    bm = ses.run(x)
    ls = ses.run(x, net="lenet5-baseline")
    same = np.array_equal(bm.output_int8, ls.output_int8)
    print("\n== execution ==")
    print(f"  bare-metal logits : {np.round(bm.output, 3)}")
    print(f"  linux-stack match : {same} (bit-exact INT8)")
    print(f"  predicted class   : {int(bm.output.argmax())}")

    X = rng.normal(0, 1, (8,) + g.input_shape).astype(np.float32)
    batch = ses.run_batch(X)                     # coalesced into one vmapped program
    seq = np.stack([ses.run(xi).output_int8 for xi in X])
    print(f"  batch(8) vs 8 runs: bit-exact={np.array_equal(batch.output_int8, seq)}")

    # async serving: submit returns futures; the scheduler coalesces them
    futs = [ses.submit(xi) for xi in X]
    asy = np.stack([f.result().output_int8 for f in futs])
    print(f"  8 async submits   : bit-exact={np.array_equal(asy, seq)}")
    st = ses.stats()
    print(f"  session stats     : {st}")
    print(f"  latency (us)      : {st.latency_summary()}  "
          f"coalesce_mean={st.coalesce_mean:.1f}")
    ses.close()


if __name__ == "__main__":
    main()
