"""Quickstart: the paper's full toolflow on LeNet-5, end to end.

    PYTHONPATH=src python examples/quickstart.py

Steps (paper Fig. 1): Caffe-style model -> INT8 calibration -> loadable ->
virtual-platform run (CSB/DBB logs) -> configuration file + weight image ->
RV32I assembly -> bare-metal execution, compared against the linux-stack
baseline and the fp32 reference.
"""

import numpy as np

from repro.core import api, graph

def main():
    g = graph.lenet5()
    print(f"model: {g.name}  layers={len(g.layers)}  params={g.num_params():,}  "
          f"MACs={g.macs():,}")

    art = api.compile_network(g)
    rep = art.storage_report()
    print("\n== bare-metal artifacts (all the SoC needs) ==")
    print(f"  configuration file : {rep['config_file_bytes']:,} B "
          f"({rep['n_write_reg']} write_reg, {rep['n_read_reg']} read_reg)")
    print(f"  RV32I program image: {rep['program_binary_bytes']:,} B")
    print(f"  weight image       : {rep['weight_image_bytes']:,} B (deduped)")
    print(f"  modeled latency    : {art.cost.ms_at_clock:.2f} ms @100MHz "
          f"(paper Table II: 4.8 ms)")

    print("\n== assembly preview ==")
    print("\n".join(art.asm_text.splitlines()[:8]), "\n  ...")

    x = np.random.default_rng(1).normal(0, 1, g.input_shape).astype(np.float32)
    bm = api.make_executor(art, "baremetal").run(x)
    ls = api.make_executor(art, "linuxstack").run(x)
    same = np.array_equal(bm.output_int8, ls.output_int8)
    print("\n== execution ==")
    print(f"  bare-metal logits : {np.round(bm.output, 3)}")
    print(f"  linux-stack match : {same} (bit-exact INT8)")
    print(f"  predicted class   : {int(bm.output.argmax())}")


if __name__ == "__main__":
    main()
