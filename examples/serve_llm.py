"""Serve a small LM with batched requests through AOT prefill/decode binaries.

    PYTHONPATH=src python examples/serve_llm.py --arch yi-6b --requests 8 \
        --prompt-len 32 --gen 16

The serving loop is the paper's bare-metal replay philosophy at LM scale:
prefill and decode are each ONE pre-compiled executable bound to a static KV
arena; requests are batched and the decode binary is replayed per token with
the cache donated in-place (zero allocation, zero retracing).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=configs.ALL_ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)   # host-scale weights
    model = registry.get(cfg.family)
    mesh = make_host_mesh()
    params = model.init_params(cfg, jax.random.key(args.seed))
    b, s = args.requests, args.prompt_len
    max_len = s + args.gen
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab, (b, s), dtype=np.int32)

    with mesh:
        # --- bind the two binaries once -----------------------------------
        prefill_fn = jax.jit(lambda p, t: model.prefill(cfg, p, {"tokens": t}))
        decode_fn = jax.jit(
            lambda p, c, t, pos: model.decode_step(cfg, p, c, {"tokens": t}, pos),
            donate_argnums=(1,))

        t0 = time.perf_counter()
        logits, pre_cache = prefill_fn(params, jnp.asarray(prompts))
        # place prefill results into the static max_len arena
        cache = model.init_cache(cfg, b, max_len)
        if cfg.family in ("ssm",):
            cache = pre_cache                          # O(1) state: already final
        else:
            def blit(dst, src):
                if dst.ndim >= 2 and src.shape != dst.shape:
                    # write prompt-long slice into the max_len axis (axis=-2)
                    idx = tuple([slice(None)] * (dst.ndim - 2)
                                + [slice(0, src.shape[-2]), slice(None)])
                    return dst.at[idx].set(src.astype(dst.dtype))
                return src.astype(dst.dtype)
            cache = jax.tree.map(blit, cache, pre_cache)
        t_prefill = time.perf_counter() - t0

        tokens = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [np.asarray(tokens)]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            logits, cache = decode_fn(params, cache, tokens, jnp.asarray(s + i))
            tokens = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs.append(np.asarray(tokens))
        t_decode = time.perf_counter() - t0

    gen = np.concatenate(outs, 1)
    print(f"arch={cfg.name} requests={b} prompt={s} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms ({b*s/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms "
          f"({b*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s, "
          f"{t_decode/(args.gen-1)*1e3:.2f} ms/step)")
    print("sample generations (token ids):")
    for r in range(min(b, 4)):
        print(f"  req{r}: {gen[r][:12].tolist()}")


if __name__ == "__main__":
    main()
