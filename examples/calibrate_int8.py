"""INT8 calibration tables — the paper's stated FUTURE WORK, implemented.

    PYTHONPATH=src python examples/calibrate_int8.py

Runs the pipeline's ``calibrate`` stage for ResNet-18 on sample batches, shows
the JSON the NVDLA compiler expects, then quantifies the INT8 accuracy impact
vs the fp32 reference across calibration percentiles using a serving Session.
"""

import numpy as np

from repro.core import graph
from repro.core.loadable import calibrate
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session


def main():
    g = graph.resnet18()
    params = g.init_params(0)
    rng = np.random.default_rng(0)
    samples = rng.normal(0, 1, (4,) + g.input_shape).astype(np.float32)

    print("== calibration table (first layers) ==")
    pipe = CompilerPipeline(g, params, samples)
    cal = pipe.run_stage("calibrate")       # staged: only calibration runs here
    text = cal.to_json()
    print("\n".join(text.splitlines()[:10]), "\n  ...")

    print("\n== percentile sweep: INT8 vs fp32 top-1 agreement ==")
    x_eval = rng.normal(0, 1, (8,) + g.input_shape).astype(np.float32)
    for pct in (100.0, 99.99, 99.9, 99.0):
        cal = calibrate(g, params, samples, percentile=pct)
        art = CompilerPipeline(g, params, samples, sample_input=x_eval[0],
                               calibration=cal).run()
        ses = Session(art)
        from tests.test_system import _fp32_forward
        out = ses.run_batch(x_eval)         # one vmapped program for the sweep
        agree, err = 0, []
        for x, y in zip(x_eval, out.output):
            ref = _fp32_forward(g, params, x)
            agree += int(ref.argmax() == y.argmax())
            err.append(np.abs(ref - y).max() / (np.abs(ref).max() + 1e-9))
        print(f"  pct={pct:7.2f}  top1_agreement={agree}/{len(x_eval)}  "
              f"max_rel_err={np.mean(err):.4f}")


if __name__ == "__main__":
    main()
