"""End-to-end driver: train a ~100M-param llama-style model for a few hundred
steps on the synthetic pipeline, with checkpoint/restart.

    PYTHONPATH=src python examples/train_100m.py --steps 200

Exercises the full training substrate on whatever devices exist: sharded
train-step binary, AdamW, grad accumulation, checkpointing, exact data resume.
"""

import argparse

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import BatchSpec, DataIterator
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import batch_sharding, build_train_step
from repro.models import registry
from repro.models.common import ArchConfig
from repro.optim import adamw


def config_100m() -> ArchConfig:
    # ~110M params: 12L x 768, GQA 12/4 heads, vocab 32k (GPT-2-small-ish)
    return ArchConfig(name="llama-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv=4, d_ff=2048, vocab=32000,
                      grad_accum=1, loss_chunk=128, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    model = registry.get(cfg.family)
    print(f"params: {cfg.num_params()/1e6:.1f}M")
    mesh = make_host_mesh()
    spec = BatchSpec(seq_len=args.seq, global_batch=args.batch, kind="train")
    opt_cfg = adamw.AdamWConfig(lr=6e-4, total_steps=args.steps,
                                warmup_steps=20)
    with mesh:
        step_fn, sh = build_train_step(cfg, mesh, opt_cfg)
        params = jax.device_put(model.init_params(cfg, jax.random.key(0)),
                                sh["params"])
        opt_state = adamw.init(params)
        data = DataIterator(cfg, spec)
        bsh, _ = batch_sharding(cfg, mesh, spec)
        losses = []
        for step in range(args.steps):
            batch = {k: jax.device_put(jax.numpy.asarray(v), bsh[k])
                     for k, v in next(data).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}")
            if (step + 1) % 100 == 0:
                store.save(args.ckpt_dir, step + 1, (params, opt_state),
                           extras={"step": step + 1, "data": data.state()})
        # random-label synthetic data: loss should approach ln(V) from above
        print(f"loss[0]={losses[0]:.3f} -> loss[-1]={losses[-1]:.3f} "
              f"(ln V = {np.log(cfg.vocab):.3f})")


if __name__ == "__main__":
    main()
