"""CLI: profile a model's layers and print measured-vs-modeled deltas.

    PYTHONPATH=src python -m repro.obs report --model lenet5 \
        [--backend baremetal] [--iters 5] [--warmup 2] [--batch 1] \
        [--no-calibrate] [--json] [--save-calibration cal.json]

``--model`` accepts anything ``repro.frontend.resolve.resolve_net`` does
(builder name or ONNX/JSON model file).  The run compiles the model, warms
the executor, collects per-layer kernel timings over the profiled path,
fits ``perfmodel.calibrate()``, and prints the per-layer table — the
workflow behind the ROADMAP's perf-model fidelity item.
"""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report",
                         help="per-layer measured-vs-modeled fidelity report")
    rep.add_argument("--model", default="lenet5", metavar="SPEC",
                     help="builder name or ONNX/JSON model file "
                          "(default: lenet5)")
    rep.add_argument("--backend", default="baremetal",
                     help="executor backend to profile (default: baremetal)")
    rep.add_argument("--iters", type=int, default=5,
                     help="profiled runs per layer stat (median)")
    rep.add_argument("--warmup", type=int, default=2,
                     help="discarded warmup runs (pay per-op compilation)")
    rep.add_argument("--batch", type=int, default=1,
                     help="profile the batched path at this bucket size")
    rep.add_argument("--no-calibrate", action="store_true",
                     help="skip the fit; print uncalibrated deltas only")
    rep.add_argument("--json", action="store_true",
                     help="emit the report as JSON instead of the table")
    rep.add_argument("--save-calibration", default=None, metavar="FILE",
                     help="write the fitted CalibrationProfile as JSON")
    args = ap.parse_args(argv)

    from repro.core import perfmodel
    from repro.core.pipeline import CompilerPipeline
    from repro.frontend.resolve import resolve_net
    from repro.obs.report import fidelity_report, format_report, \
        profile_layers
    from repro.runtime import create_executor

    g, params = resolve_net(args.model)
    art = CompilerPipeline(g, params=params).run()
    ex = create_executor(args.backend, art)
    samples = profile_layers(ex, iters=args.iters, warmup=args.warmup,
                             batch=args.batch)
    cal = None
    if not args.no_calibrate:
        cal = perfmodel.calibrate(samples, ex.descs, dtype=ex.cfg.dtype)
    rep = fidelity_report(ex, samples, cal)
    rep["model"] = args.model
    if args.save_calibration and cal is not None:
        with open(args.save_calibration, "w") as f:
            json.dump(cal.to_dict(), f, indent=1)
        print(f"[repro.obs] calibration -> {args.save_calibration}",
              file=sys.stderr)
    if args.json:
        print(json.dumps(rep, indent=1))
    else:
        print(format_report(rep, name=args.model))
        if cal is not None:
            fams = ", ".join(
                f"{k}: {cal.compute_rate(k):.0f} MACs/us, "
                f"{cal.stream_bw(k):.0f} B/us, "
                f"launch {cal.launch_us(k):.1f}us"
                for k in sorted(cal.families))
            print(f"calibration [{cal.platform}, "
                  f"{cal.samples} samples, "
                  f"fallback {cal.us_per_cycle:.3g} us/cycle] {fams}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
