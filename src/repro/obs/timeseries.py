"""Sliding-window serving telemetry: ring-buffered time buckets holding
counters and streaming latency histograms.

Everything the cumulative-since-boot stats (``NetStats``, ``/metrics``
counters) cannot answer lives here: *windowed* p50/p90/p99, error rate and
goodput over the trailing 30s/5m/1h, so a ten-minute soak can see a
thirty-second p99 regression.  The scheduler feeds one ``record`` per
resolved request (ok / degraded / error / shed / rejected) and the SLO
burn-rate engine (``repro.obs.slo``), ``/metrics`` and the table-6
saturation harness query windows out of it.

Design:

  * **Fixed-boundary streaming histograms** (:class:`StreamingHistogram`):
    geometric bucket boundaries (``HISTOGRAM_GROWTH`` = 1.35x per bucket,
    1us .. ~66s), so a quantile estimate is the upper edge of the bucket
    holding the true rank — never below the true sample, never more than
    one growth factor above it.  Bounded error, O(1) memory, O(1) insert,
    mergeable across time buckets.
  * **Ring-buffered time buckets** (:class:`NetSeries`): wall time is
    quantised into ``bucket_s`` epochs; each epoch owns one ring slot with
    its own counters + histogram.  A slot is lazily reset when its epoch
    comes around again, and every slot remembers which epoch wrote it — a
    stale slot (clock jumped forward past it) is skipped by queries and
    recycled by writes, so arbitrary forward clock jumps stay correct.
  * **Injectable clock**: ``Telemetry(clock=...)`` — tests and simulations
    drive windows deterministically; production uses ``time.monotonic``.

The hot path (one ``record`` per request, on the dispatcher thread) is a
bisect + a few integer increments under a per-net lock.  Queries merge at
most ``ceil(window/bucket_s)`` buckets.  Stdlib + numpy only.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
import threading
import time
from typing import Dict, List, Optional, Tuple

# geometric latency bucket boundaries in microseconds: 1.35x per bucket from
# 1us to ~66s.  A recorded quantile's estimate sits in [true, true * 1.35]
# (values below the first boundary report the first boundary; values past
# the last land in one overflow bucket reporting last * 1.35).
HISTOGRAM_GROWTH = 1.35
LATENCY_BUCKETS_US: Tuple[float, ...] = tuple(
    HISTOGRAM_GROWTH ** i for i in range(61))

# terminal request statuses the scheduler records (mirrors the trace
# statuses; "cancelled" shutdown races are deliberately not recorded — a
# closing server's cancellations are not service quality signal)
STATUSES = ("ok", "degraded", "error", "shed", "rejected")

# statuses that count against an availability/error-rate objective by
# default: backend faults and deadline sheds.  429 admission rejections are
# opt-in per objective (the table-6 harness counts them; a deliberately
# overloaded soak may not want to).
BAD_STATUSES = ("error", "shed")


def snap_up(us: float) -> float:
    """Smallest histogram boundary >= ``us`` — normalising a latency
    threshold to a boundary makes ``count_over`` exact at that threshold."""
    i = bisect.bisect_left(LATENCY_BUCKETS_US, us)
    return LATENCY_BUCKETS_US[min(i, len(LATENCY_BUCKETS_US) - 1)]


class StreamingHistogram:
    """Fixed-boundary latency histogram with bounded quantile error.

    ``len(LATENCY_BUCKETS_US) + 1`` integer bins (the last is overflow);
    inserts are one bisect; ``quantile`` walks cumulative counts to the
    requested rank and reports that bucket's upper edge, so the estimate is
    >= the true rank sample and <= ``HISTOGRAM_GROWTH`` times it (for
    samples inside the boundary range).  Not thread-safe on its own — the
    owning :class:`NetSeries` serialises access.
    """

    __slots__ = ("bins", "count", "sum_us")

    def __init__(self):
        self.bins: List[int] = [0] * (len(LATENCY_BUCKETS_US) + 1)
        self.count = 0
        self.sum_us = 0.0

    def add(self, us: float) -> None:
        self.bins[bisect.bisect_left(LATENCY_BUCKETS_US, us)] += 1
        self.count += 1
        self.sum_us += us

    def merge(self, other: "StreamingHistogram") -> None:
        for i, n in enumerate(other.bins):
            self.bins[i] += n
        self.count += other.count
        self.sum_us += other.sum_us

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding the rank-``ceil(q*count)``
        sample; 0.0 when empty.  ``q`` in (0, 1]."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        cum = 0
        for i, n in enumerate(self.bins):
            cum += n
            if cum >= rank:
                if i < len(LATENCY_BUCKETS_US):
                    return LATENCY_BUCKETS_US[i]
                return LATENCY_BUCKETS_US[-1] * HISTOGRAM_GROWTH  # overflow
        return LATENCY_BUCKETS_US[-1] * HISTOGRAM_GROWTH  # pragma: no cover

    def count_over(self, threshold_us: float) -> int:
        """Samples recorded in buckets whose lower edge >= ``threshold_us``
        — exact "samples > threshold" when the threshold is a boundary
        (see :func:`snap_up`), conservative (undercount by at most one
        bucket's worth) otherwise."""
        i = bisect.bisect_left(LATENCY_BUCKETS_US, threshold_us) + 1
        return sum(self.bins[i:])

    def cumulative(self) -> List[Tuple[float, int]]:
        """Prometheus-histogram shape: ``[(le, cumulative_count), ...]``
        ending at ``(+Inf, count)``."""
        out, cum = [], 0
        for le, n in zip(LATENCY_BUCKETS_US + (float("inf"),), self.bins):
            cum += n
            out.append((le, cum))
        return out


@dataclasses.dataclass(frozen=True)
class TimeSeriesConfig:
    """Window geometry.  ``windows`` must be ascending; the ring holds
    ``ceil(windows[-1] / bucket_s) + 1`` buckets (the +1 keeps the current
    partial bucket from evicting the oldest full one).  The default
    30s/5m/1h triple is the Google-SRE multi-window ladder the burn-rate
    engine pairs up (fast: 30s+5m, slow: 5m+1h)."""
    bucket_s: float = 5.0
    windows: Tuple[float, ...] = (30.0, 300.0, 3600.0)

    def __post_init__(self):
        if self.bucket_s <= 0:
            raise ValueError(f"bucket_s must be > 0, got {self.bucket_s}")
        ws = tuple(float(w) for w in self.windows)
        if len(ws) < 2 or any(a >= b for a, b in zip(ws, ws[1:])):
            raise ValueError(f"windows must be >= 2 ascending durations, "
                             f"got {self.windows!r}")
        if ws[0] < self.bucket_s:
            raise ValueError(f"smallest window {ws[0]}s is finer than the "
                             f"bucket ({self.bucket_s}s)")
        object.__setattr__(self, "windows", ws)

    @property
    def ring_len(self) -> int:
        return int(math.ceil(self.windows[-1] / self.bucket_s)) + 1


class WindowStats:
    """One window's merged view: status counters, goodput numerator and the
    merged latency histogram, plus the covered wall time for rates."""

    __slots__ = ("window_s", "covered_s", "counts", "good", "hist")

    def __init__(self, window_s: float, covered_s: float,
                 counts: Dict[str, int], good: int, hist: StreamingHistogram):
        self.window_s = window_s
        self.covered_s = covered_s          # wall time actually observed
        self.counts = counts                # per-STATUSES request counts
        self.good = good                    # ok/degraded within deadline
        self.hist = hist                    # ok/degraded latencies only

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def quantile(self, q: float) -> float:
        return self.hist.quantile(q)

    def bad_fraction(self, statuses: Tuple[str, ...] = BAD_STATUSES) -> float:
        total = self.total
        if total == 0:
            return 0.0
        return sum(self.counts.get(s, 0) for s in statuses) / total

    @property
    def error_rate(self) -> float:
        return self.bad_fraction()

    @property
    def rps(self) -> float:
        return self.total / self.covered_s if self.covered_s > 0 else 0.0

    @property
    def goodput_rps(self) -> float:
        """Requests completed ok (and within their deadline, when they
        carried one) per second of covered wall time."""
        return self.good / self.covered_s if self.covered_s > 0 else 0.0

    @property
    def mean_us(self) -> float:
        return self.hist.sum_us / self.hist.count if self.hist.count else 0.0

    def summary(self) -> Dict[str, float]:
        """The windowed scalar set ``/v1/slo`` and the benchmarks report."""
        return {
            "total": self.total, "good": self.good,
            "p50_us": self.quantile(0.50), "p90_us": self.quantile(0.90),
            "p99_us": self.quantile(0.99), "mean_us": self.mean_us,
            "error_rate": self.error_rate, "rps": self.rps,
            "goodput_rps": self.goodput_rps,
            **{s: self.counts.get(s, 0) for s in STATUSES},
        }


class _Bucket:
    __slots__ = ("epoch", "counts", "good", "hist")

    def __init__(self):
        self.reset(-1)

    def reset(self, epoch: int) -> None:
        self.epoch = epoch
        self.counts = {s: 0 for s in STATUSES}
        self.good = 0
        self.hist = StreamingHistogram()


class NetSeries:
    """One network's ring of time buckets plus since-reset totals."""

    def __init__(self, config: TimeSeriesConfig, clock):
        self.config = config
        self._clock = clock
        self._lock = threading.Lock()
        self._ring = [_Bucket() for _ in range(config.ring_len)]
        self.total_hist = StreamingHistogram()   # since boot/reset, unwindowed
        self.total_counts = {s: 0 for s in STATUSES}
        self.t_first: Optional[float] = None     # first record since reset

    def _bucket(self, t: float) -> _Bucket:
        epoch = int(t // self.config.bucket_s)
        b = self._ring[epoch % len(self._ring)]
        if b.epoch != epoch:        # recycled slot (or clock jumped past it)
            b.reset(epoch)
        return b

    def record(self, latency_us: float, status: str = "ok",
               good: Optional[bool] = None,
               t: Optional[float] = None) -> None:
        if status not in self.total_counts:
            raise ValueError(f"unknown status {status!r}; known: {STATUSES}")
        completed = status in ("ok", "degraded")
        if good is None:
            good = completed
        t = self._clock() if t is None else t
        with self._lock:
            if self.t_first is None:
                self.t_first = t
            b = self._bucket(t)
            b.counts[status] += 1
            self.total_counts[status] += 1
            if good:
                b.good += 1
            if completed:
                b.hist.add(latency_us)
                self.total_hist.add(latency_us)

    def window(self, window_s: float, now: Optional[float] = None) -> WindowStats:
        """Merged stats over the trailing ``window_s`` (bucket-granular: the
        oldest included bucket may start up to ``bucket_s`` before
        ``now - window_s``)."""
        now = self._clock() if now is None else now
        bs = self.config.bucket_s
        k = min(len(self._ring), int(math.ceil(window_s / bs)))
        e_now = int(now // bs)
        hist = StreamingHistogram()
        counts = {s: 0 for s in STATUSES}
        good = 0
        with self._lock:
            t_first = self.t_first
            for e in range(e_now - k + 1, e_now + 1):
                b = self._ring[e % len(self._ring)]
                if b.epoch != e:                 # never written or stale
                    continue
                for s, n in b.counts.items():
                    counts[s] += n
                good += b.good
                hist.merge(b.hist)
        covered = 0.0
        if t_first is not None:
            covered = max(0.0, min(float(window_s), now - t_first))
        return WindowStats(float(window_s), covered, counts, good, hist)

    def totals(self) -> Tuple[List[Tuple[float, int]], float, int,
                              Dict[str, int]]:
        """Since-reset cumulative histogram (Prometheus shape) + counters."""
        with self._lock:
            return (self.total_hist.cumulative(), self.total_hist.sum_us,
                    self.total_hist.count, dict(self.total_counts))

    def reset(self) -> None:
        with self._lock:
            for b in self._ring:
                b.reset(-1)
            self.total_hist = StreamingHistogram()
            self.total_counts = {s: 0 for s in STATUSES}
            self.t_first = None


class Telemetry:
    """Per-net :class:`NetSeries` registry — one per ``Session``.

    The scheduler records every resolved request here (all requests, not
    just the tracer's sampled subset); the SLO engine, ``/metrics`` and the
    saturation harness read windows out.  ``clock`` defaults to
    ``time.monotonic`` and is injectable for deterministic tests.
    """

    def __init__(self, config: Optional[TimeSeriesConfig] = None,
                 clock=time.monotonic):
        self.config = config or TimeSeriesConfig()
        self.clock = clock
        self._lock = threading.Lock()
        self._series: Dict[str, NetSeries] = {}

    def series(self, net: str) -> NetSeries:
        with self._lock:
            s = self._series.get(net)
            if s is None:
                s = self._series[net] = NetSeries(self.config, self.clock)
            return s

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._series)

    def record(self, net: str, latency_us: float, status: str = "ok",
               good: Optional[bool] = None, t: Optional[float] = None) -> None:
        self.series(net).record(latency_us, status=status, good=good, t=t)

    def window(self, net: str, window_s: float,
               now: Optional[float] = None) -> WindowStats:
        return self.series(net).window(window_s, now=now)

    def reset(self, net: Optional[str] = None) -> None:
        """Clear recorded samples (one net's, or every net's) — phase
        isolation for benchmarks/tests; production never needs it."""
        with self._lock:
            targets = ([self._series[net]] if net is not None
                       and net in self._series
                       else list(self._series.values()) if net is None
                       else [])
        for s in targets:
            s.reset()
