"""Declarative SLOs and the multi-window burn-rate engine.

An :class:`SloPolicy` declares what "good service" means for one net (or
``"*"`` for all): latency-percentile objectives ("p99 <= 15ms"), error-rate
objectives ("< 1% of requests error or shed") and goodput floors ("> 50
good req/s").  The :class:`SloEngine` evaluates policies against the
windowed telemetry (``repro.obs.timeseries``) Google-SRE style: instead of
alerting on a raw threshold, it computes each objective's **burn rate** —
how fast the error budget is being consumed, ``bad_fraction / budget`` —
over *paired* windows, and alerts only when both windows of a pair agree:

  * **breach**: burn >= ``fast_burn`` (default 14x) on BOTH the two
    shortest windows (30s + 5m by default) — a fast, sustained burn;
    paging-grade.
  * **warning**: burn >= ``slow_burn`` (default 2x) on BOTH the two
    longest windows (5m + 1h) — a slow leak that exhausts the budget
    well before the period ends; ticket-grade.

The long window makes the alert *proportional* (a one-request blip cannot
fire it); the short window makes it *reset fast* (the alert clears soon
after the cause does, instead of lingering for the long window's span).
State transitions emit ``slo_burn`` instants into the PR 9 trace store,
flip the per-net ``slo_state`` gauge surfaced on ``/metrics`` / ``/healthz``
/ ``GET /v1/slo``, and — when the policy opts in — trip the PR 8 circuit
breaker open so the fallback/shedding machinery reacts to the breach.

Policies load from JSON (``repro.serve --slo slo.json``)::

    {"policies": [{
        "net": "lenet5",              // or "*"
        "objectives": [
            {"kind": "latency", "quantile": 0.99, "threshold_ms": 15},
            {"kind": "error_rate", "budget": 0.01},
            {"kind": "goodput", "min_rps": 50}
        ],
        "fast_burn": 14, "slow_burn": 2,
        "open_circuit_on_breach": false
    }]}

Stdlib only; deterministic under an injected telemetry clock.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.timeseries import (BAD_STATUSES, Telemetry, WindowStats,
                                  snap_up)

STATES = ("ok", "warning", "breach")
STATE_CODES = {s: i for i, s in enumerate(STATES)}  # /metrics gauge values


@dataclasses.dataclass(frozen=True)
class SloObjective:
    """One measurable objective.  ``kind``:

    * ``latency``  — at most ``budget`` of requests slower than
      ``threshold_us`` (i.e. "p<quantile> <= threshold"; ``budget``
      defaults to ``1 - quantile``).  The threshold is snapped up to a
      histogram boundary at construction so the windowed bad-fraction is
      exact (see ``timeseries.snap_up``).
    * ``error_rate`` — at most ``budget`` of requests end in a
      ``bad_statuses`` terminal state.
    * ``goodput`` — at least ``min_rps`` good requests per second; burn is
      ``min_rps / observed`` so 2x means serving half the floor.
    """
    kind: str
    quantile: float = 0.99              # latency
    threshold_us: float = 0.0           # latency
    budget: float = 0.0                 # latency (default 1-quantile), error
    min_rps: float = 0.0                # goodput
    bad_statuses: Tuple[str, ...] = BAD_STATUSES

    def __post_init__(self):
        if self.kind not in ("latency", "error_rate", "goodput"):
            raise ValueError(f"unknown objective kind {self.kind!r}")
        if self.kind == "latency":
            if not 0.0 < self.quantile < 1.0:
                raise ValueError(f"latency quantile must be in (0,1), "
                                 f"got {self.quantile}")
            if self.threshold_us <= 0:
                raise ValueError("latency objective needs threshold_us > 0")
            object.__setattr__(self, "threshold_us",
                               snap_up(self.threshold_us))
            if self.budget <= 0.0:
                object.__setattr__(self, "budget", 1.0 - self.quantile)
        elif self.kind == "error_rate":
            if self.budget <= 0.0:
                object.__setattr__(self, "budget", 0.01)
        elif self.kind == "goodput" and self.min_rps <= 0:
            raise ValueError("goodput objective needs min_rps > 0")

    # -- evaluation ---------------------------------------------------------
    def burn(self, w: WindowStats) -> float:
        """Error-budget burn rate over one window (1.0 = consuming exactly
        the budget; 0.0 when the window holds no signal)."""
        if self.kind == "latency":
            if w.hist.count == 0:
                return 0.0
            bad = w.hist.count_over(self.threshold_us) / w.hist.count
            return bad / self.budget
        if self.kind == "error_rate":
            if w.total == 0:
                return 0.0
            return w.bad_fraction(self.bad_statuses) / self.budget
        # goodput: no traffic at all is "no data", not an outage — the
        # error-rate/latency objectives own in-traffic failure modes
        if w.total == 0 or w.covered_s <= 0:
            return 0.0
        gp = w.goodput_rps
        return self.min_rps / gp if gp > 0 else float("inf")

    def value(self, w: WindowStats) -> float:
        """The observed quantity the objective constrains (for reporting)."""
        if self.kind == "latency":
            return w.quantile(self.quantile)
        if self.kind == "error_rate":
            return w.bad_fraction(self.bad_statuses)
        return w.goodput_rps

    def compliant(self, w: WindowStats) -> bool:
        """Direct point-in-window compliance (burn <= 1) — what the table-6
        saturation search gates probes on (alerting uses burn pairs)."""
        return self.burn(w) <= 1.0

    def describe(self) -> str:
        if self.kind == "latency":
            return (f"p{self.quantile * 100:g} <= "
                    f"{self.threshold_us / 1e3:.3g}ms")
        if self.kind == "error_rate":
            return (f"{'|'.join(self.bad_statuses)} rate <= "
                    f"{self.budget:.2%}")
        return f"goodput >= {self.min_rps:g} req/s"

    def to_dict(self) -> Dict:
        d = {"kind": self.kind}
        if self.kind == "latency":
            d.update(quantile=self.quantile, threshold_us=self.threshold_us,
                     budget=self.budget)
        elif self.kind == "error_rate":
            d.update(budget=self.budget, bad_statuses=list(self.bad_statuses))
        else:
            d.update(min_rps=self.min_rps)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "SloObjective":
        d = dict(d)
        if "threshold_ms" in d:            # JSON convenience spelling
            d["threshold_us"] = float(d.pop("threshold_ms")) * 1e3
        if "bad_statuses" in d:
            d["bad_statuses"] = tuple(d["bad_statuses"])
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown objective field(s): {sorted(unknown)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class SloPolicy:
    """Objectives plus alerting thresholds for one net (``"*"`` = default
    policy for any net without an exact match)."""
    net: str = "*"
    objectives: Tuple[SloObjective, ...] = ()
    fast_burn: float = 14.0     # breach: both short windows burning this hot
    slow_burn: float = 2.0      # warning: both long windows burning this hot
    min_samples: int = 10       # per-window floor before it can vote
    open_circuit_on_breach: bool = False

    def __post_init__(self):
        if not self.objectives:
            raise ValueError(f"policy for {self.net!r} declares no objectives")
        object.__setattr__(self, "objectives", tuple(self.objectives))
        if self.slow_burn > self.fast_burn:
            raise ValueError("slow_burn must be <= fast_burn")

    def check(self, w: WindowStats) -> Tuple[bool, List[Dict]]:
        """Direct compliance of one window against every objective — the
        saturation harness's per-probe oracle."""
        details = [{"objective": o.describe(), "kind": o.kind,
                    "value": o.value(w), "burn": o.burn(w),
                    "ok": o.compliant(w)} for o in self.objectives]
        return all(d["ok"] for d in details), details

    def to_dict(self) -> Dict:
        return {"net": self.net,
                "objectives": [o.to_dict() for o in self.objectives],
                "fast_burn": self.fast_burn, "slow_burn": self.slow_burn,
                "min_samples": self.min_samples,
                "open_circuit_on_breach": self.open_circuit_on_breach}

    @classmethod
    def from_dict(cls, d: Dict) -> "SloPolicy":
        d = dict(d)
        d["objectives"] = tuple(SloObjective.from_dict(o)
                                for o in d.get("objectives", ()))
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown policy field(s): {sorted(unknown)}")
        return cls(**d)


def load_policies(path) -> Tuple[SloPolicy, ...]:
    """Load ``{"policies": [...]}`` (or a bare list) from a JSON file."""
    doc = json.loads(pathlib.Path(path).read_text())
    items = doc.get("policies", doc) if isinstance(doc, dict) else doc
    if not isinstance(items, list) or not items:
        raise ValueError(f"{path}: expected a non-empty policy list")
    return tuple(SloPolicy.from_dict(p) for p in items)


class SloEngine:
    """Evaluates policies against the telemetry windows; owns per-net
    state (ok/warning/breach), emits ``slo_burn`` trace instants on every
    transition, and optionally trips the circuit breaker on breach.

    ``evaluate()`` is cheap (a few window merges per net) and idempotent;
    call it ad hoc (every ``/metrics`` scrape and ``/v1/slo`` hit does) or
    let ``start(period_s)`` run it on a daemon thread.  ``breaker`` is a
    ``callable(net_name)`` that force-opens that net's circuit.
    """

    def __init__(self, policies: Sequence[SloPolicy], telemetry: Telemetry,
                 tracer=None, breaker: Optional[Callable[[str], None]] = None):
        self.policies = tuple(policies)
        if not self.policies:
            raise ValueError("SloEngine needs at least one policy")
        self.telemetry = telemetry
        self.tracer = tracer
        self.breaker = breaker
        ws = telemetry.config.windows
        self.fast_windows = ws[:2]           # e.g. (30s, 5m)
        self.slow_windows = ws[-2:]          # e.g. (5m, 1h)
        self._lock = threading.Lock()
        self._states: Dict[str, str] = {}
        self._detail: Dict[str, Dict] = {}
        self._stop: Optional[threading.Event] = None
        self._thread: Optional[threading.Thread] = None

    def policy_for(self, net: str) -> Optional[SloPolicy]:
        """Exact-name policy wins over a ``"*"`` wildcard."""
        wild = None
        for p in self.policies:
            if p.net == net:
                return p
            if p.net == "*":
                wild = p
        return wild

    def _nets(self) -> List[str]:
        nets = set(self.telemetry.names())
        nets.update(p.net for p in self.policies if p.net != "*")
        return sorted(n for n in nets if self.policy_for(n) is not None)

    def evaluate(self, now: Optional[float] = None) -> Dict[str, str]:
        """One evaluation pass; returns ``{net: state}`` and updates the
        published detail.  Thread-safe; transitions fire side effects."""
        now = self.telemetry.clock() if now is None else now
        results: Dict[str, str] = {}
        details: Dict[str, Dict] = {}
        transitions = []                     # fire side effects outside lock
        with self._lock:
            for net in self._nets():
                policy = self.policy_for(net)
                windows = sorted(set(self.fast_windows + self.slow_windows))
                stats = {w: self.telemetry.window(net, w, now=now)
                         for w in windows}
                state, objs = "ok", []
                for obj in policy.objectives:
                    burns = {w: obj.burn(stats[w]) for w in windows}
                    voting = {w: stats[w].total >= policy.min_samples
                              for w in windows}
                    fast = all(voting[w] and burns[w] >= policy.fast_burn
                               for w in self.fast_windows)
                    slow = all(voting[w] and burns[w] >= policy.slow_burn
                               for w in self.slow_windows)
                    ostate = ("breach" if fast else
                              "warning" if slow else "ok")
                    if STATE_CODES[ostate] > STATE_CODES[state]:
                        state = ostate
                    objs.append({
                        "objective": obj.describe(), "kind": obj.kind,
                        "state": ostate,
                        "burn": {f"{w:g}s": round(burns[w], 4)
                                 for w in windows},
                        "value": {f"{w:g}s": obj.value(stats[w])
                                  for w in windows},
                    })
                prev = self._states.get(net, "ok")
                self._states[net] = state
                details[net] = {
                    "state": state, "objectives": objs,
                    "windows": {f"{w:g}s": stats[w].summary()
                                for w in windows},
                }
                results[net] = state
                if state != prev:
                    worst = max((o for o in objs
                                 if o["state"] == state),
                                key=lambda o: max(o["burn"].values()),
                                default=objs[0])
                    transitions.append((net, prev, state, policy, worst))
            self._detail = details
        for net, prev, state, policy, worst in transitions:
            if self.tracer is not None:
                self.tracer.note_global(
                    "slo_burn", net=net, state=state, prev=prev,
                    objective=worst["objective"],
                    burn=max(worst["burn"].values()))
            if (state == "breach" and policy.open_circuit_on_breach
                    and self.breaker is not None):
                self.breaker(net)
        return results

    def states(self) -> Dict[str, str]:
        with self._lock:
            return dict(self._states)

    def state(self, net: str) -> str:
        with self._lock:
            return self._states.get(net, "ok")

    def snapshot(self) -> Dict:
        """The ``GET /v1/slo`` document (call ``evaluate()`` first for a
        fresh view)."""
        with self._lock:
            return {
                "windows": [f"{w:g}s" for w in self.telemetry.config.windows],
                "burn_pairs": {
                    "fast": [f"{w:g}s" for w in self.fast_windows],
                    "slow": [f"{w:g}s" for w in self.slow_windows]},
                "policies": [p.to_dict() for p in self.policies],
                "nets": dict(self._detail),
            }

    # -- background evaluator -----------------------------------------------
    def start(self, period_s: float = 5.0) -> None:
        if self._thread is not None:
            return
        self._stop = threading.Event()

        def loop():
            while not self._stop.wait(period_s):
                try:
                    self.evaluate()
                except Exception:            # pragma: no cover - paranoia
                    pass

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="repro-slo")
        self._thread.start()

    def close(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None
            self._stop = None
