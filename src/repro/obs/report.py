"""Measured-vs-modeled per-layer fidelity: profile, calibrate, report.

The ROADMAP's perf-model fidelity item (resnet18 ``cycle_ratio=2.82``) needs
the per-layer measured timings the executors' profiled path collects.  This
module turns those samples into the calibration workflow:

    ex = create_executor("baremetal", art)
    samples = profile_layers(ex, iters=5)            # median us per layer
    cal = perfmodel.calibrate(samples, ex.descs)     # CalibrationProfile
    rep = fidelity_report(ex, samples, cal)          # per-layer deltas
    print(format_report(rep))

``python -m repro.obs report`` wraps exactly this over any frontend-
resolvable model.  The error metric is the mean absolute log-ratio
``mean(|ln(measured/modeled)|)`` over the GEMM layers: scale-invariant, so
the *uncalibrated* model gets the fairest possible baseline — its single
best global scale (the geometric-mean ratio) is divided out before its
error is charged — and the calibrated fit must win on *shape*, not on
units.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import perfmodel


def profile_layers(ex, x=None, iters: int = 3, warmup: int = 1,
                   batch: int = 1) -> List[Dict]:
    """Run the executor's profiled path and aggregate per-layer medians.

    Returns one sample dict per descriptor — ``{"index", "unit", "kernel",
    "bucket", "us"}`` with ``us`` the median over ``iters`` runs (the first
    ``warmup`` runs are discarded: they pay per-op compilation)."""
    if x is None:
        dims = tuple(ex.input_dims)[1:]
        rng = np.random.default_rng(0)
        x = rng.normal(0, 1, dims).astype(np.float32)
    if batch > 1:
        X = np.stack([x] * batch)
        run = lambda: ex.run_batch_profiled(X, lanes=batch)[1]
    else:
        run = lambda: ex.run_profiled(x)[1]
    for _ in range(max(warmup, 1)):
        run()
    per_run = [run() for _ in range(max(iters, 1))]
    out = []
    for i, first in enumerate(per_run[0]):
        med = float(np.median([r[i]["us"] for r in per_run]))
        s = dict(first)
        s["us"] = med
        s.pop("t0", None)
        s.pop("t1", None)
        out.append(s)
    return out


def fidelity_report(ex, samples: Sequence[Dict],
                    calibration: Optional[perfmodel.CalibrationProfile]
                    = None) -> Dict:
    """Per-layer measured vs modeled (uncalibrated and calibrated) table.

    ``rows``: layer index, unit, kernel, measured us, both models' us and
    signed error percentages.  ``err_uncal``/``err_cal``: mean absolute
    log-ratio over the CONV/FC layers (the layers ``select_kernel`` actually
    costs); calibrated columns are present only when ``calibration`` is.
    """
    descs, dtype = ex.descs, ex.cfg.dtype
    prof = perfmodel.resolve_profile(None)
    meas, static, feats = [], [], []
    for s in samples:
        d = descs[int(s["index"])]
        lanes = max(int(s.get("bucket", 1)), 1)
        kernel = s.get("kernel") or perfmodel.KERNEL_VPU
        meas.append(float(s["us"]))
        static.append(perfmodel.static_cost_units(
            d, kernel, prof, dtype, lanes, bool(s.get("native", False))))
        feats.append(perfmodel.sample_features(d, dtype))
    gemm = [i for i, s in enumerate(samples)
            if descs[int(s["index"])].unit in ("CONV", "FC")]
    # the uncalibrated model's single best global scale: geometric-mean
    # measured/static ratio over the layers the error is charged on
    ratios = [meas[i] / static[i] for i in gemm
              if static[i] > 0 and math.isfinite(static[i]) and meas[i] > 0]
    scale = math.exp(sum(math.log(r) for r in ratios) / len(ratios)) \
        if ratios else 1.0
    rows, errs_u, errs_c = [], [], []
    for i, s in enumerate(samples):
        d = descs[int(s["index"])]
        kernel = s.get("kernel") or perfmodel.KERNEL_VPU
        lanes = max(int(s.get("bucket", 1)), 1)
        uncal = static[i] * scale if math.isfinite(static[i]) else float("nan")
        row = {"index": int(s["index"]), "unit": d.unit, "kernel": kernel,
               "bucket": lanes, "measured_us": meas[i],
               "modeled_uncal_us": uncal,
               "err_uncal_pct": (uncal / meas[i] - 1.0) * 100.0
               if meas[i] > 0 and math.isfinite(uncal) else float("nan")}
        if calibration is not None:
            macs, sbytes = feats[i]
            cal = calibration.predict_us(
                kernel, macs, sbytes, batch=lanes,
                native=bool(s.get("native", False)),
                static_cost=static[i] if math.isfinite(static[i]) else None)
            row["modeled_cal_us"] = cal if cal is not None else float("nan")
            row["err_cal_pct"] = (cal / meas[i] - 1.0) * 100.0 \
                if cal and meas[i] > 0 else float("nan")
        rows.append(row)
        if i in gemm and meas[i] > 0:
            if math.isfinite(uncal) and uncal > 0:
                errs_u.append(abs(math.log(uncal / meas[i])))
            cal = row.get("modeled_cal_us")
            if calibration is not None and cal and math.isfinite(cal):
                errs_c.append(abs(math.log(cal / meas[i])))
    rep = {"dtype": dtype, "platform": prof.platform, "rows": rows,
           "gemm_layers": len(gemm), "uncal_scale": scale,
           "err_uncal": float(np.mean(errs_u)) if errs_u else float("nan")}
    if calibration is not None:
        rep["err_cal"] = float(np.mean(errs_c)) if errs_c else float("nan")
    return rep


def format_report(rep: Dict, name: str = "") -> str:
    """Human-readable per-layer delta table for the report CLI."""
    has_cal = "err_cal" in rep
    head = (f"{'layer':>5} {'unit':<4} {'kernel':<18} {'bucket':>6} "
            f"{'measured_us':>12} {'model_us':>10} {'err%':>8}")
    if has_cal:
        head += f" {'cal_us':>10} {'cal_err%':>8}"
    lines = [f"fidelity report{' — ' + name if name else ''} "
             f"[{rep['dtype']} on {rep['platform']}, "
             f"uncal scale {rep['uncal_scale']:.3g} us/cycle]", head,
             "-" * len(head)]
    for r in rep["rows"]:
        line = (f"{r['index']:>5} {r['unit']:<4} {r['kernel']:<18} "
                f"{r['bucket']:>6} {r['measured_us']:>12.1f} "
                f"{r['modeled_uncal_us']:>10.1f} {r['err_uncal_pct']:>+8.1f}")
        if has_cal:
            line += (f" {r['modeled_cal_us']:>10.1f} "
                     f"{r['err_cal_pct']:>+8.1f}")
        lines.append(line)
    lines.append("-" * len(head))
    tail = (f"mean |log err| over {rep['gemm_layers']} GEMM layers: "
            f"uncalibrated {rep['err_uncal']:.3f}")
    if has_cal:
        tail += f" -> calibrated {rep['err_cal']:.3f}"
    lines.append(tail)
    return "\n".join(lines)
