"""Request tracing: ids, lifecycle spans, per-layer samples, Chrome export.

One ``Tracer`` lives on each ``Session`` and is threaded through the
scheduler and the HTTP front-end.  Every submitted request gets a trace id
(accepted/emitted over HTTP as the ``X-Repro-Trace-Id`` header); a
deterministic every-Nth-request sampler (``TraceConfig.sample_rate``)
decides which requests additionally record a ``RequestTrace`` — monotonic
``time.perf_counter`` spans for queue-wait, coalesce/hold, pad, launch,
device-execute, retry backoff, plus instant events for the fault paths
(shed, watchdog fire, arena reset, circuit transitions).  A request whose
id was supplied by the client is ALWAYS traced, so a caller can opt a
specific request into tracing regardless of the sampler.

Completed traces land in a bounded ring buffer and export as Chrome
trace-event JSON (``chrome://tracing`` / Perfetto "JSON Object Format"):
one pid, one tid per trace, ``ph:"X"`` complete events for spans (ts/dur in
microseconds relative to the tracer's epoch) and ``ph:"i"`` instants for
events.  The tracer also aggregates per-(net, phase) latency histograms
that ``/metrics`` renders in Prometheus histogram format.

Everything here is stdlib-only and lock-light: the per-request hot path is
a handful of ``perf_counter`` calls and list appends on the (GIL-atomic)
span list; the tracer lock guards only the sampler counters, the ring
buffer, and the histogram bins.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

TRACE_HEADER = "X-Repro-Trace-Id"

# phase-histogram bucket upper bounds in microseconds (Prometheus ``le``);
# +Inf is implicit as the final bucket
PHASE_BUCKETS_US: Tuple[float, ...] = (
    50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
    25000.0, 50000.0, 100000.0, 250000.0, 1000000.0)

_ID_ALPHABET = "0123456789abcdef"


def new_trace_id() -> str:
    """16 hex chars of OS randomness — compact, log-greppable, collision-safe
    at any realistic request volume."""
    return os.urandom(8).hex()


def valid_trace_id(tid: str) -> bool:
    """Accept client-supplied ids that are sane header tokens: 1-64 chars of
    [A-Za-z0-9._-] (W3C traceparent ids and uuids both pass)."""
    if not tid or len(tid) > 64:
        return False
    return all(c.isalnum() or c in "._-" for c in tid)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Tracing/profiling knobs (``Session(trace=...)``, ``--trace-sample``).

    ``sample_rate=N`` traces every Nth request per net (1 = all, 0 = only
    requests that arrive with a client-supplied trace id); ``profile=True``
    additionally runs sampled requests through the executors' per-layer
    profiled path (stepwise kernel timing — slower, for calibration runs).
    ``enabled=False`` turns the subsystem off entirely: ids are still
    assigned (the HTTP contract keeps holding) but nothing is recorded.
    """
    enabled: bool = True
    sample_rate: int = 1
    profile: bool = False
    capacity: int = 256            # completed-trace ring buffer length
    max_events: int = 512          # span+event cap per trace (runaway guard)

    def __post_init__(self):
        if self.sample_rate < 0:
            raise ValueError(f"sample_rate must be >= 0, got "
                             f"{self.sample_rate}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")


@dataclasses.dataclass
class Span:
    name: str
    t0: float                      # time.perf_counter seconds
    t1: float
    args: Dict = dataclasses.field(default_factory=dict)


class RequestTrace:
    """Recorded lifecycle of ONE sampled request.

    Mutated from the submitting thread, the dispatcher thread and the
    launcher worker — appends to the span/event lists are GIL-atomic, and
    ``Tracer.finish`` is the only cross-thread ordering point (idempotent
    under the tracer lock, so the fault paths can't double-complete it).
    """

    __slots__ = ("trace_id", "net", "t_start", "t_end", "status", "error",
                 "profile", "spans", "events", "layers", "finished")

    def __init__(self, trace_id: str, net: str, profile: bool = False,
                 t_start: Optional[float] = None):
        self.trace_id = trace_id
        self.net = net
        self.t_start = time.perf_counter() if t_start is None else t_start
        self.t_end = 0.0
        self.status = "pending"
        self.error = ""
        self.profile = profile
        self.spans: List[Span] = []
        self.events: List[Tuple[str, float, Dict]] = []
        self.layers: List[Dict] = []
        self.finished = False

    def add_span(self, name: str, t0: float, t1: float, **args) -> None:
        if len(self.spans) < 512 and t1 >= t0:
            self.spans.append(Span(name, t0, t1, args))

    def event(self, name: str, t: Optional[float] = None, **args) -> None:
        if len(self.events) < 512:
            self.events.append((name, time.perf_counter() if t is None
                                else t, args))

    def add_layers(self, samples: List[Dict]) -> None:
        """Attach per-layer kernel samples from a profiled launch."""
        room = 2048 - len(self.layers)
        if room > 0:
            self.layers.extend(samples[:room])

    @property
    def duration_us(self) -> float:
        end = self.t_end or time.perf_counter()
        return (end - self.t_start) * 1e6

    def phase_us(self) -> Dict[str, float]:
        """Summed span duration per phase name, plus end-to-end ``total``."""
        out: Dict[str, float] = {}
        for s in self.spans:
            out[s.name] = out.get(s.name, 0.0) + (s.t1 - s.t0) * 1e6
        if self.t_end:
            out["total"] = (self.t_end - self.t_start) * 1e6
        return out


# future-outcome exception name -> trace status (name-based so this module
# never imports the runtime layer: no circular imports, and stub errors in
# tests map the same way)
_STATUS_BY_EXC = {
    "DeadlineExceededError": "shed",
    "QueueFullError": "rejected",
    "CircuitOpenError": "rejected",
    "CancelledError": "cancelled",
}


def status_for_exception(exc: BaseException) -> str:
    """Terminal trace status for a request that failed with ``exc``."""
    return _STATUS_BY_EXC.get(type(exc).__name__, "error")


class Tracer:
    """Session-wide trace collector: sampler, ring buffer, histograms."""

    def __init__(self, config: Optional[TraceConfig] = None):
        self.config = config or TraceConfig()
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}     # per-net submit counter
        self._store: List[RequestTrace] = []    # ring, newest last
        self._hist: Dict[Tuple[str, str], List] = {}  # (net,phase)->[bins,sum,n]
        self._global_events: List[Tuple[str, float, Dict]] = []
        self.epoch = time.perf_counter()        # ts=0 of the Chrome export
        self.dropped = 0                        # traces evicted from the ring

    # -- lifecycle ----------------------------------------------------------
    def start(self, net: str, trace_id: Optional[str] = None,
              t_start: Optional[float] = None) -> Tuple[str,
                                                        Optional[RequestTrace]]:
        """Admit one request: always returns its (possibly fresh) trace id,
        plus a ``RequestTrace`` when the sampler (or a client-supplied id)
        selects it for recording.  ``t_start`` pins the trace window to the
        caller's submit timestamp so the queue span nests inside it."""
        cfg = self.config
        forced = trace_id is not None
        tid = trace_id if forced else new_trace_id()
        if not cfg.enabled:
            return tid, None
        with self._lock:
            n = self._counters.get(net, 0)
            self._counters[net] = n + 1
        sampled = cfg.sample_rate > 0 and n % cfg.sample_rate == 0
        if not (sampled or forced):
            return tid, None
        return tid, RequestTrace(tid, net, profile=cfg.profile,
                                 t_start=t_start)

    def finish(self, trace: Optional[RequestTrace], status: str = "ok",
               error: str = "") -> None:
        """Complete a trace exactly once (idempotent; later calls no-op)."""
        if trace is None:
            return
        with self._lock:
            if trace.finished:
                return
            trace.finished = True
        trace.t_end = time.perf_counter()
        trace.status = status
        trace.error = error
        trace.add_span("request", trace.t_start, trace.t_end,
                       status=status, **({"error": error} if error else {}))
        with self._lock:
            self._store.append(trace)
            if len(self._store) > self.config.capacity:
                self.dropped += len(self._store) - self.config.capacity
                del self._store[:len(self._store) - self.config.capacity]
            for phase, us in trace.phase_us().items():
                key = (trace.net, phase)
                h = self._hist.get(key)
                if h is None:
                    h = self._hist[key] = [[0] * (len(PHASE_BUCKETS_US) + 1),
                                           0.0, 0]
                bins, _, _ = h
                i = 0
                while i < len(PHASE_BUCKETS_US) and us > PHASE_BUCKETS_US[i]:
                    i += 1
                bins[i] += 1
                h[1] += us
                h[2] += 1

    def finish_future(self, trace: RequestTrace, fut) -> None:
        """``Future.add_done_callback`` hook: derive the terminal status from
        the future's outcome — ok / degraded / shed / rejected / cancelled /
        error — so every admitted request completes its trace exactly once
        no matter which path (success, retry-exhaustion, shed, close)
        resolved it."""
        try:
            if fut.cancelled():
                self.finish(trace, status="cancelled")
                return
            exc = fut.exception()
            if exc is None:
                res = fut.result()
                degraded = bool(getattr(res, "degraded", False))
                self.finish(trace, status="degraded" if degraded else "ok")
            else:
                self.finish(trace, status=status_for_exception(exc),
                            error=type(exc).__name__)
        except Exception:                       # pragma: no cover - paranoia
            self.finish(trace, status="error", error="finish_future")

    # -- fault-plane events -------------------------------------------------
    def note_global(self, name: str, **args) -> None:
        """Record a session-wide instant event (not tied to any single
        request's trace): circuit transitions, SLO burn alerts.  Rendered as
        a process-scoped instant in the Chrome export."""
        if not self.config.enabled:
            return
        with self._lock:
            self._global_events.append((name, time.perf_counter(), args))
            del self._global_events[:-256]

    def note_circuit(self, net: str, state: str) -> None:
        """Record a circuit-breaker transition."""
        self.note_global("circuit_" + state, net=net)

    def global_events(self) -> List[Tuple[str, float, Dict]]:
        with self._lock:
            return list(self._global_events)

    # -- export -------------------------------------------------------------
    def traces(self, limit: Optional[int] = None) -> List[RequestTrace]:
        with self._lock:
            out = list(self._store)
        return out[-limit:] if limit else out

    def phase_histograms(self) -> Dict[Tuple[str, str], Dict]:
        """{(net, phase): {"buckets": [(le, cumulative_count)...], "sum",
        "count"}} with the +Inf bucket last — Prometheus histogram shape."""
        with self._lock:
            snap = {k: ([list(v[0])], v[1], v[2]) for k, v in
                    self._hist.items()}
        out = {}
        for key, (bins_w, total, count) in snap.items():
            bins = bins_w[0]
            cum, buckets = 0, []
            for le, n in zip(PHASE_BUCKETS_US + (float("inf"),), bins):
                cum += n
                buckets.append((le, cum))
            out[key] = {"buckets": buckets, "sum": total, "count": count}
        return out

    def _rel_us(self, t: float) -> float:
        return (t - self.epoch) * 1e6

    def chrome_trace(self, limit: Optional[int] = None) -> Dict:
        """Chrome trace-event JSON ("JSON Object Format"): load the result of
        ``json.dumps`` straight into chrome://tracing or ui.perfetto.dev."""
        events: List[Dict] = [
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "repro.serve"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
             "args": {"name": "scheduler"}},
        ]
        with self._lock:
            glob = list(self._global_events)
        for name, t, args in glob:
            events.append({"ph": "i", "pid": 1, "tid": 0, "name": name,
                           "s": "p", "ts": self._rel_us(t), "args": args})
        for i, tr in enumerate(self.traces(limit)):
            tid = i + 1
            events.append({"ph": "M", "pid": 1, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": f"{tr.net} {tr.trace_id}"}})
            for s in tr.spans:
                events.append({
                    "ph": "X", "pid": 1, "tid": tid, "cat": "request",
                    "name": s.name, "ts": self._rel_us(s.t0),
                    "dur": max((s.t1 - s.t0) * 1e6, 0.001),
                    "args": dict(s.args, trace_id=tr.trace_id)})
            for name, t, args in tr.events:
                events.append({"ph": "i", "pid": 1, "tid": tid, "s": "t",
                               "cat": "request", "name": name,
                               "ts": self._rel_us(t),
                               "args": dict(args, trace_id=tr.trace_id)})
            for ly in tr.layers:
                ev = {"ph": "X", "pid": 1, "tid": tid, "cat": "kernel",
                      "name": f"{ly.get('unit', '?')}"
                              f"#{ly.get('index', '?')}:"
                              f"{ly.get('kernel', '?')}",
                      "dur": max(float(ly.get("us", 0.0)), 0.001),
                      "args": dict(ly, trace_id=tr.trace_id)}
                ev["ts"] = (self._rel_us(float(ly["t0"])) if "t0" in ly
                            else self._rel_us(tr.t_start))
                events.append(ev)
        events.sort(key=lambda e: e.get("ts", 0.0))
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"source": "repro.obs", "dropped": self.dropped}}

    def to_file(self, path) -> None:
        import json
        import pathlib
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.chrome_trace(), indent=1))
