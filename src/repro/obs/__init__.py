"""repro.obs — tracing, profiling, windowed telemetry and SLO monitoring.

Public surface:

  * ``TraceConfig`` / ``Tracer`` / ``RequestTrace`` — lifecycle spans with
    deterministic sampling, a ring-buffered store, Chrome trace-event
    export, per-phase latency histograms (``src/repro/obs/trace.py``).
  * ``TRACE_HEADER`` — the ``X-Repro-Trace-Id`` HTTP contract.
  * ``Telemetry`` / ``TimeSeriesConfig`` / ``StreamingHistogram`` /
    ``WindowStats`` — sliding-window serving telemetry: ring-buffered time
    buckets with bounded-error streaming histograms; windowed
    p50/p90/p99/error-rate/goodput over 30s/5m/1h
    (``src/repro/obs/timeseries.py``).
  * ``SloPolicy`` / ``SloObjective`` / ``SloEngine`` / ``load_policies`` —
    declarative per-net objectives evaluated by a Google-SRE multi-window
    burn-rate engine; emits ``slo_burn`` trace events, drives the
    ``slo_state`` gauge and can trip the circuit breaker
    (``src/repro/obs/slo.py``).
  * ``profile_layers`` / ``fidelity_report`` / ``format_report`` — the
    measured-vs-modeled calibration workflow over the executors' profiled
    path (``src/repro/obs/report.py``; the fit itself is
    ``repro.core.perfmodel.calibrate``).
  * ``python -m repro.obs report`` — CLI printing per-layer deltas for any
    frontend-resolvable model.
"""

from repro.obs.trace import (PHASE_BUCKETS_US, RequestTrace, Span,
                             TRACE_HEADER, TraceConfig, Tracer, new_trace_id,
                             status_for_exception, valid_trace_id)
from repro.obs.timeseries import (BAD_STATUSES, HISTOGRAM_GROWTH,
                                  LATENCY_BUCKETS_US, NetSeries,
                                  StreamingHistogram, Telemetry,
                                  TimeSeriesConfig, WindowStats, snap_up)
from repro.obs.slo import (STATE_CODES, SloEngine, SloObjective, SloPolicy,
                           load_policies)
from repro.obs.report import fidelity_report, format_report, profile_layers

__all__ = [
    "PHASE_BUCKETS_US", "RequestTrace", "Span", "TRACE_HEADER",
    "TraceConfig", "Tracer", "new_trace_id", "status_for_exception",
    "valid_trace_id",
    "BAD_STATUSES", "HISTOGRAM_GROWTH", "LATENCY_BUCKETS_US", "NetSeries",
    "StreamingHistogram", "Telemetry", "TimeSeriesConfig", "WindowStats",
    "snap_up",
    "STATE_CODES", "SloEngine", "SloObjective", "SloPolicy", "load_policies",
    "fidelity_report", "format_report", "profile_layers",
]
