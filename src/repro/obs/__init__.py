"""repro.obs — end-to-end request tracing + per-layer kernel profiling.

Public surface:

  * ``TraceConfig`` / ``Tracer`` / ``RequestTrace`` — lifecycle spans with
    deterministic sampling, a ring-buffered store, Chrome trace-event
    export, per-phase latency histograms (``src/repro/obs/trace.py``).
  * ``TRACE_HEADER`` — the ``X-Repro-Trace-Id`` HTTP contract.
  * ``profile_layers`` / ``fidelity_report`` / ``format_report`` — the
    measured-vs-modeled calibration workflow over the executors' profiled
    path (``src/repro/obs/report.py``; the fit itself is
    ``repro.core.perfmodel.calibrate``).
  * ``python -m repro.obs report`` — CLI printing per-layer deltas for any
    frontend-resolvable model.
"""

from repro.obs.trace import (PHASE_BUCKETS_US, RequestTrace, Span,
                             TRACE_HEADER, TraceConfig, Tracer, new_trace_id,
                             status_for_exception, valid_trace_id)
from repro.obs.report import fidelity_report, format_report, profile_layers

__all__ = [
    "PHASE_BUCKETS_US", "RequestTrace", "Span", "TRACE_HEADER",
    "TraceConfig", "Tracer", "new_trace_id", "status_for_exception",
    "valid_trace_id",
    "fidelity_report", "format_report", "profile_layers",
]
