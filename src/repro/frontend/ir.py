"""Format-independent frontend IR: what every importer parses *into*.

``FrontendGraph`` is the one common op graph all importers target (the
ngraph multi-frontend shape: caffe2/tf/onnx each parse to a single IR, then
shared passes lower it).  It is deliberately closer to ONNX than to the
engine: nodes are SSA (each tensor has exactly one producer), parameters are
``initializers`` (named constant arrays), and ops keep their frontend
attributes.  The pass pipeline (``repro.frontend.passes``) normalises this
graph — folding BatchNorm, fusing activations, legalising layout — until it
contains only ops ``repro.frontend.lower`` can map onto
``repro.core.graph.NetGraph`` layers.

Canonical op vocabulary (ONNX spelling; the JSON importer emits the same):

    Conv Gemm MatMul Relu MaxPool AveragePool GlobalAveragePool Add Mul Div
    Flatten Reshape BatchNormalization Concat Identity Dropout Constant
    Softmax

Only the subset in ``lower.LOWERABLE_OPS`` survives to lowering; everything
else must be eliminated by a pass or rejected by the partitioner with an
:class:`UnsupportedOpError` — never a silent fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np


class FrontendError(ValueError):
    """Base class for importer/pass failures (malformed model, bad shapes)."""


class UnsupportedOpError(FrontendError):
    """An op the engine cannot execute survived the pass pipeline.

    Raised at *import time* (by the partitioner, or eagerly by a pass/
    importer that can already prove an op can never lower), naming the op,
    the node carrying it, and the supported set — so an unseen model fails
    with an actionable message instead of deep inside tracegen/VP.
    """

    def __init__(self, op: str, node: str, supported: Iterable[str],
                 detail: str = ""):
        self.op = op
        self.node = node
        self.supported = tuple(sorted(supported))
        msg = (f"unsupported op {op!r} (node {node!r})"
               f"{': ' + detail if detail else ''}; "
               f"supported ops after the pass pipeline: "
               f"{', '.join(self.supported)}")
        super().__init__(msg)


@dataclasses.dataclass
class FrontendNode:
    """One op application.  All supported ops are single-output."""
    name: str
    op: str
    inputs: List[str]                  # tensor names (activations or initializers)
    outputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def output(self) -> str:
        if len(self.outputs) != 1:
            raise FrontendError(
                f"node {self.name!r} ({self.op}) has {len(self.outputs)} "
                f"outputs; only single-output nodes are supported")
        return self.outputs[0]


@dataclasses.dataclass
class FrontendGraph:
    """SSA op graph + initializers, the importers' common product.

    ``inputs`` holds the graph's activation inputs as ``(name, (C, H, W))``
    — the engine is single-image, so the importer strips/validates the ONNX
    batch dimension before building this.  ``shapes`` is filled by the
    shape-inference pass (tensor name -> tuple; 3-tuples are (C, H, W)
    feature maps, 1-tuples are flattened vectors).
    """
    name: str
    nodes: List[FrontendNode] = dataclasses.field(default_factory=list)
    initializers: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    inputs: List[Tuple[str, Tuple[int, ...]]] = dataclasses.field(default_factory=list)
    outputs: List[str] = dataclasses.field(default_factory=list)
    source_format: str = ""
    source_digest: str = ""            # sha256 of the imported file's bytes
    shapes: Dict[str, Tuple[int, ...]] = dataclasses.field(default_factory=dict)

    # -- topology helpers ----------------------------------------------------
    def producer(self, tensor: str) -> Optional[FrontendNode]:
        for n in self.nodes:
            if tensor in n.outputs:
                return n
        return None

    def consumers(self, tensor: str) -> List[FrontendNode]:
        return [n for n in self.nodes if tensor in n.inputs]

    def is_initializer(self, tensor: str) -> bool:
        return tensor in self.initializers

    def is_graph_input(self, tensor: str) -> bool:
        return any(tensor == name for name, _ in self.inputs)

    def remove_node(self, node: FrontendNode) -> None:
        self.nodes.remove(node)

    def node_label(self, node: FrontendNode) -> str:
        """Stable human-readable handle (ONNX node names may be empty)."""
        return node.name or (node.outputs[0] if node.outputs else "<unnamed>")

    # -- structural validation ----------------------------------------------
    def check_ssa(self) -> "FrontendGraph":
        """Every tensor defined exactly once, before use; outputs resolved."""
        defined = {name for name, _ in self.inputs} | set(self.initializers)
        for n in self.nodes:
            for t in n.inputs:
                if t and t not in defined:
                    raise FrontendError(
                        f"{self.name}: node {self.node_label(n)!r} ({n.op}) "
                        f"reads undefined tensor {t!r} (dangling reference "
                        f"or use-before-def)")
            for t in n.outputs:
                if t in defined:
                    raise FrontendError(
                        f"{self.name}: tensor {t!r} defined more than once "
                        f"(node {self.node_label(n)!r})")
                defined.add(t)
        for t in self.outputs:
            if t not in defined:
                raise FrontendError(
                    f"{self.name}: graph output {t!r} is never produced")
        return self
