"""repro.frontend — import external model formats into the compiler.

The missing quadrant of the toolflow: every net used to enter as a
hand-written ``NetGraph`` builder, so the system could only compile models
someone had already transliterated into Python.  This package makes *unseen*
models first-class:

    importer (onnx | json)          per-format parser -> FrontendGraph
        |
    pass pipeline                   canonicalize, shape inference, BN/scale
        |                           folding, ReLU fusion, layout
        v                           legalization, unsupported-op partitioner
    lower                           FrontendGraph -> NetGraph + params
        |
    CompilerPipeline                unchanged: calibrate -> loadable -> VP
                                    -> trace/weights/asm

Entry point::

    from repro import frontend
    m = frontend.load("model.onnx")            # format sniffed; or format=
    arts = CompilerPipeline(m.graph, params=m.params).run()

Importers are registered by format name and implement the ``Importer``
protocol (``format``, ``suffixes``, ``parse(data, name) -> FrontendGraph``);
``register_importer`` lets external code plug in new formats.  Everything a
format importer produces funnels through the *same* pass pipeline and
lowering, so a new format costs one parser, not a new compiler.

Unsupported models fail at import time with :class:`UnsupportedOpError`
naming the op, node and supported set — never a silent fallback, never an
error deep inside tracegen.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pathlib
from typing import Dict, Iterable, Optional, Protocol, Tuple, Union

import numpy as np

from repro.core.graph import NetGraph
from repro.frontend.ir import (FrontendError, FrontendGraph, FrontendNode,
                               UnsupportedOpError)
from repro.frontend.json_importer import JsonImporter
from repro.frontend.lower import lower
from repro.frontend.onnx_importer import OnnxImporter
from repro.frontend.passes import (DEFAULT_PIPELINE, LOWERABLE_OPS, PASSES,
                                   run_pass, run_pipeline)


class Importer(Protocol):
    """What a format plugin implements (see ``register_importer``)."""
    format: str                        # registry key, e.g. "onnx"
    suffixes: Tuple[str, ...]          # file suffixes this format sniffs to

    def parse(self, data: bytes, name: str = "") -> FrontendGraph: ...


IMPORTERS: Dict[str, Importer] = {}


def register_importer(importer: Importer) -> Importer:
    """Register (or replace) the importer for ``importer.format``."""
    IMPORTERS[importer.format] = importer
    return importer


register_importer(OnnxImporter())
register_importer(JsonImporter())


@dataclasses.dataclass
class ImportedModel:
    """``load``'s product: everything CompilerPipeline and serving need.

    ``graph``/``params`` drop straight into
    ``CompilerPipeline(graph, params=params)``; ``frontend_graph`` is the
    post-pipeline IR kept for inspection (shapes, folded initializers).
    """
    graph: NetGraph
    params: Dict[str, Dict[str, np.ndarray]]
    frontend_graph: FrontendGraph
    source_format: str
    source_digest: str
    source_path: str = ""


def _sniff(path: pathlib.Path, data: bytes) -> str:
    """Pick an importer format for a file (suffix first, then content)."""
    for imp in IMPORTERS.values():
        if path.suffix.lower() in imp.suffixes:
            return imp.format
    head = data.lstrip()[:1]
    if head in (b"{", b"["):
        return "json"
    if data[:1] == b"\x08":            # ModelProto field 1 (ir_version) varint
        return "onnx"
    raise FrontendError(
        f"cannot sniff model format of {path.name!r} (suffix "
        f"{path.suffix!r}); pass format= explicitly — registered formats: "
        f"{', '.join(IMPORTERS)}")


def parse(path: Union[str, pathlib.Path], format: Optional[str] = None
          ) -> FrontendGraph:
    """Parse a model file to a raw (pre-pass) :class:`FrontendGraph`."""
    path = pathlib.Path(path)
    if not path.is_file():
        raise FrontendError(f"model file not found: {path}")
    data = path.read_bytes()
    fmt = format or _sniff(path, data)
    if fmt not in IMPORTERS:
        raise FrontendError(f"no importer registered for format {fmt!r}; "
                            f"registered formats: {', '.join(IMPORTERS)}")
    g = IMPORTERS[fmt].parse(data, name=path.stem)
    g.source_format = fmt
    g.source_digest = hashlib.sha256(data).hexdigest()
    return g


def load(path: Union[str, pathlib.Path], format: Optional[str] = None,
         passes: Optional[Iterable[str]] = None) -> ImportedModel:
    """Import a model file end-to-end: parse -> pass pipeline -> lower.

    ``format`` forces an importer (default: sniff by suffix, then content);
    ``passes`` overrides the default pass list (mostly for tests — the
    default pipeline is what serving and the CLI run).
    """
    fg = parse(path, format=format)
    fg = run_pipeline(fg, passes)
    graph, params = lower(fg)
    return ImportedModel(graph=graph, params=params, frontend_graph=fg,
                         source_format=fg.source_format,
                         source_digest=fg.source_digest,
                         source_path=str(path))


__all__ = ["Importer", "ImportedModel", "IMPORTERS", "register_importer",
           "parse", "load", "FrontendGraph", "FrontendNode", "FrontendError",
           "UnsupportedOpError", "OnnxImporter", "JsonImporter", "lower",
           "PASSES", "DEFAULT_PIPELINE", "LOWERABLE_OPS", "run_pass",
           "run_pipeline"]
