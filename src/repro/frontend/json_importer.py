"""Declarative JSON importer: layer-list capture -> :class:`FrontendGraph`.

The ``repro-net-v1`` format mirrors the hand-written ``NetGraph`` builders —
a topologically ordered layer list — so tests and users can author nets
without touching protobuf (and without writing Python).  It still parses
into the *same* ``FrontendGraph`` the ONNX importer produces and runs the
same pass pipeline, so BatchNorm folding, ReLU fusion and the partitioner
are exercised identically on both paths.

    {
      "format": "repro-net-v1",
      "name": "tinynet",
      "input_shape": [3, 16, 16],
      "seed": 7,                              // He-init any missing weights
      "layers": [
        {"name": "conv1", "type": "conv", "inputs": ["data"],
         "out_channels": 8, "kernel": 3, "stride": 1, "pad": 1,
         "relu": true},
        {"name": "bn1",   "type": "batchnorm", "inputs": ["conv1"]},
        {"name": "pool1", "type": "pool", "inputs": ["bn1"],
         "kernel": 2, "stride": 2, "mode": "max"},
        {"name": "fc1",   "type": "fc", "inputs": ["pool1"],
         "out_channels": 10}
      ],
      "weights": {                            // optional, else seeded
        "conv1": {"w": {"shape": [8,3,3,3], "dtype": "float32",
                        "b64": "..."}}
      }
    }

Layer types: ``conv fc pool add concat batchnorm relu flatten`` (``relu``
may also ride as a flag on conv/fc/add, exactly like the builders — the
importer then emits a separate Relu node for the fusion pass to fold back).
Weights are base64-encoded little-endian arrays; anything absent is
He-initialised from ``seed`` + the layer name, so a fixture can be a few
hundred bytes of JSON yet fully determine the compiled bundle.
"""

from __future__ import annotations

import base64
import hashlib
import json
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.frontend.ir import (FrontendError, FrontendGraph, FrontendNode,
                               UnsupportedOpError)

FORMAT_ID = "repro-net-v1"

_POOL_OPS = {"max": "MaxPool", "avg": "AveragePool", "gap": "GlobalAveragePool"}
# declarative layer type -> canonical op (conv/fc/pool handled specially)
_SIMPLE_OPS = {"add": "Add", "concat": "Concat",
               "batchnorm": "BatchNormalization", "relu": "Relu",
               "flatten": "Flatten"}
LAYER_TYPES = ("conv", "fc", "pool", "add", "concat", "batchnorm", "relu",
               "flatten")


def _b64_array(spec: Dict[str, Any], where: str) -> np.ndarray:
    for key in ("shape", "b64"):
        if key not in spec:
            raise FrontendError(f"{where}: weight spec missing {key!r} "
                                f"(need shape/dtype/b64)")
    dt = np.dtype(spec.get("dtype", "float32"))
    raw = base64.b64decode(spec["b64"])
    a = np.frombuffer(raw, dtype=dt.newbyteorder("<")).astype(dt)
    shape = tuple(int(d) for d in spec["shape"])
    if a.size != int(np.prod(shape)):
        raise FrontendError(f"{where}: b64 payload has {a.size} elements, "
                            f"shape {shape} needs {int(np.prod(shape))}")
    return a.reshape(shape)


def encode_array(a: np.ndarray) -> Dict[str, Any]:
    """Inverse of the b64 weight spec (fixture generation helper)."""
    return {"shape": list(a.shape), "dtype": str(a.dtype),
            "b64": base64.b64encode(np.ascontiguousarray(a).tobytes()).decode()}


def _seeded(seed: int, name: str, kind: str) -> np.random.Generator:
    # stable per-tensor stream: independent of layer order, reproducible
    h = hashlib.sha256(f"{seed}:{name}:{kind}".encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


class JsonImporter:
    """``Importer`` protocol implementation for ``repro-net-v1`` JSON."""

    format = "json"
    suffixes = (".json",)

    def parse(self, data: bytes, name: str = "") -> FrontendGraph:
        try:
            doc = json.loads(data.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise FrontendError(f"{name or 'model'}: not valid JSON ({e})") \
                from None
        if not isinstance(doc, dict) or doc.get("format") != FORMAT_ID:
            raise FrontendError(
                f"{name or 'model'}: expected a {FORMAT_ID!r} document "
                f"(got format={doc.get('format') if isinstance(doc, dict) else type(doc).__name__!r})")
        for key in ("name", "input_shape", "layers"):
            if key not in doc:
                raise FrontendError(f"{doc.get('name', name)}: missing "
                                    f"required key {key!r}")
        net = str(doc["name"])
        input_shape = tuple(int(d) for d in doc["input_shape"])
        if len(input_shape) != 3 or min(input_shape) < 1:
            raise FrontendError(f"{net}: input_shape must be (C, H, W) "
                                f"positive ints, got {doc['input_shape']}")
        seed = int(doc.get("seed", 0))
        weights = doc.get("weights", {})

        g = FrontendGraph(name=net, source_format="json",
                          source_digest=hashlib.sha256(data).hexdigest())
        g.inputs.append(("data", input_shape))
        shapes: Dict[str, Tuple[int, ...]] = {"data": input_shape}

        for i, spec in enumerate(doc["layers"]):
            where = f"{net}: layers[{i}]"
            lname = spec.get("name")
            ltype = spec.get("type")
            if not lname or not ltype:
                raise FrontendError(f"{where}: every layer needs "
                                    f"'name' and 'type'")
            inputs = list(spec.get("inputs", []))
            if not inputs:
                raise FrontendError(f"{where} ({lname!r}): no inputs listed")
            if ltype not in LAYER_TYPES:
                raise UnsupportedOpError(ltype, lname, LAYER_TYPES,
                                         detail="unknown declarative layer "
                                                "type")
            for t in inputs:
                if t not in shapes:
                    raise FrontendError(
                        f"{where} ({lname!r}): input {t!r} is not 'data' or "
                        f"an earlier layer (defined so far: "
                        f"{sorted(shapes)})")
            out = self._emit(g, where, lname, ltype, spec, inputs, shapes,
                             weights.get(lname, {}), seed)
            shapes[lname] = out
        g.outputs.append(doc["layers"][-1]["name"])
        return g.check_ssa()

    # -- per-layer emission --------------------------------------------------
    def _emit(self, g: FrontendGraph, where: str, lname: str, ltype: str,
              spec: Dict, inputs: List[str], shapes: Dict[str, tuple],
              w_spec: Dict, seed: int) -> Tuple[int, ...]:
        """Append FrontendNodes for one declarative layer; return out shape.

        The local shape propagation here only sizes seeded weights — the
        authoritative shape checking happens in the shared shape-inference
        pass, like every other frontend.
        """
        relu = bool(spec.get("relu", False))
        out_t = lname if not relu else f"{lname}__preact"

        def flat(shape):
            return int(np.prod(shape))

        if ltype == "conv":
            cin = shapes[inputs[0]][0] if len(shapes[inputs[0]]) == 3 else None
            if cin is None:
                raise FrontendError(f"{where} ({lname!r}): conv input must "
                                    f"be a (C,H,W) feature map")
            k = int(spec.get("kernel", 1))
            cout = int(spec.get("out_channels", 0))
            groups = int(spec.get("groups", 1))
            if groups < 1 or cin % groups:
                raise FrontendError(f"{where} ({lname!r}): groups={groups} "
                                    f"does not divide in_channels={cin}")
            if "w" in w_spec:
                w = _b64_array(w_spec["w"], f"{where} ({lname!r}) w")
                cout = cout or int(w.shape[0])
            else:
                fan_in = (cin // groups) * k * k
                w = _seeded(seed, lname, "w").normal(
                    0, np.sqrt(2.0 / fan_in),
                    (cout, cin // groups, k, k)).astype(np.float32)
            if "b" in w_spec:
                b = _b64_array(w_spec["b"], f"{where} ({lname!r}) b")
            else:
                b = _seeded(seed, lname, "b").normal(
                    0, 0.05, (cout,)).astype(np.float32)
            g.initializers[f"{lname}.w"] = w
            g.initializers[f"{lname}.b"] = b
            stride, pad = int(spec.get("stride", 1)), int(spec.get("pad", 0))
            g.nodes.append(FrontendNode(
                name=lname, op="Conv", inputs=[inputs[0], f"{lname}.w",
                                               f"{lname}.b"],
                outputs=[out_t],
                attrs={"kernel_shape": [k, k], "strides": [stride, stride],
                       "pads": [pad, pad, pad, pad], "group": groups,
                       "dilations": [1, 1]}))
            c, h, w_ = shapes[inputs[0]]
            p = (h + 2 * pad - k) // stride + 1
            q = (w_ + 2 * pad - k) // stride + 1
            out_shape = (cout, p, q)
        elif ltype == "fc":
            cin = flat(shapes[inputs[0]])
            cout = int(spec.get("out_channels", 0))
            if "w" in w_spec:
                w = _b64_array(w_spec["w"], f"{where} ({lname!r}) w")
                cout = cout or int(w.shape[0])
            else:
                w = _seeded(seed, lname, "w").normal(
                    0, np.sqrt(2.0 / cin), (cout, cin)).astype(np.float32)
            if "b" in w_spec:
                b = _b64_array(w_spec["b"], f"{where} ({lname!r}) b")
            else:
                b = _seeded(seed, lname, "b").normal(
                    0, 0.05, (cout,)).astype(np.float32)
            g.initializers[f"{lname}.w"] = w
            g.initializers[f"{lname}.b"] = b
            g.nodes.append(FrontendNode(
                name=lname, op="Gemm",
                inputs=[inputs[0], f"{lname}.w", f"{lname}.b"],
                outputs=[out_t],
                attrs={"alpha": 1.0, "beta": 1.0, "transB": 1}))
            out_shape = (cout,)
        elif ltype == "pool":
            mode = spec.get("mode", spec.get("pool_mode", ""))
            if mode not in _POOL_OPS:
                raise FrontendError(f"{where} ({lname!r}): pool mode must be "
                                    f"one of {sorted(_POOL_OPS)}, got "
                                    f"{mode!r}")
            attrs: Dict[str, Any] = {}
            c, h, w_ = shapes[inputs[0]]
            if mode == "gap":
                out_shape = (c, 1, 1)
            else:
                k = int(spec.get("kernel", 1))
                stride = int(spec.get("stride", 1))
                pad = int(spec.get("pad", 0))
                attrs = {"kernel_shape": [k, k], "strides": [stride, stride],
                         "pads": [pad, pad, pad, pad]}
                out_shape = (c, (h + 2 * pad - k) // stride + 1,
                             (w_ + 2 * pad - k) // stride + 1)
            g.nodes.append(FrontendNode(name=lname, op=_POOL_OPS[mode],
                                        inputs=[inputs[0]], outputs=[out_t],
                                        attrs=attrs))
        elif ltype == "add":
            g.nodes.append(FrontendNode(name=lname, op="Add", inputs=inputs,
                                        outputs=[out_t]))
            out_shape = shapes[inputs[0]]
        elif ltype == "concat":
            g.nodes.append(FrontendNode(name=lname, op="Concat",
                                        inputs=inputs, outputs=[out_t],
                                        attrs={"axis": 1}))
            cs = [shapes[t] for t in inputs]
            out_shape = (sum(c[0] for c in cs),) + cs[0][1:]
        elif ltype == "batchnorm":
            c = shapes[inputs[0]][0]
            names = ("gamma", "beta", "mean", "var")
            vals = {}
            for kind in names:
                if kind in w_spec:
                    vals[kind] = _b64_array(w_spec[kind],
                                            f"{where} ({lname!r}) {kind}")
                elif kind in ("gamma", "var"):
                    vals[kind] = _seeded(seed, lname, kind).uniform(
                        0.5, 1.5, (c,)).astype(np.float32)
                else:
                    vals[kind] = _seeded(seed, lname, kind).normal(
                        0, 0.1, (c,)).astype(np.float32)
            for kind in names:
                g.initializers[f"{lname}.{kind}"] = vals[kind]
            g.nodes.append(FrontendNode(
                name=lname, op="BatchNormalization",
                inputs=[inputs[0]] + [f"{lname}.{k}" for k in names],
                outputs=[out_t],
                attrs={"epsilon": float(spec.get("epsilon", 1e-5))}))
            out_shape = shapes[inputs[0]]
        elif ltype == "relu":
            g.nodes.append(FrontendNode(name=lname, op="Relu",
                                        inputs=[inputs[0]], outputs=[out_t]))
            out_shape = shapes[inputs[0]]
        else:                                  # flatten
            g.nodes.append(FrontendNode(name=lname, op="Flatten",
                                        inputs=[inputs[0]], outputs=[out_t],
                                        attrs={"axis": 1}))
            out_shape = (flat(shapes[inputs[0]]),)

        if relu:
            if ltype not in ("conv", "fc", "add"):
                raise FrontendError(f"{where} ({lname!r}): 'relu' flag is "
                                    f"only meaningful on conv/fc/add")
            g.nodes.append(FrontendNode(name=f"{lname}_relu", op="Relu",
                                        inputs=[out_t], outputs=[lname]))
        return out_shape
