"""Staged compiler passes over :class:`FrontendGraph` (ngraph-style).

Each pass is a plain function ``(FrontendGraph) -> FrontendGraph`` registered
by name, individually invocable (``run_pass(g, "fold_batchnorm")``) and
individually unit-tested.  ``run_pipeline`` runs the default staged order:

    canonicalize      Constant->initializer, drop Identity/Dropout/trailing
                      Softmax, MatMul->Gemm
    infer_shapes      shape inference + validation over every tensor
    fold_constants    evaluate nodes whose inputs are all initializers
    fold_batchnorm    BatchNormalization after Conv/Gemm -> folded w/b
    fold_scales       constant Add/Mul/Div after Conv/Gemm -> folded into
                      bias / per-channel weight scales (requant-scale folding
                      — folded scales flow into the per-channel int8 weight
                      quantisation instead of costing an EW pass)
    fuse_relu         Relu after Conv/Gemm/Add -> fused_relu tag (the SDP
                      epilogue executes it for free)
    legalize_layout   NCHW legalization: full-flatten Flatten/Reshape removal,
                      Gemm transB/alpha/beta normalisation, zero-bias
                      materialisation
    infer_shapes      re-validate after graph surgery
    partition         unsupported-op partitioner: raises UnsupportedOpError
                      naming the op, its node and the supported set

A pass list is data, not policy: callers may run any subset in any order —
every pass re-establishes its own preconditions or fails descriptively.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional

from repro.frontend.ir import FrontendGraph
from repro.frontend.passes.canonicalize import canonicalize
from repro.frontend.passes.shapes import infer_shapes
from repro.frontend.passes.fold import (fold_batchnorm, fold_constants,
                                        fold_scales)
from repro.frontend.passes.fuse import fuse_relu
from repro.frontend.passes.layout import legalize_layout
from repro.frontend.passes.partition import LOWERABLE_OPS, partition

PASSES: Dict[str, Callable[[FrontendGraph], FrontendGraph]] = {
    "canonicalize": canonicalize,
    "infer_shapes": infer_shapes,
    "fold_constants": fold_constants,
    "fold_batchnorm": fold_batchnorm,
    "fold_scales": fold_scales,
    "fuse_relu": fuse_relu,
    "legalize_layout": legalize_layout,
    "partition": partition,
}

DEFAULT_PIPELINE = ("canonicalize", "infer_shapes", "fold_constants",
                    "fold_batchnorm", "fold_scales", "fuse_relu",
                    "legalize_layout", "infer_shapes", "partition")


def run_pass(g: FrontendGraph, name: str) -> FrontendGraph:
    """Run one pass by name (unknown names raise, listing the registry)."""
    if name not in PASSES:
        raise ValueError(f"unknown pass {name!r}; registered passes: "
                         f"{', '.join(PASSES)}")
    return PASSES[name](g)


def run_pipeline(g: FrontendGraph,
                 names: Optional[Iterable[str]] = None) -> FrontendGraph:
    """Run a pass list in order (default: ``DEFAULT_PIPELINE``)."""
    for name in (DEFAULT_PIPELINE if names is None else names):
        g = run_pass(g, name)
    return g


__all__ = ["PASSES", "DEFAULT_PIPELINE", "LOWERABLE_OPS", "run_pass",
           "run_pipeline", "canonicalize", "infer_shapes", "fold_constants",
           "fold_batchnorm", "fold_scales", "fuse_relu", "legalize_layout",
           "partition"]
