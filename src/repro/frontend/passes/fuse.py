"""ReLU fusion tagging: Relu after Conv/Gemm/Add -> ``fused_relu`` attr.

NVDLA executes activation in the SDP epilogue of the producing op (the
engine's ``Descriptor.relu`` flag), so a standalone Relu node is free — *if*
it immediately follows a Conv/Gemm/Add that nothing else reads pre-
activation.  This pass tags such producers and deletes the Relu node;
any Relu it cannot fuse survives to the partitioner, which rejects it with
an explanation rather than silently emitting an op the engine lacks.
"""

from __future__ import annotations

from repro.frontend.ir import FrontendGraph
from repro.frontend.passes.canonicalize import rewire

FUSABLE = ("Conv", "Gemm", "Add")


def fuse_relu(g: FrontendGraph) -> FrontendGraph:
    for node in list(g.nodes):
        if node.op != "Relu":
            continue
        src = node.inputs[0]
        prod = g.producer(src)
        if prod is None or prod.op not in FUSABLE:
            continue
        if src in g.outputs or len(g.consumers(src)) != 1:
            continue                      # someone reads the pre-activation
        # relu is idempotent: a second Relu over an already-tagged producer
        # folds away too
        prod.attrs["fused_relu"] = True
        rewire(g, node.output, prod.output)
        g.remove_node(node)
    return g
