"""Shape inference + validation over every tensor in a FrontendGraph.

Fills ``g.shapes`` (tensor name -> tuple): feature maps are (C, H, W)
3-tuples — the engine's single-image layout, the ONNX batch dim having been
stripped by the importer — flattened vectors are 1-tuples.  Every mismatch
raises a descriptive :class:`FrontendError` naming the node, so a malformed
model fails here instead of deep inside tracegen/VP.

Ops the vocabulary doesn't know get best-effort passthrough (first input's
shape) so that the *partitioner* — not this pass — owns the unsupported-op
error message.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.frontend.ir import FrontendError, FrontendGraph, FrontendNode


def _err(g: FrontendGraph, n: FrontendNode, msg: str) -> FrontendError:
    return FrontendError(f"{g.name}: {n.op} node {g.node_label(n)!r}: {msg}")


def _feature(g, n, shape, what) -> Tuple[int, int, int]:
    if len(shape) != 3:
        raise _err(g, n, f"{what} must be a (C, H, W) feature map, got "
                         f"shape {tuple(shape)}")
    return shape


def _pool_out(g, n, shape, attrs) -> Tuple[int, int, int]:
    c, h, w = _feature(g, n, shape, "input")
    ks = attrs.get("kernel_shape")
    if not ks or len(ks) != 2:
        raise _err(g, n, f"kernel_shape must be 2-D, got {ks!r}")
    st = attrs.get("strides", [1, 1])
    pt, pl, pb, pr = attrs.get("pads", [0, 0, 0, 0])
    p = (h + pt + pb - ks[0]) // st[0] + 1
    q = (w + pl + pr - ks[1]) // st[1] + 1
    if p < 1 or q < 1:
        raise _err(g, n, f"kernel {ks} stride {st} pads {[pt, pl, pb, pr]} "
                         f"produce empty output ({p}x{q}) on {h}x{w} input")
    return c, p, q


def infer_shapes(g: FrontendGraph) -> FrontendGraph:
    g.check_ssa()
    shapes = {name: tuple(arr.shape) for name, arr in g.initializers.items()}
    for name, shape in g.inputs:
        shapes[name] = tuple(shape)

    for n in g.nodes:
        ins = [t for t in n.inputs if t]
        for t in ins:
            if t not in shapes:
                raise _err(g, n, f"input tensor {t!r} has no shape "
                                 f"(produced by an unshaped node?)")
        a = n.attrs
        if n.op == "Conv":
            c, h, w = _feature(g, n, shapes[ins[0]], "input")
            if not g.is_initializer(ins[1]):
                raise _err(g, n, f"weight {ins[1]!r} must be a constant "
                                 f"initializer (dynamic weights cannot be "
                                 f"preloaded into the DRAM image)")
            wshape = shapes[ins[1]]
            if len(wshape) != 4:
                raise _err(g, n, f"weight must be (K, C/g, R, S), got "
                                 f"{wshape}")
            k_out, cin_g, r, s = wshape
            group = a.get("group", 1)
            if cin_g * group != c:
                raise _err(g, n, f"weight expects {cin_g * group} input "
                                 f"channels (C/g={cin_g} x group={group}), "
                                 f"input has {c}")
            ks = a.get("kernel_shape", [r, s])
            if tuple(ks) != (r, s):
                raise _err(g, n, f"kernel_shape {ks} disagrees with weight "
                                 f"spatial dims ({r}, {s})")
            if len(ins) > 2 and shapes[ins[2]] not in ((k_out,), (1, k_out)):
                raise _err(g, n, f"bias shape {shapes[ins[2]]} != ({k_out},)")
            st = a.get("strides", [1, 1])
            pt, pl, pb, pr = a.get("pads", [0, 0, 0, 0])
            p = (h + pt + pb - r) // st[0] + 1
            q = (w + pl + pr - s) // st[1] + 1
            if p < 1 or q < 1:
                raise _err(g, n, f"kernel ({r},{s}) stride {st} pads "
                                 f"{[pt, pl, pb, pr]} produce empty output "
                                 f"on {h}x{w} input")
            out = (k_out, p, q)
        elif n.op == "Gemm":
            f_in = int(np.prod(shapes[ins[0]]))
            if not g.is_initializer(ins[1]):
                raise _err(g, n, f"weight {ins[1]!r} must be a constant "
                                 f"initializer")
            wshape = shapes[ins[1]]
            if len(wshape) != 2:
                raise _err(g, n, f"weight must be 2-D, got {wshape}")
            if a.get("transA", 0):
                raise _err(g, n, "transA=1 is not supported (activations "
                                 "are vectors)")
            k_out, f_w = (wshape if a.get("transB", 0) else wshape[::-1])
            if f_w != f_in:
                raise _err(g, n, f"weight contracts over {f_w} features, "
                                 f"input {ins[0]!r} flattens to {f_in} "
                                 f"(shape {shapes[ins[0]]})")
            if len(ins) > 2:
                bshape = shapes[ins[2]]
                if bshape not in ((k_out,), (1, k_out)):
                    raise _err(g, n, f"bias shape {bshape} != ({k_out},)")
            out = (k_out,)
        elif n.op == "MatMul":
            # pre-canonicalize form; same contract as Gemm transB=0
            f_in = int(np.prod(shapes[ins[0]]))
            wshape = shapes[ins[1]]
            if len(wshape) != 2 or wshape[0] != f_in:
                raise _err(g, n, f"operand shapes {shapes[ins[0]]} x "
                                 f"{wshape} do not contract")
            out = (wshape[1],)
        elif n.op in ("MaxPool", "AveragePool"):
            out = _pool_out(g, n, shapes[ins[0]], a)
        elif n.op == "GlobalAveragePool":
            c, _, _ = _feature(g, n, shapes[ins[0]], "input")
            out = (c, 1, 1)
        elif n.op == "Add":
            s0, s1 = shapes[ins[0]], shapes[ins[1]]
            if s0 != s1:
                # constant bias broadcast (folded away later) is tolerated
                n_init = sum(g.is_initializer(t) for t in ins[:2])
                squeeze = tuple(d for d in s1 if d != 1)
                if not (n_init == 1 and (squeeze == (s0[0],) or squeeze == ()
                                         or squeeze == tuple(
                                             d for d in s0 if d != 1))):
                    raise _err(g, n, f"operand shapes differ: {s0} vs {s1} "
                                     f"(residual adds need identical "
                                     f"shapes)")
            out = s0 if not g.is_initializer(ins[0]) else s1
        elif n.op in ("Mul", "Div"):
            s0, s1 = shapes[ins[0]], shapes[ins[1]]
            act = s1 if g.is_initializer(ins[0]) else s0
            out = act
        elif n.op == "BatchNormalization":
            c = _feature(g, n, shapes[ins[0]], "input")[0]
            for t in ins[1:5]:
                if tuple(d for d in shapes[t] if d != 1) != (c,):
                    raise _err(g, n, f"parameter {t!r} has shape "
                                     f"{shapes[t]}, expected ({c},) to "
                                     f"match {c} channels")
            out = shapes[ins[0]]
        elif n.op == "Relu":
            out = shapes[ins[0]]
        elif n.op == "Flatten":
            out = (int(np.prod(shapes[ins[0]])),)
        elif n.op == "Reshape":
            total = int(np.prod(shapes[ins[0]]))
            if len(ins) > 1:
                if not g.is_initializer(ins[1]):
                    raise _err(g, n, f"shape operand {ins[1]!r} must be "
                                     f"constant")
                target = [int(d) for d in g.initializers[ins[1]].ravel()]
                if len(target) > 1 and target[0] == 1:
                    target = target[1:]    # strip the batch dim, like inputs
                if target.count(-1) > 1:
                    raise _err(g, n, f"reshape target {target} has more "
                                     f"than one -1")
                known = int(np.prod([d for d in target if d != -1])) or 1
                if -1 in target:
                    if total % known:
                        raise _err(g, n, f"reshape to {target} incompatible "
                                         f"with {total} elements")
                    target = [total // known if d == -1 else d
                              for d in target]
                if int(np.prod(target)) != total:
                    raise _err(g, n, f"reshape to {target} incompatible "
                                     f"with {total} elements")
                out = tuple(target)
            else:
                out = (total,)
        elif n.op == "Concat":
            axis = a.get("axis", 1)
            if axis not in (0, 1):
                raise _err(g, n, f"only channel concat is supported "
                                 f"(axis 1 in NCHW), got axis={axis}")
            cs = [shapes[t] for t in ins]
            if any(len(c) != 3 for c in cs) or \
                    any(c[1:] != cs[0][1:] for c in cs):
                raise _err(g, n, f"operands must be (C, H, W) maps with "
                                 f"equal spatial dims, got {cs}")
            out = (sum(c[0] for c in cs),) + cs[0][1:]
        elif n.op in ("Identity", "Dropout", "Softmax"):
            out = shapes[ins[0]]
        else:
            # unknown op: best-effort passthrough; the partitioner owns the
            # descriptive rejection
            out = shapes[ins[0]] if ins else ()
        shapes[n.output] = tuple(out)

    g.shapes = shapes
    return g
