"""Folding passes: constants, BatchNorm-into-Conv, scale/bias-into-Conv.

All three rewrite parameters algebraically at import time so the runtime
graph carries only engine ops:

* ``fold_constants`` — a node whose inputs are all initializers is just a
  very slow way to write an array; evaluate it (``refeval``) and promote the
  result to an initializer.
* ``fold_batchnorm`` — inference BatchNorm after Conv/Gemm is an affine
  per-channel map; fold it into the producer's weights and bias
  (``w' = w·γ/√(σ²+ε)``, ``b' = (b−μ)·γ/√(σ²+ε) + β``).  Folding is done in
  float64 and rounded once to float32.
* ``fold_scales`` — constant ``Add`` (bias), ``Mul``/``Div`` (per-channel or
  scalar scales) following Conv/Gemm fold the same way.  For int8 plans this
  is *requant-scale folding*: the folded scale flows into the per-channel
  weight quantisation (``quant.quantize_weights``) and the SDP's fixed-point
  requant words, instead of burning an EW pass at runtime.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.frontend import refeval
from repro.frontend.ir import FrontendError, FrontendGraph, FrontendNode
from repro.frontend.passes.canonicalize import prune_initializers, rewire


def fold_constants(g: FrontendGraph) -> FrontendGraph:
    for node in list(g.nodes):
        ins = [t for t in node.inputs if t]
        if node.op not in refeval._EVAL_OPS:
            continue
        if not ins or not all(g.is_initializer(t) for t in ins):
            continue
        if node.output in g.outputs:
            continue                      # a fully-constant net stays a net
        value = refeval.eval_node(node, [g.initializers[t] for t in ins])
        g.initializers[node.output] = value
        g.remove_node(node)
    prune_initializers(g)
    return g


# ---------------------------------------------------------------------------
# shared: locate the foldable producer of a tensor
# ---------------------------------------------------------------------------
def _foldable_producer(g: FrontendGraph, tensor: str
                       ) -> Optional[FrontendNode]:
    """The Conv/Gemm producing ``tensor``, if folding into it is sound:
    single consumer, not a graph output, constant weights."""
    prod = g.producer(tensor)
    if prod is None or prod.op not in ("Conv", "Gemm"):
        return None
    if tensor in g.outputs or len(g.consumers(tensor)) != 1:
        return None
    if len(prod.inputs) < 2 or not g.is_initializer(prod.inputs[1]):
        return None
    return prod


def _producer_wb(g: FrontendGraph, prod: FrontendNode
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """(w, b) as float64, materialising a zero bias if the op has none."""
    w = np.asarray(g.initializers[prod.inputs[1]], np.float64)
    k_out = w.shape[0] if (prod.op == "Conv" or prod.attrs.get("transB", 0)) \
        else w.shape[1]
    if len(prod.inputs) > 2 and prod.inputs[2]:
        b = np.asarray(g.initializers[prod.inputs[2]], np.float64).reshape(-1)
    else:
        b = np.zeros(k_out, np.float64)
    return w, b


def _store_wb(g: FrontendGraph, prod: FrontendNode, w: np.ndarray,
              b: np.ndarray, tag: str) -> None:
    """Write folded params under fresh names (weights may be shared)."""
    wname, bname = f"{prod.name}.{tag}.w", f"{prod.name}.{tag}.b"
    g.initializers[wname] = w.astype(np.float32)
    g.initializers[bname] = b.astype(np.float32)
    prod.inputs = [prod.inputs[0], wname, bname]


def _scale_weights(prod: FrontendNode, w: np.ndarray,
                   scale: np.ndarray) -> np.ndarray:
    """Apply a per-output-channel scale to Conv/Gemm weights."""
    if prod.op == "Conv" or prod.attrs.get("transB", 0):
        return w * scale.reshape((-1,) + (1,) * (w.ndim - 1))
    return w * scale.reshape(1, -1)       # Gemm transB=0: K on axis 1


def fold_batchnorm(g: FrontendGraph) -> FrontendGraph:
    folded = False
    for node in list(g.nodes):
        if node.op != "BatchNormalization":
            continue
        ins = [t for t in node.inputs if t]
        if len(ins) != 5 or not all(g.is_initializer(t) for t in ins[1:]):
            continue                      # dynamic BN params: partitioner's
        prod = _foldable_producer(g, ins[0])
        if prod is None:
            continue
        gamma, beta, mean, var = (np.asarray(g.initializers[t], np.float64)
                                  .reshape(-1) for t in ins[1:5])
        eps = float(node.attrs.get("epsilon", 1e-5))
        scale = gamma / np.sqrt(var + eps)
        w, b = _producer_wb(g, prod)
        _store_wb(g, prod, _scale_weights(prod, w, scale),
                  (b - mean) * scale + beta, "bnfold")
        rewire(g, node.output, prod.output)
        g.remove_node(node)
        folded = True
    if folded:
        prune_initializers(g)
    return g


def _channel_const(g: FrontendGraph, node: FrontendNode, k_out: int
                   ) -> Optional[np.ndarray]:
    """The constant operand of a binary node, as a (K,) or scalar vector."""
    const = [t for t in node.inputs if g.is_initializer(t)]
    if len(const) != 1:
        return None
    c = np.asarray(g.initializers[const[0]], np.float64)
    if c.size == 1:
        return c.reshape(-1)
    if c.size == k_out and tuple(d for d in c.shape if d != 1) == (k_out,):
        return c.reshape(-1)
    return None                           # not scalar / per-channel broadcast


def fold_scales(g: FrontendGraph) -> FrontendGraph:
    folded = False
    for node in list(g.nodes):
        if node.op not in ("Add", "Mul", "Div"):
            continue
        acts = [t for t in node.inputs if not g.is_initializer(t)]
        if len(acts) != 1:
            continue                      # residual add / constant-constant
        if node.op == "Div" and g.is_initializer(node.inputs[0]):
            continue                      # const / act is not a scale
        prod = _foldable_producer(g, acts[0])
        if prod is None:
            continue
        w, b = _producer_wb(g, prod)
        c = _channel_const(g, node, b.shape[0])
        if c is None:
            continue
        if node.op == "Add":
            b = b + c
        else:
            if node.op == "Div":
                if np.any(c == 0):
                    raise FrontendError(
                        f"{g.name}: Div node {g.node_label(node)!r} divides "
                        f"by a zero constant")
                c = 1.0 / c
            w, b = _scale_weights(prod, w, c), b * c
        _store_wb(g, prod, w, b, "sfold")
        rewire(g, node.output, prod.output)
        g.remove_node(node)
        folded = True
    if folded:
        prune_initializers(g)
    return g
