"""NCHW layout legalization: make every surviving op engine-shaped.

The engine stores activations as single-image (C, H, W) row-major — exactly
ONNX's NCHW with the batch dim stripped — and its FC unit flattens (C, H, W)
row-major implicitly.  So:

* full-flatten ``Flatten``/``Reshape`` nodes are erased (their consumers
  read the unflattened map; row-major order makes this a no-op),
* ``Gemm`` is normalised to the engine's weight layout: ``transB=1``
  (weights (K, F)), ``alpha``/``beta`` folded into w/b, a zero bias
  materialised when absent — so lowering sees exactly one Gemm shape,
* ``Conv`` gets an explicit zero bias and its ``auto_pad`` resolved
  (``VALID`` -> zero pads; ``SAME_*`` is rejected — the engine has a single
  symmetric pad).

Requires shapes (run ``infer_shapes`` first); re-run it afterwards to
re-validate the surgered graph.
"""

from __future__ import annotations

import numpy as np

from repro.frontend.ir import (FrontendError, FrontendGraph,
                               UnsupportedOpError)
from repro.frontend.passes.canonicalize import prune_initializers, rewire
from repro.frontend.passes.partition import LOWERABLE_OPS


def legalize_layout(g: FrontendGraph) -> FrontendGraph:
    if not g.shapes:
        raise FrontendError(f"{g.name}: legalize_layout needs shapes — run "
                            f"the infer_shapes pass first")
    for node in list(g.nodes):
        if node.op in ("Flatten", "Reshape"):
            src = node.inputs[0]
            total = int(np.prod(g.shapes[src]))
            out = g.shapes[node.output]
            if out != (total,):
                raise UnsupportedOpError(
                    node.op, g.node_label(node), LOWERABLE_OPS,
                    detail=f"only full flattens legalise away "
                           f"({g.shapes[src]} -> {out} is a real reshape; "
                           f"the engine has no data-movement op for it)")
            rewire(g, node.output, src)
            g.remove_node(node)
        elif node.op == "Gemm":
            a = node.attrs
            w = np.asarray(g.initializers[node.inputs[1]], np.float64)
            if not a.get("transB", 0):
                w = w.T
            alpha, beta = float(a.get("alpha", 1.0)), float(a.get("beta", 1.0))
            w = w * alpha
            if len(node.inputs) > 2 and node.inputs[2]:
                b = np.asarray(g.initializers[node.inputs[2]],
                               np.float64).reshape(-1) * beta
            else:
                b = np.zeros(w.shape[0], np.float64)
            wname, bname = f"{node.name}.legal.w", f"{node.name}.legal.b"
            g.initializers[wname] = np.ascontiguousarray(w, np.float32)
            g.initializers[bname] = b.astype(np.float32)
            node.inputs = [node.inputs[0], wname, bname]
            node.attrs = {**a, "alpha": 1.0, "beta": 1.0, "transA": 0,
                          "transB": 1}
        elif node.op == "Conv":
            auto = node.attrs.get("auto_pad", "NOTSET")
            if auto == "VALID":
                node.attrs["pads"] = [0, 0, 0, 0]
                node.attrs["auto_pad"] = "NOTSET"
            elif auto not in ("", "NOTSET"):
                raise UnsupportedOpError(
                    "Conv", g.node_label(node), LOWERABLE_OPS,
                    detail=f"auto_pad={auto!r} is not supported — export "
                           f"with explicit symmetric pads")
            if len(node.inputs) < 3 or not node.inputs[2]:
                k_out = g.initializers[node.inputs[1]].shape[0]
                bname = f"{node.name}.legal.b"
                g.initializers[bname] = np.zeros(k_out, np.float32)
                node.inputs = [node.inputs[0], node.inputs[1], bname]
    prune_initializers(g)
    return g
