"""Canonicalization: normalise importer output before any analysis.

* ``Constant`` nodes become initializers (their only purpose).
* ``Identity`` / ``Dropout`` (inference no-ops) are spliced out.
* A *trailing* ``Softmax`` (producing the graph output) is dropped: the
  engine ends pre-softmax like the paper's nets, and argmax is invariant
  under softmax.  A mid-graph Softmax is left for the partitioner to reject.
* ``MatMul`` with a constant right operand becomes a bias-less ``Gemm``
  (transB=0), so every fully-connected layer flows through one op.
"""

from __future__ import annotations

import numpy as np

from repro.frontend.ir import FrontendError, FrontendGraph


def rewire(g: FrontendGraph, old: str, new: str) -> None:
    """Redirect every reader of tensor ``old`` to ``new``."""
    for n in g.nodes:
        n.inputs = [new if t == old else t for t in n.inputs]
    g.outputs = [new if t == old else t for t in g.outputs]


def prune_initializers(g: FrontendGraph) -> None:
    """Drop initializers nothing reads (after folding rewires weights)."""
    used = {t for n in g.nodes for t in n.inputs}
    used.update(g.outputs)
    for name in list(g.initializers):
        if name not in used:
            del g.initializers[name]


def canonicalize(g: FrontendGraph) -> FrontendGraph:
    for node in list(g.nodes):
        if node.op == "Constant":
            value = node.attrs.get("value")
            if not isinstance(value, np.ndarray):
                raise FrontendError(
                    f"{g.name}: Constant node {g.node_label(node)!r} has no "
                    f"tensor 'value' attribute (sparse/typed constants are "
                    f"not supported)")
            g.initializers[node.output] = np.asarray(value)
            g.remove_node(node)
        elif node.op in ("Identity", "Dropout"):
            rewire(g, node.output, node.inputs[0])
            g.remove_node(node)
        elif node.op == "Softmax" and node.output in g.outputs:
            rewire(g, node.output, node.inputs[0])
            g.remove_node(node)
        elif node.op == "MatMul":
            if len(node.inputs) == 2 and g.is_initializer(node.inputs[1]):
                node.op = "Gemm"
                node.attrs = {"alpha": 1.0, "beta": 1.0, "transA": 0,
                              "transB": 0}
    prune_initializers(g)
    return g.check_ssa()
