"""Unsupported-op partitioner: the pipeline's final gate (no silent fallback).

Partitions the node list into maximal runs of engine-lowerable ops.  If the
whole graph is one supported partition, it passes; otherwise it raises
:class:`UnsupportedOpError` naming the first offending op, its node, the
supported set, and the partition summary — at *import time*, never
mid-compile.  Beyond op names it also enforces the engine's per-op
constraints (square kernels, symmetric pads, no dilation), so "Conv the
engine cannot run" fails as loudly as "op the engine has never heard of".
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.frontend.ir import (FrontendGraph, FrontendNode,
                               UnsupportedOpError)

# ops repro.frontend.lower can map onto NetGraph layers
LOWERABLE_OPS = ("Conv", "Gemm", "MaxPool", "AveragePool",
                 "GlobalAveragePool", "Add", "Concat")

_HINTS = {
    "Relu": "could not fuse into a preceding conv/fc/add (the engine only "
            "executes ReLU in the SDP epilogue)",
    "BatchNormalization": "could not fold into a preceding conv/fc "
                          "(inference BN folds only when its input is a "
                          "single-consumer Conv/Gemm with constant "
                          "parameters)",
    "Mul": "constant scales fold into a preceding conv/fc; tensor-tensor "
           "multiply has no engine unit",
    "Div": "constant scales fold into a preceding conv/fc; tensor-tensor "
           "divide has no engine unit",
    "Flatten": "only full flattens legalise away",
    "Reshape": "only full flattens legalise away",
    "Softmax": "only a trailing Softmax is dropped (argmax-invariant); "
               "mid-graph Softmax has no engine unit",
    "MatMul": "lowers only with a constant weight operand",
}


def _constraint(g: FrontendGraph, n: FrontendNode) -> Optional[str]:
    """A human-readable constraint violation for a name-supported op."""
    a = n.attrs
    if n.op == "Conv":
        if any(d != 1 for d in a.get("dilations", [1, 1])):
            return f"dilations={a['dilations']} (the engine has no dilation)"
        pt, pl, pb, pr = a.get("pads", [0, 0, 0, 0])
        if not (pt == pb == pl == pr):
            return (f"asymmetric pads {[pt, pl, pb, pr]} (the engine has "
                    f"one symmetric pad register)")
        ks = a.get("kernel_shape", [1, 1])
        st = a.get("strides", [1, 1])
        if ks[0] != ks[1] or st[0] != st[1]:
            return (f"non-square kernel {ks} / strides {st} (the engine "
                    f"walks square windows)")
    elif n.op == "Gemm":
        if a.get("transA", 0) or not a.get("transB", 0) or \
                float(a.get("alpha", 1.0)) != 1.0 or \
                float(a.get("beta", 1.0)) != 1.0:
            return ("non-normalised Gemm (run the legalize_layout pass: "
                    "transB=1, alpha=beta=1)")
    elif n.op in ("MaxPool", "AveragePool"):
        pt, pl, pb, pr = a.get("pads", [0, 0, 0, 0])
        if not (pt == pb == pl == pr):
            return f"asymmetric pads {[pt, pl, pb, pr]}"
        ks = a.get("kernel_shape", [1, 1])
        st = a.get("strides", [1, 1])
        if ks[0] != ks[1] or st[0] != st[1]:
            return f"non-square kernel {ks} / strides {st}"
        if n.op == "AveragePool" and pt != 0 and \
                not a.get("count_include_pad", 0):
            return ("padded AveragePool with count_include_pad=0 (the "
                    "engine's PDP divides by the full window)")
    elif n.op == "Add":
        init = [t for t in n.inputs if g.is_initializer(t)]
        if init:
            return (f"constant operand {init[0]!r} did not fold (constant "
                    f"adds fold into a preceding conv/fc only)")
    elif n.op == "Concat":
        if a.get("axis", 1) not in (0, 1):
            return f"axis={a['axis']} (only channel concat is free on NVDLA)"
    return None


def partition(g: FrontendGraph) -> FrontendGraph:
    """Validate that the graph is one engine-lowerable partition."""
    bad: List[Tuple[FrontendNode, str]] = []
    for n in g.nodes:
        if n.op not in LOWERABLE_OPS:
            bad.append((n, _HINTS.get(n.op, "no engine unit for this op")))
        else:
            violation = _constraint(g, n)
            if violation is not None:
                bad.append((n, violation))
    if not bad:
        return g

    # partition summary: how the node list splits around unsupported nodes
    bad_set = {id(n) for n, _ in bad}
    segments, run = [], 0
    for n in g.nodes:
        if id(n) in bad_set:
            if run:
                segments.append(run)
            run = 0
        else:
            run += 1
    if run:
        segments.append(run)
    node, why = bad[0]
    others = ", ".join(f"{g.node_label(n)}({n.op})" for n, _ in bad[1:])
    raise UnsupportedOpError(
        node.op, g.node_label(node), LOWERABLE_OPS,
        detail=f"{why}.  Graph partitions into {len(segments)} supported "
               f"segment(s) of {segments or [0]} node(s) around "
               f"{len(bad)} unsupported node(s)"
               + (f" (also: {others})" if others else ""))
