"""resolve_net: one spec grammar for "which model?" across every entry point.

Before the frontend existed, the CLI surfaces (``repro.serve``,
``benchmarks.run``) could only name nets out of ``graph.BUILDERS``.  This
resolver accepts either:

  * a builder name   — ``"lenet5"`` -> ``graph.BUILDERS["lenet5"]()`` with
    ``init_params(seed)`` weights (the historical behaviour), or
  * a model file     — ``"models/net.onnx"`` / ``"net.json"`` ->
    ``repro.frontend.load`` (importer + pass pipeline + lowering).

so every tool that compiles a net gains frontend support by switching one
lookup.  Ambiguity is impossible: a spec containing a path separator or an
importer suffix is a file, anything else must be a builder name.
"""

from __future__ import annotations

import pathlib
from typing import Dict, Tuple

import numpy as np

from repro.core.graph import BUILDERS, NetGraph
from repro.frontend import IMPORTERS, load
from repro.frontend.ir import FrontendError


def _looks_like_path(spec: str) -> bool:
    suffixes = {s for imp in IMPORTERS.values() for s in imp.suffixes}
    return ("/" in spec or "\\" in spec
            or pathlib.Path(spec).suffix.lower() in suffixes)


def resolve_net(spec: str, seed: int = 0
                ) -> Tuple[NetGraph, Dict[str, Dict[str, np.ndarray]]]:
    """Resolve a builder name or model-file path to (NetGraph, params)."""
    if spec in BUILDERS:
        g = BUILDERS[spec]()
        return g, g.init_params(seed)
    if _looks_like_path(spec):
        m = load(spec)
        return m.graph, m.params
    raise FrontendError(
        f"cannot resolve net {spec!r}: not a registered builder "
        f"({', '.join(sorted(BUILDERS))}) and not a model file path "
        f"(suffixes: "
        f"{', '.join(sorted(s for i in IMPORTERS.values() for s in i.suffixes))})")
