"""ONNX importer: ModelProto bytes -> :class:`FrontendGraph`.

Parses the ONNX protobuf directly off the wire (``repro.frontend.protowire``)
so importing needs no ``onnx``/``protobuf`` install — the optional
``[frontend]`` extra is only for cross-validation and fixture export.  Field
numbers below are fixed by onnx.proto's wire contract (they can never change
without breaking every serialized model in existence).

Supported surface, mirroring what the engine can execute:
  * single graph input, NCHW, batch dim 1 or symbolic,
  * float32 initializers (raw_data or float_data),
  * the op vocabulary of ``repro.frontend.ir`` — anything else still parses
    (this importer is deliberately total over well-formed files) and is
    rejected *by name* later, by the unsupported-op partitioner.
"""

from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.frontend.ir import FrontendError, FrontendGraph, FrontendNode
from repro.frontend.protowire import Msg, WireError

# -- onnx.proto field numbers (wire contract) --------------------------------
# ModelProto
_M_GRAPH, _M_OPSET = 7, 8
# OperatorSetIdProto
_OS_DOMAIN, _OS_VERSION = 1, 2
# GraphProto
_G_NODE, _G_NAME, _G_INIT, _G_INPUT, _G_OUTPUT = 1, 2, 5, 11, 12
# NodeProto
_N_INPUT, _N_OUTPUT, _N_NAME, _N_OPTYPE, _N_ATTR = 1, 2, 3, 4, 5
# AttributeProto
_A_NAME, _A_F, _A_I, _A_S, _A_T, _A_FLOATS, _A_INTS, _A_STRINGS = \
    1, 2, 3, 4, 5, 7, 8, 9
# TensorProto
_T_DIMS, _T_DTYPE, _T_FLOAT, _T_INT32, _T_INT64, _T_NAME, _T_RAW = \
    1, 2, 4, 5, 7, 8, 9
# ValueInfoProto / TypeProto / TypeProto.Tensor / TensorShapeProto / Dimension
_VI_NAME, _VI_TYPE = 1, 2
_TY_TENSOR = 1
_TT_ELEM, _TT_SHAPE = 1, 2
_TS_DIM = 1
_D_VALUE, _D_PARAM = 1, 2

# TensorProto.DataType values this importer materialises
_DTYPES = {1: np.float32, 6: np.int32, 7: np.int64, 11: np.float64}


def _decode_tensor(t: Msg, where: str) -> Tuple[str, np.ndarray]:
    name = t.str_(_T_NAME)
    dims = tuple(t.ints(_T_DIMS))
    code = t.int_(_T_DTYPE)
    if code not in _DTYPES:
        raise FrontendError(
            f"{where}: initializer {name!r} has TensorProto data_type "
            f"{code}; this importer reads float32/float64/int32/int64")
    dt = np.dtype(_DTYPES[code])
    raw = t.bytes_(_T_RAW)
    if raw:
        a = np.frombuffer(raw, dtype=dt.newbyteorder("<")).astype(dt)
    elif code == 1:
        a = np.asarray(t.floats(_T_FLOAT), np.float32)
    elif code in (6, 7):
        a = np.asarray(t.ints(_T_INT64 if code == 7 else _T_INT32), dt)
    else:
        raise FrontendError(f"{where}: initializer {name!r} carries neither "
                            f"raw_data nor typed data")
    want = int(np.prod(dims)) if dims else a.size
    if a.size != want:
        raise FrontendError(
            f"{where}: initializer {name!r} dims {dims} need {want} "
            f"elements, data has {a.size}")
    return name, a.reshape(dims)


def _decode_attr(a: Msg) -> Tuple[str, Any]:
    name = a.str_(_A_NAME)
    # onnx sets AttributeProto.type, but the populated field is unambiguous;
    # probing fields keeps us independent of writers that omit the enum.
    if a.has(_A_INTS):
        return name, list(a.ints(_A_INTS))
    if a.has(_A_FLOATS):
        return name, list(a.floats(_A_FLOATS))
    if a.has(_A_STRINGS):
        return name, a.strs(_A_STRINGS)
    if a.has(_A_S):
        return name, a.str_(_A_S)
    if a.has(_A_T):
        _, arr = _decode_tensor(a.msg(_A_T), f"attribute {name!r}")
        return name, arr
    if a.has(_A_F):
        return name, a.float_(_A_F)
    if a.has(_A_I):
        return name, a.int_(_A_I)
    return name, None


def _decode_value_info(vi: Msg) -> Tuple[str, List[Any]]:
    """(name, dims) where dims entries are int or a str dim_param."""
    name = vi.str_(_VI_NAME)
    tt = vi.msg(_VI_TYPE).msg(_TY_TENSOR)
    dims: List[Any] = []
    for d in tt.msg(_TT_SHAPE).msgs(_TS_DIM):
        dims.append(d.str_(_D_PARAM) if d.has(_D_PARAM) else d.int_(_D_VALUE))
    return name, dims


def _input_chw(name: str, dims: List[Any], model_name: str) -> Tuple[int, ...]:
    """Map an ONNX input shape onto the engine's (C, H, W) single image."""
    concrete = [d for d in dims if isinstance(d, int)]
    if len(dims) == 4:
        n, rest = dims[0], dims[1:]
        if isinstance(n, int) and n != 1:
            raise FrontendError(
                f"{model_name}: input {name!r} has batch dimension {n}; the "
                f"engine is single-image (batch must be 1 or symbolic — "
                f"serving batches via the runtime scheduler instead)")
        dims = rest
    elif len(dims) != 3:
        raise FrontendError(
            f"{model_name}: input {name!r} has rank-{len(dims)} shape "
            f"{dims}; expected NCHW (N,C,H,W) or (C,H,W)")
    if not all(isinstance(d, int) and d > 0 for d in dims):
        raise FrontendError(
            f"{model_name}: input {name!r} has non-concrete feature dims "
            f"{dims} (only the batch dim may be symbolic); concrete dims "
            f"seen: {concrete}")
    return tuple(dims)


class OnnxImporter:
    """``Importer`` protocol implementation for ``.onnx`` files."""

    format = "onnx"
    suffixes = (".onnx",)

    def parse(self, data: bytes, name: str = "") -> FrontendGraph:
        try:
            model = Msg(data)
            gp = model.msg(_M_GRAPH)
            if not gp.bytes_list(_G_NODE) and not gp.bytes_list(_G_INPUT):
                raise FrontendError(
                    "no GraphProto found (is this an ONNX ModelProto?)")
            graph_name = gp.str_(_G_NAME) or name or "onnx_model"
            g = FrontendGraph(name=graph_name, source_format="onnx",
                              source_digest=hashlib.sha256(data).hexdigest())
            for t in gp.msgs(_G_INIT):
                tname, arr = _decode_tensor(t, graph_name)
                g.initializers[tname] = arr
            for vi in gp.msgs(_G_INPUT):
                vname, dims = _decode_value_info(vi)
                if vname in g.initializers:    # pre-IR4 style initializer input
                    continue
                g.inputs.append((vname, _input_chw(vname, dims, graph_name)))
            for vi in gp.msgs(_G_OUTPUT):
                g.outputs.append(_decode_value_info(vi)[0])
            for i, np_ in enumerate(gp.msgs(_G_NODE)):
                attrs = dict(_decode_attr(a) for a in np_.msgs(_N_ATTR))
                node = FrontendNode(
                    name=np_.str_(_N_NAME) or f"node_{i}",
                    op=np_.str_(_N_OPTYPE),
                    inputs=[t for t in np_.strs(_N_INPUT)],
                    outputs=np_.strs(_N_OUTPUT),
                    attrs=attrs)
                g.nodes.append(node)
        except WireError as e:
            raise FrontendError(f"{name or 'model'}: not a readable ONNX "
                                f"protobuf ({e})") from None
        if len(g.inputs) != 1:
            raise FrontendError(
                f"{g.name}: expected exactly one graph input, found "
                f"{[n for n, _ in g.inputs]!r} (multi-input models are not "
                f"servable on the single-surface engine)")
        if len(g.outputs) != 1:
            raise FrontendError(
                f"{g.name}: expected exactly one graph output, found "
                f"{g.outputs!r}")
        return g.check_ssa()
