"""Float32 numpy reference evaluator for :class:`FrontendGraph` ops.

Three consumers share these semantics:
  * the constant-folding pass (evaluating nodes whose inputs are all
    initializers),
  * pass unit tests (e.g. proving BatchNorm folding is numerically exact by
    evaluating a graph before and after the pass),
  * importer sanity checks.

This is *frontend* float32 semantics — the post-import fp32 model a user
would run in their framework — not the engine oracle (``core/refops`` stays
the int8/bf16 authority the executors are tested against).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.frontend.ir import (FrontendGraph, FrontendNode, FrontendError,
                               UnsupportedOpError)


def _conv(x, w, b, strides, pads, group):
    cin, h, win = x.shape
    k_out, cin_g, r, s = w.shape
    st, (pt, pl, pb, pr) = strides[0], pads
    xp = np.pad(x, ((0, 0), (pt, pb), (pl, pr)))
    p = (h + pt + pb - r) // st + 1
    q = (win + pl + pr - s) // st + 1
    out = np.empty((k_out, p, q), np.float32)
    kg = k_out // group
    for g in range(group):
        xg = xp[g * cin_g:(g + 1) * cin_g]
        cols = np.empty((cin_g, r, s, p, q), np.float32)
        for rr in range(r):
            for ss in range(s):
                cols[:, rr, ss] = xg[:, rr:rr + st * p:st, ss:ss + st * q:st]
        wg = w[g * kg:(g + 1) * kg].reshape(kg, -1)
        out[g * kg:(g + 1) * kg] = \
            (wg @ cols.reshape(cin_g * r * s, p * q)).reshape(kg, p, q)
    return out + b.reshape(-1, 1, 1)


def _pool(x, kernel, strides, pads, mode):
    c, h, w = x.shape
    (r, s), st, (pt, pl, pb, pr) = kernel, strides[0], pads
    fill = -np.inf if mode == "max" else 0.0
    xp = np.pad(x, ((0, 0), (pt, pb), (pl, pr)), constant_values=fill)
    p = (h + pt + pb - r) // st + 1
    q = (w + pl + pr - s) // st + 1
    acc = np.full((c, p, q), fill, np.float32)
    for rr in range(r):
        for ss in range(s):
            win = xp[:, rr:rr + st * p:st, ss:ss + st * q:st]
            acc = np.maximum(acc, win) if mode == "max" else acc + win
    return acc if mode == "max" else acc / (r * s)


def eval_node(node: FrontendNode, inputs: List[np.ndarray]) -> np.ndarray:
    """Evaluate one node on concrete float32 inputs (frontend semantics)."""
    op, a = node.op, node.attrs
    x = [np.asarray(v, np.float32) for v in inputs]
    if op == "Conv":
        w = x[1]
        b = x[2] if len(x) > 2 else np.zeros(w.shape[0], np.float32)
        out = _conv(x[0], w, b, a.get("strides", [1, 1]),
                    a.get("pads", [0, 0, 0, 0]), a.get("group", 1))
    elif op in ("Gemm", "MatMul"):
        w = x[1]
        if op == "MatMul" or not a.get("transB", 0):
            w = w.T
        out = float(a.get("alpha", 1.0)) * (w @ x[0].reshape(-1))
        if len(x) > 2:
            out = out + float(a.get("beta", 1.0)) * x[2]
    elif op == "Relu":
        out = np.maximum(x[0], 0)
    elif op == "MaxPool":
        out = _pool(x[0], a["kernel_shape"], a.get("strides", [1, 1]),
                    a.get("pads", [0, 0, 0, 0]), "max")
    elif op == "AveragePool":
        out = _pool(x[0], a["kernel_shape"], a.get("strides", [1, 1]),
                    a.get("pads", [0, 0, 0, 0]), "avg")
    elif op == "GlobalAveragePool":
        out = x[0].mean(axis=(1, 2), keepdims=True)
    elif op == "Add":
        out = x[0] + x[1].reshape(x[0].shape if x[1].size == x[0].size
                                  else x[1].shape)
    elif op == "Mul":
        out = x[0] * x[1]
    elif op == "Div":
        out = x[0] / x[1]
    elif op == "BatchNormalization":
        gamma, beta, mean, var = (v.reshape(-1, 1, 1) for v in x[1:5])
        eps = float(a.get("epsilon", 1e-5))
        out = gamma * (x[0] - mean) / np.sqrt(var + eps) + beta
    elif op in ("Flatten", "Reshape"):
        out = x[0].reshape(-1)
    elif op == "Concat":
        out = np.concatenate(x, axis=0)
    elif op in ("Identity", "Dropout"):
        out = x[0]
    elif op == "Softmax":
        e = np.exp(x[0] - x[0].max())
        out = e / e.sum()
    else:
        raise UnsupportedOpError(op, node.name, _EVAL_OPS,
                                 detail="no reference evaluation")
    return np.asarray(out, np.float32)


_EVAL_OPS = ("Conv", "Gemm", "MatMul", "Relu", "MaxPool", "AveragePool",
             "GlobalAveragePool", "Add", "Mul", "Div", "BatchNormalization",
             "Flatten", "Reshape", "Concat", "Identity", "Dropout", "Softmax")


def evaluate(g: FrontendGraph, feed: Dict[str, np.ndarray]
             ) -> Dict[str, np.ndarray]:
    """Forward-evaluate the whole graph; returns every tensor's value."""
    vals: Dict[str, np.ndarray] = {k: np.asarray(v, np.float32)
                                   for k, v in g.initializers.items()}
    for name, shape in g.inputs:
        if name not in feed:
            raise FrontendError(f"evaluate: missing graph input {name!r}")
        x = np.asarray(feed[name], np.float32)
        if x.shape != tuple(shape):
            raise FrontendError(f"evaluate: input {name!r} has shape "
                                f"{x.shape}, graph declares {tuple(shape)}")
        vals[name] = x
    for node in g.nodes:
        ins = []
        for t in node.inputs:
            if t == "":                    # optional ONNX input slot
                continue
            ins.append(vals[t])
        out = eval_node(node, ins)
        vals[node.output] = out
    return vals
