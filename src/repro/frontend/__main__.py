"""CLI: import a model file, inspect it, compile it to a servable bundle.

    # inspect: importer + pass pipeline + lowering, print the lowered net
    PYTHONPATH=src python -m repro.frontend examples/models/tinynet.json

    # compile to a saved Artifacts bundle (servable by `python -m repro.serve`)
    PYTHONPATH=src python -m repro.frontend model.onnx --compile-to bundle/

    # also run the compiled net on the bare-metal executor and check it
    # matches the VP oracle bit-exactly
    PYTHONPATH=src python -m repro.frontend model.onnx --compile-to b/ --verify

Exit codes: 0 ok, 1 import/compile/verify failure (UnsupportedOpError and
friends print their descriptive message, not a traceback).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from repro import frontend
from repro.core.pipeline import CompilerPipeline
from repro.frontend.ir import FrontendError


def _summary(m: frontend.ImportedModel) -> str:
    g = m.graph
    lines = [f"{g.name}: {m.source_format} import, "
             f"digest {m.source_digest[:12]}, input {g.input_shape}"]
    for l in g.layers:
        extra = ""
        if l.type == "conv":
            extra = (f" k{l.kernel}s{l.stride}p{l.pad} -> {l.out_channels}ch"
                     + (f" g{l.groups}" if l.groups > 1 else ""))
        elif l.type == "fc":
            extra = f" -> {l.out_channels}"
        elif l.type == "pool":
            extra = f" {l.pool_mode}" + \
                (f" k{l.kernel}s{l.stride}" if l.pool_mode != "gap" else "")
        lines.append(f"  {l.name:<16} {l.type}{extra}"
                     f"{' +relu' if l.relu else ''}  out={l.out_shape}")
    n_params = sum(int(a.size) for p in m.params.values()
                   for a in p.values())
    lines.append(f"  {len(g.layers)} layers, {n_params} parameters")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.frontend",
        description="import an ONNX / repro-net-v1 JSON model into the "
                    "compiler toolflow")
    ap.add_argument("model", help="model file (.onnx / .json)")
    ap.add_argument("--format", choices=sorted(frontend.IMPORTERS),
                    help="force an importer (default: sniff)")
    ap.add_argument("--compile-to", metavar="DIR",
                    help="compile and save an Artifacts bundle to DIR")
    ap.add_argument("--verify", action="store_true",
                    help="after compiling, run the bare-metal executor and "
                         "check bit-exact parity with the VP oracle")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for calibration samples (default 0)")
    args = ap.parse_args(argv)

    try:
        m = frontend.load(args.model, format=args.format)
    except FrontendError as e:
        print(f"import failed: {e}", file=sys.stderr)
        return 1
    print(_summary(m))

    if not (args.compile_to or args.verify):
        return 0
    pipe = CompilerPipeline(m.graph, params=m.params, seed=args.seed)
    art = pipe.run()
    print(f"compiled: {len(art.loadable.descriptors)} descriptors, "
          f"{art.cost.ms_at_clock:.2f} ms @100MHz (cost model)")
    if args.compile_to:
        path = art.save(args.compile_to)
        print(f"saved bundle -> {path}")
    if args.verify:
        from repro.core.vp import VirtualPlatform
        from repro.runtime import create_executor
        rng = np.random.default_rng(args.seed + 17)
        x = rng.normal(0, 1, m.graph.input_shape).astype(np.float32)
        vp = VirtualPlatform(art.loadable).run(x)
        bm = create_executor("baremetal", art).run(x)
        if not np.array_equal(vp.output_int8, bm.output_int8):
            print("verify FAILED: bare-metal executor diverges from VP "
                  "oracle", file=sys.stderr)
            return 1
        print(f"verify ok: bare-metal == VP oracle "
              f"({vp.output_int8.size} int8 outputs bit-exact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
