"""Minimal protobuf *wire-format* codec (no generated code, no deps).

ONNX models are protobuf messages, but depending on the ``onnx``/``protobuf``
packages would make the frontend's core path optional-dependency-shaped.  The
wire format itself is tiny — varints plus length-delimited submessages — so
the ONNX importer decodes it directly with this module and stays stdlib+numpy
only.  The ``onnx`` package remains an optional ``[frontend]`` extra used for
cross-validation tests and for exporting fixtures from real frameworks.

Decode: :class:`Msg` lazily indexes ``field_number -> [raw values]`` for one
message buffer; typed accessors (``ints``/``floats``/``str_``/``msgs``)
handle both packed and repeated encodings.  Encode: ``enc_*`` helpers build
messages bottom-up (used by the committed fixture generator and the tests'
round-trip checks).

Wire types: 0 varint · 1 fixed64 · 2 length-delimited · 5 fixed32.
"""

from __future__ import annotations

import struct
from typing import Dict, Iterator, List, Tuple


class WireError(ValueError):
    """Malformed wire data (truncated varint, bad wire type, overrun)."""


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def read_varint(buf: bytes, pos: int) -> Tuple[int, int]:
    """Decode one varint at ``pos``; returns (value, next_pos)."""
    result = 0
    shift = 0
    while True:
        if pos >= len(buf):
            raise WireError(f"truncated varint at byte {pos}")
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not b & 0x80:
            return result, pos
        shift += 7
        if shift > 63:
            raise WireError(f"varint longer than 10 bytes at byte {pos}")


def to_signed64(v: int) -> int:
    """Reinterpret an unsigned varint as two's-complement int64."""
    return v - (1 << 64) if v >= (1 << 63) else v


def iter_fields(buf: bytes) -> Iterator[Tuple[int, int, bytes]]:
    """Yield (field_number, wire_type, raw) triples for one message buffer.

    To keep one value shape, varints are yielded as their minimal
    little-endian byte string (re-parsed by the typed accessors);
    fixed/length-delimited fields yield their payload bytes.
    """
    pos = 0
    n = len(buf)
    while pos < n:
        tag, pos = read_varint(buf, pos)
        field, wt = tag >> 3, tag & 7
        if field == 0:
            raise WireError(f"field number 0 at byte {pos}")
        if wt == 0:
            v, pos = read_varint(buf, pos)
            yield field, wt, v.to_bytes((v.bit_length() + 7) // 8 or 1, "little")
        elif wt == 1:
            if pos + 8 > n:
                raise WireError(f"truncated fixed64 at byte {pos}")
            yield field, wt, buf[pos:pos + 8]
            pos += 8
        elif wt == 2:
            ln, pos = read_varint(buf, pos)
            if pos + ln > n:
                raise WireError(f"length-delimited field {field} overruns "
                                f"buffer ({ln} bytes at {pos}, have {n - pos})")
            yield field, wt, buf[pos:pos + ln]
            pos += ln
        elif wt == 5:
            if pos + 4 > n:
                raise WireError(f"truncated fixed32 at byte {pos}")
            yield field, wt, buf[pos:pos + 4]
            pos += 4
        else:
            raise WireError(f"unsupported wire type {wt} (field {field}); "
                            f"groups are not part of proto3")


class Msg:
    """One decoded message: ``field_number -> [(wire_type, payload)]``."""

    def __init__(self, buf: bytes):
        self._f: Dict[int, List[Tuple[int, bytes]]] = {}
        for field, wt, payload in iter_fields(buf):
            self._f.setdefault(field, []).append((wt, payload))

    def has(self, field: int) -> bool:
        return field in self._f

    # -- scalar accessors ----------------------------------------------------
    def int_(self, field: int, default: int = 0) -> int:
        """Last int64/enum value of ``field`` (proto3 last-one-wins)."""
        vals = self.ints(field)
        return vals[-1] if vals else default

    def ints(self, field: int) -> List[int]:
        """All int64 values: repeated varints and/or packed payloads."""
        out: List[int] = []
        for wt, payload in self._f.get(field, []):
            if wt == 0:
                out.append(to_signed64(int.from_bytes(payload, "little")))
            elif wt == 2:                      # packed repeated varints
                pos = 0
                while pos < len(payload):
                    v, pos = read_varint(payload, pos)
                    out.append(to_signed64(v))
            else:
                raise WireError(f"field {field}: wire type {wt} is not an int")
        return out

    def float_(self, field: int, default: float = 0.0) -> float:
        vals = self.floats(field)
        return vals[-1] if vals else default

    def floats(self, field: int) -> List[float]:
        """All float32 values: repeated fixed32 and/or packed payloads."""
        out: List[float] = []
        for wt, payload in self._f.get(field, []):
            if wt == 5:
                out.append(struct.unpack("<f", payload)[0])
            elif wt == 2:                      # packed repeated floats
                if len(payload) % 4:
                    raise WireError(f"field {field}: packed float payload "
                                    f"of {len(payload)} bytes")
                out.extend(struct.unpack(f"<{len(payload) // 4}f", payload))
            else:
                raise WireError(f"field {field}: wire type {wt} is not a float")
        return out

    def bytes_(self, field: int, default: bytes = b"") -> bytes:
        vals = self._f.get(field, [])
        return vals[-1][1] if vals else default

    def bytes_list(self, field: int) -> List[bytes]:
        return [p for _, p in self._f.get(field, [])]

    def str_(self, field: int, default: str = "") -> str:
        return self.bytes_(field, default.encode()).decode("utf-8")

    def strs(self, field: int) -> List[str]:
        return [p.decode("utf-8") for p in self.bytes_list(field)]

    def msg(self, field: int) -> "Msg":
        return Msg(self.bytes_(field))

    def msgs(self, field: int) -> List["Msg"]:
        return [Msg(p) for p in self.bytes_list(field)]


# ---------------------------------------------------------------------------
# encode (fixture generation + round-trip tests)
# ---------------------------------------------------------------------------
def enc_varint(v: int) -> bytes:
    if v < 0:
        v += 1 << 64                           # two's-complement int64
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def enc_tag(field: int, wt: int) -> bytes:
    return enc_varint((field << 3) | wt)


def enc_int(field: int, v: int) -> bytes:
    return enc_tag(field, 0) + enc_varint(v)


def enc_float(field: int, v: float) -> bytes:
    return enc_tag(field, 5) + struct.pack("<f", v)


def enc_bytes(field: int, payload: bytes) -> bytes:
    return enc_tag(field, 2) + enc_varint(len(payload)) + payload


def enc_str(field: int, s: str) -> bytes:
    return enc_bytes(field, s.encode("utf-8"))


def enc_packed_ints(field: int, vals) -> bytes:
    return enc_bytes(field, b"".join(enc_varint(v) for v in vals))
