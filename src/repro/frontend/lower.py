"""Lowering: a pass-pipeline-normalised FrontendGraph -> NetGraph + params.

The last frontend stage.  After ``passes.run_pipeline`` the graph contains
only ``LOWERABLE_OPS``; this module maps them 1:1 onto
``repro.core.graph.NetGraph`` layers (the compiler's existing input IR) and
extracts the float32 parameter dict ``CompilerPipeline`` quantises.  The
input layer is renamed ``data`` — the name the arena planner and the
calibration table key on — and the produced ``NetGraph`` carries the
frontend's ``source_digest`` so compiled-artifact cache keys distinguish two
files that happen to share a graph name.

Anything still un-mappable here (a non-square kernel that slipped past a
custom pass list, say) raises :class:`UnsupportedOpError` — lowering is part
of import, so these still fail at import time.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.graph import NetGraph
from repro.frontend.ir import (FrontendError, FrontendGraph, FrontendNode,
                               UnsupportedOpError)
from repro.frontend.passes.partition import LOWERABLE_OPS

_POOL_MODES = {"MaxPool": "max", "AveragePool": "avg",
               "GlobalAveragePool": "gap"}


def _scalar(node: FrontendNode, key: str, default=None) -> int:
    vals = node.attrs.get(key, default)
    if vals is None:
        return 0
    return int(vals[0]) if isinstance(vals, (list, tuple)) else int(vals)


def _layer_name(g: FrontendGraph, node: FrontendNode, taken: set) -> str:
    """NetGraph layer names come from node names (ONNX may leave them
    machiney — e.g. ``/conv1/Conv``); sanitise and uniquify."""
    base = g.node_label(node).strip("/").replace("/", "_").replace(":", "_") \
        or "layer"
    name = base
    i = 1
    while name in taken or name == "data":
        name = f"{base}_{i}"
        i += 1
    return name


def lower(fg: FrontendGraph) -> Tuple[NetGraph, Dict[str, Dict[str, np.ndarray]]]:
    """Map a normalised FrontendGraph onto (NetGraph, params)."""
    if len(fg.inputs) != 1:
        raise FrontendError(f"{fg.name}: lowering needs exactly one graph "
                            f"input, got {[n for n, _ in fg.inputs]}")
    in_name, in_shape = fg.inputs[0]
    if len(in_shape) != 3:
        raise FrontendError(f"{fg.name}: graph input {in_name!r} must be "
                            f"(C, H, W), got {tuple(in_shape)}")

    g = NetGraph(fg.name, tuple(int(d) for d in in_shape))
    g.layer(name="data", type="input", inputs=[])
    params: Dict[str, Dict[str, np.ndarray]] = {}
    # frontend tensor name -> NetGraph layer name
    t2l: Dict[str, str] = {in_name: "data"}
    taken = {"data"}

    for node in fg.nodes:
        if node.op not in LOWERABLE_OPS:
            raise UnsupportedOpError(node.op, fg.node_label(node),
                                     LOWERABLE_OPS,
                                     detail="reached lowering — run the "
                                            "partition pass first")
        name = _layer_name(fg, node, taken)
        taken.add(name)
        acts = [t for t in node.inputs if not fg.is_initializer(t)]
        try:
            srcs = [t2l[t] for t in acts]
        except KeyError as e:
            raise FrontendError(f"{fg.name}: node {fg.node_label(node)!r} "
                                f"reads {e.args[0]!r}, which no lowered "
                                f"layer produces") from None
        relu = bool(node.attrs.get("fused_relu", False))

        if node.op == "Conv":
            w = np.asarray(fg.initializers[node.inputs[1]], np.float32)
            b = np.asarray(fg.initializers[node.inputs[2]],
                           np.float32).reshape(-1)
            k_out, _, r, s = w.shape
            if r != s:
                raise UnsupportedOpError(
                    "Conv", fg.node_label(node), LOWERABLE_OPS,
                    detail=f"non-square kernel ({r}x{s})")
            g.layer(name=name, type="conv", inputs=srcs,
                    out_channels=int(k_out), kernel=int(r),
                    stride=_scalar(node, "strides", [1]) or 1,
                    pad=_scalar(node, "pads", [0]),
                    groups=int(node.attrs.get("group", 1)), relu=relu)
            params[name] = {"w": w, "b": b}
        elif node.op == "Gemm":
            w = np.asarray(fg.initializers[node.inputs[1]], np.float32)
            b = np.asarray(fg.initializers[node.inputs[2]],
                           np.float32).reshape(-1)
            g.layer(name=name, type="fc", inputs=srcs,
                    out_channels=int(w.shape[0]), relu=relu)
            params[name] = {"w": w, "b": b}
        elif node.op in _POOL_MODES:
            mode = _POOL_MODES[node.op]
            kw = {}
            if mode != "gap":
                kw = dict(kernel=_scalar(node, "kernel_shape", [1]),
                          stride=_scalar(node, "strides", [1]) or 1,
                          pad=_scalar(node, "pads", [0]))
            g.layer(name=name, type="pool", inputs=srcs, pool_mode=mode, **kw)
        elif node.op == "Add":
            g.layer(name=name, type="add", inputs=srcs, relu=relu)
        else:                              # Concat
            g.layer(name=name, type="concat", inputs=srcs)
        t2l[node.output] = name

    if len(fg.outputs) != 1:
        raise FrontendError(f"{fg.name}: lowering needs exactly one graph "
                            f"output, got {fg.outputs}")
    out_layer = t2l.get(fg.outputs[0])
    if out_layer != g.layers[-1].name:
        raise FrontendError(
            f"{fg.name}: graph output {fg.outputs[0]!r} maps to layer "
            f"{out_layer!r}, but the engine serves the last layer "
            f"({g.layers[-1].name!r}) — reorder the model so its output is "
            f"produced last")

    g.source_digest = fg.source_digest
    g.validate()
    g.infer_shapes()

    # cross-check lowered shapes against the pass pipeline's inference —
    # two independent shape computations must agree on every layer
    if fg.shapes:
        t2shape = {t: fg.shapes[t] for t in t2l if t in fg.shapes}
        for t, lname in t2l.items():
            want = t2shape.get(t)
            got = g.by_name()[lname].out_shape
            if want is None or lname == "data":
                continue
            want3 = want if len(want) == 3 else (want[0], 1, 1)
            if tuple(want3) != tuple(got):
                raise FrontendError(
                    f"{fg.name}: shape disagreement on {lname!r}: frontend "
                    f"inferred {want3}, NetGraph inferred {got} "
                    f"(importer bug — please report)")
    return g, params
