"""Synthetic-but-production-shaped data pipeline.

Deterministic, step-indexed batch synthesis: batch(step) is a pure function of
(seed, step), so
  * every data-parallel host computes its own shard with no coordination,
  * restart-from-checkpoint resumes the stream exactly (fault tolerance),
  * a straggling/replaced host can recompute any shard (elastic scaling).

This mirrors how a real pipeline (SSTable/ArrayRecord shards + index) behaves
at the interface level; the content is synthetic token streams since the paper
targets inference of pretrained nets, not data curation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig


@dataclasses.dataclass
class BatchSpec:
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


def make_batch(cfg: ArchConfig, spec: BatchSpec, step: int = 0,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """Synthesize the batch for ``step`` (host-side numpy, then device-put)."""
    rng = np.random.default_rng(np.uint64(seed) + np.uint64(step) * 1_000_003)
    b, s = spec.global_batch, spec.seq_len
    if cfg.family == "encdec":
        dec = max(s // cfg.dec_len_ratio, 64)
        out = {
            "frames": rng.normal(0, 1, (b, s, cfg.d_model)).astype(np.float32),
            "tokens": rng.integers(0, cfg.vocab, (b, dec), dtype=np.int32),
        }
        if spec.kind == "train":
            out["labels"] = rng.integers(0, cfg.vocab, (b, dec), dtype=np.int32)
        return out
    out = {"tokens": rng.integers(0, cfg.vocab, (b, s), dtype=np.int32)}
    if spec.kind == "train":
        out["labels"] = rng.integers(0, cfg.vocab, (b, s), dtype=np.int32)
    if cfg.family == "vlm":
        # M-RoPE 3D positions (temporal, h, w) — text-like monotonic stub
        pos = np.broadcast_to(np.arange(s, dtype=np.int32)[None], (b, s))
        out["pos3"] = np.stack([pos, pos, pos])
    return out


def batch_shapes(cfg: ArchConfig, spec: BatchSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    b, s = spec.global_batch, spec.seq_len
    if cfg.family == "encdec":
        dec = max(s // cfg.dec_len_ratio, 64)
        shapes = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, dec), jnp.int32),
        }
        if spec.kind == "train":
            shapes["labels"] = jax.ShapeDtypeStruct((b, dec), jnp.int32)
        return shapes
    shapes = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if spec.kind == "train":
        shapes["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "vlm":
        shapes["pos3"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return shapes


def decode_batch_shapes(cfg: ArchConfig, spec: BatchSpec):
    """Decode step inputs: one new token (B,1) (+ pos3 for vlm)."""
    b = spec.global_batch
    shapes = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "vlm":
        shapes["pos3"] = jax.ShapeDtypeStruct((3, b, 1), jnp.int32)
    return shapes


class DataIterator:
    """Step-indexed iterator with exact resume (used by launch/train.py)."""

    def __init__(self, cfg: ArchConfig, spec: BatchSpec, seed: int = 0,
                 start_step: int = 0):
        self.cfg, self.spec, self.seed = cfg, spec, seed
        self.step = start_step

    def __iter__(self) -> "DataIterator":
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        out = make_batch(self.cfg, self.spec, self.step, self.seed)
        self.step += 1
        return out

    def state(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def restore(cls, cfg, spec, state: Dict[str, int]) -> "DataIterator":
        return cls(cfg, spec, seed=state["seed"], start_step=state["step"])
