"""AdamW with global-norm clipping and warmup+cosine schedule.

Optimizer state is a pytree congruent with params, so it inherits the params'
NamedShardings under jit (fully sharded optimizer state — ZeRO-ish for free on
the TP axis; the DP axis keeps params replicated, as v5e HBM comfortably fits
the assigned models at 256-way sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Any = None      # None -> param dtype; jnp.bfloat16 halves mu/nu


def init(params, state_dtype=None) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, state_dtype or p.dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params), nu=jax.tree.map(z, params))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        sd = m.dtype                     # state dtype (f32 or bf16)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh = m2 / (1 - b1 ** step.astype(jnp.float32))
        vh = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(sd), v2.astype(sd))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), \
        {"grad_norm": gnorm, "lr": lr}
