"""granite-34b [dense] — llama-arch MQA (kv=1), code model. [arXiv:2405.04324; hf]
88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""

from repro.models.common import ArchConfig

ID = "granite-34b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="dense", n_layers=88, d_model=6144, n_heads=48, n_kv=1,
        d_ff=24576, vocab=49152)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv=1, d_ff=192, vocab=256, loss_chunk=16, remat=False, grad_accum=1)
