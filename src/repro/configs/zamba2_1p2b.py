"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Shared attn invoked every 6 mamba layers (6 invocations, shared weights).
"""

from repro.models.common import ArchConfig

ID = "zamba2-1.2b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="hybrid", n_layers=38, d_model=2048, n_heads=32,
        n_kv=32, d_ff=8192, vocab=32000, ssm_state=64, ssm_headdim=64,
        ssm_expand=2, attn_every=6)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="hybrid", n_layers=4, d_model=64, n_heads=4,
        n_kv=4, d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16,
        ssm_expand=2, attn_every=2, ssm_chunk=16, loss_chunk=16, remat=False, grad_accum=1)
