"""llama4-maverick-400b-a17b [moe] — MoE, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
"""

from repro.models.common import ArchConfig

ID = "llama4-maverick-400b-a17b"


def full() -> ArchConfig:
    # Llama-4 style interleaving: MoE every 2nd layer + 1 shared expert
    # (400B total / ~17B active with 128 routed experts, top-1).
    return ArchConfig(
        name=ID, family="moe", n_layers=48, d_model=5120, n_heads=40, n_kv=8,
        d_ff=8192, vocab=202048, n_experts=128, top_k=1, moe_every=2,
        n_shared_experts=1)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="moe", n_layers=4, d_model=64, n_heads=4,
        n_kv=2, d_ff=96, vocab=256, n_experts=8, top_k=1, moe_every=2,
        n_shared_experts=1, moe_chunk=16, loss_chunk=16, remat=False, grad_accum=1)
