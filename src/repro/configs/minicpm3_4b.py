"""minicpm3-4b [dense/MLA] — multi-head latent attention. [hf:openbmb/MiniCPM3-4B; hf]
62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448; MLA ranks: q 768, kv 256,
qk_nope 64, qk_rope 32, v 64 (MiniCPM3 release values).
"""

from repro.models.common import ArchConfig

ID = "minicpm3-4b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="mla", n_layers=62, d_model=2560, n_heads=40, n_kv=40,
        d_ff=6400, vocab=73448, q_lora_rank=768, kv_lora_rank=256,
        qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="mla", n_layers=2, d_model=64, n_heads=4,
        n_kv=4, d_ff=128, vocab=256, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, loss_chunk=16, remat=False, grad_accum=1)
