"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution (vision frontend STUBBED:
input_specs provides token/patch ids + 3D position ids). [arXiv:2409.12191; hf]
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""

from repro.models.common import ArchConfig

ID = "qwen2-vl-72b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="vlm", n_layers=80, d_model=8192, n_heads=64, n_kv=8,
        d_ff=29568, vocab=152064, mrope_sections=(16, 24, 24))


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
        n_kv=2, d_ff=128, vocab=256, mrope_sections=(2, 3, 3),
        loss_chunk=16, remat=False, grad_accum=1)
