"""whisper-tiny [audio] — enc-dec, conv frontend STUBBED (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]
4L (enc) + 4L (dec) d_model=384 6H d_ff=1536 vocab=51865.
"""

from repro.models.common import ArchConfig

ID = "whisper-tiny"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="encdec", n_layers=8, n_enc_layers=4, n_dec_layers=4,
        d_model=384, n_heads=6, n_kv=6, d_ff=1536, vocab=51865)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="encdec", n_layers=4, n_enc_layers=2,
        n_dec_layers=2, d_model=48, n_heads=4, n_kv=4, d_ff=96, vocab=256,
        loss_chunk=8, remat=False, grad_accum=1)
