"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]
32L d_model=4096 (64 heads x 64) d_ff=14336 vocab=65536.
"""

from repro.models.common import ArchConfig

ID = "rwkv6-7b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="ssm", n_layers=32, d_model=4096, n_heads=64, n_kv=64,
        d_ff=14336, vocab=65536, rwkv_lora=64, ssm_chunk=256)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="ssm", n_layers=2, d_model=64, n_heads=4,
        n_kv=4, d_ff=128, vocab=256, rwkv_lora=16, ssm_chunk=16,
        loss_chunk=16, remat=False, grad_accum=1)
