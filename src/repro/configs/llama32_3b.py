"""llama3.2-3b [dense] — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from repro.models.common import ArchConfig

ID = "llama3.2-3b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="dense", n_layers=28, d_model=3072, n_heads=24, n_kv=8,
        d_ff=8192, vocab=128256)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="dense", n_layers=2, d_model=48, n_heads=4,
        n_kv=2, d_ff=96, vocab=256, loss_chunk=16, remat=False, grad_accum=1)
