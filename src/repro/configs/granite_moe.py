"""granite-moe-3b-a800m [moe] — 40 fine-grained experts, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
"""

from repro.models.common import ArchConfig

ID = "granite-moe-3b-a800m"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="moe", n_layers=32, d_model=1536, n_heads=24, n_kv=8,
        d_ff=512, vocab=49155, n_experts=40, top_k=8)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="moe", n_layers=2, d_model=48, n_heads=4,
        n_kv=2, d_ff=32, vocab=256, n_experts=5, top_k=2, moe_chunk=16,
        loss_chunk=16, remat=False, grad_accum=1)
