"""Architecture config registry: ``--arch <id>`` -> ArchConfig.

Ten assigned architectures (full + reduced smoke variants), plus the paper's
own CNNs (LeNet-5 / ResNet-18 / ResNet-50 / AlexNet / MobileNet / GoogLeNet)
which live in ``repro.core.graph.BUILDERS`` (they run on the engine/trace
substrate, not the LM substrate).

CNNs *without* a hand-written builder enter through ``repro.frontend``
(ONNX / repro-net-v1 JSON importers + pass pipeline);
``repro.frontend.resolve.resolve_net`` accepts either a ``BUILDERS`` name or
a model-file path, so CLI surfaces treat both uniformly.
"""

from __future__ import annotations

from repro.configs import (granite_34b, granite_moe, llama32_3b,
                           llama4_maverick, minicpm3_4b, qwen2_vl_72b,
                           rwkv6_7b, whisper_tiny, yi_6b, zamba2_1p2b)
from repro.models.common import ArchConfig

_MODULES = [llama4_maverick, granite_moe, yi_6b, minicpm3_4b, llama32_3b,
            granite_34b, whisper_tiny, zamba2_1p2b, rwkv6_7b, qwen2_vl_72b]

ARCHS = {m.ID: m for m in _MODULES}
ALL_ARCH_IDS = list(ARCHS)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    if arch_id not in ARCHS:
        raise ValueError(f"unknown arch {arch_id!r}; known: {ALL_ARCH_IDS}")
    return ARCHS[arch_id].smoke() if smoke else ARCHS[arch_id].full()


# ---------------------------------------------------------------------------
# Assigned input shapes (seq_len, global_batch) and applicability
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid archs
# (full-attention archs are skipped per the assignment; see DESIGN.md §4).
LONG_OK = {"zamba2-1.2b", "rwkv6-7b"}


def cells(include_skipped: bool = False):
    """All (arch_id, shape_name) dry-run cells (32 runnable, 8 skipped)."""
    out = []
    for a in ALL_ARCH_IDS:
        for s in SHAPES:
            skipped = (s == "long_500k" and a not in LONG_OK)
            if skipped and not include_skipped:
                continue
            out.append((a, s) if not include_skipped else (a, s, skipped))
    return out
