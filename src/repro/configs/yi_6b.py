"""yi-6b [dense] — llama-arch GQA. [arXiv:2403.04652; hf]
32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from repro.models.common import ArchConfig

ID = "yi-6b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ID, family="dense", n_layers=32, d_model=4096, n_heads=32, n_kv=4,
        d_ff=11008, vocab=64000)


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ID + "-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv=2, d_ff=128, vocab=256, loss_chunk=16, remat=False, grad_accum=1)
