"""Executor-backend registry.

Backends register themselves with the ``@register_backend("name")`` decorator;
``create(kind, artifacts)`` instantiates one from an :class:`Artifacts` set
and verifies it satisfies the uniform :class:`ExecutorBackend` protocol
(``run`` / ``run_batch(padded, lanes)`` / ``capabilities()``) — the Session
scheduler drives every backend through that contract alone, with no
per-backend special cases.  Unknown backend names raise with the list of
registered backends — no silent fallback.
"""

from __future__ import annotations

from typing import Callable, Dict, List

_PROTOCOL_METHODS = ("run", "run_batch", "capabilities")


_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Decorator: register ``factory(artifacts, **kw) -> executor`` as ``name``."""
    def deco(factory: Callable) -> Callable:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        _BACKENDS[name] = factory
        return factory
    return deco


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


def create(kind: str, artifacts, **kw):
    """Instantiate the ``kind`` backend over ``artifacts``.

    Raises ``ValueError`` naming the registered backends for unknown kinds,
    and ``TypeError`` when a factory returns an object that does not satisfy
    the ``ExecutorBackend`` protocol.
    """
    try:
        factory = _BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown executor backend {kind!r}; registered backends: "
            f"{', '.join(backend_names())}") from None
    ex = factory(artifacts, **kw)
    missing = [m for m in _PROTOCOL_METHODS
               if not callable(getattr(ex, m, None))]
    if missing:
        raise TypeError(
            f"backend {kind!r} factory returned {type(ex).__name__}, which "
            f"does not satisfy repro.core.executor.ExecutorBackend "
            f"(missing: {', '.join(missing)}); executors must provide "
            f"run(x), run_batch(X, lanes=None) and capabilities()")
    return ex
