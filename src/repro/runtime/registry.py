"""Executor-backend registry.

Backends register themselves with the ``@register_backend("name")`` decorator;
``create(kind, artifacts)`` instantiates one from an :class:`Artifacts` set.
Unknown backend names raise with the list of registered backends — no silent
fallback.
"""

from __future__ import annotations

from typing import Callable, Dict, List


_BACKENDS: Dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Decorator: register ``factory(artifacts, **kw) -> executor`` as ``name``."""
    def deco(factory: Callable) -> Callable:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        _BACKENDS[name] = factory
        return factory
    return deco


def backend_names() -> List[str]:
    return sorted(_BACKENDS)


def create(kind: str, artifacts, **kw):
    """Instantiate the ``kind`` backend over ``artifacts``.

    Raises ``ValueError`` naming the registered backends for unknown kinds.
    """
    try:
        factory = _BACKENDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown executor backend {kind!r}; registered backends: "
            f"{', '.join(backend_names())}") from None
    return factory(artifacts, **kw)
