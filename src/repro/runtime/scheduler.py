"""Async serving scheduler: per-net dispatchers + SLA-aware micro-batching.

``Session.submit(x)`` enqueues one inference request and returns a
``concurrent.futures.Future``.  Every resident network gets its **own
dispatcher thread and queue** (a slow ResNet batch can never head-of-line
block LeNet traffic); each dispatcher drains its queue, coalesces compatible
requests into one batch, pads it to a power-of-two bucket (so each batch
shape compiles exactly once), executes it through the backend's
``run_batch(padded, lanes)``, and resolves each future with its lane's
``ExecResult`` — bit-exact versus running every request through sequential
``run`` calls, because the batch program itself is bit-exact and padding
lanes are sliced off before anyone sees them.

**SLA-aware ordering.**  Requests carry ``priority`` (higher = more urgent)
and an optional ``deadline_us`` latency budget.  The queue is a heap ordered
by ``(-priority, deadline, arrival)``: urgent traffic launches first, and
within a priority class the tightest deadline wins (EDF).  A request whose
deadline has already passed when the dispatcher would launch it is **shed**
— its future fails fast with :class:`DeadlineExceededError` instead of
burning a batch slot on an answer nobody wants.

**Continuous batching.**  The collector holds a forming batch open (up to
``max_wait_us``) and admits late-arriving compatible requests right up to
launch; after the hold it re-reads the queue head, so a high-priority
arrival during the hold window leads the very next dispatch.

**Admission control.**  ``SchedulerConfig.max_queue`` bounds each net's
queue; past it, ``submit`` fails fast with :class:`QueueFullError` (the HTTP
front-end maps it to 429) instead of growing the queue without bound.

Micro-batching is *adaptive*: each dispatcher tracks an EMA of recent
coalesce sizes.  Under solo traffic (EMA ~ 1) it dispatches immediately —
waiting would only add latency; once concurrency is observed it holds the
head request up to ``max_wait_us`` to let the batch fill towards
``max_batch``.

When several devices are visible and the backend reports
``capabilities().shardable``, a coalesced batch whose bucket divides the
device count is dispatched with its lane axis sharded over a 1-axis data
mesh (``repro.distributed.sharding.serving_mesh``); GSPMD splits the vmapped
program across devices and replicates the resident weight arena.

Padding and lane masking live HERE, not in executors: backends receive an
already-padded batch plus the live-lane count and stay policy-free.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
import itertools
import math
import queue
import random
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional

import numpy as np

from repro.core import perfmodel
from repro.core.executor import ExecResult
from repro.obs.trace import status_for_exception

# EMA of coalesce sizes above which a dispatcher starts holding the head
# request for stragglers (below it, traffic is effectively solo).
_COALESCE_THRESHOLD = 1.25
_EMA_ALPHA = 0.2


class QueueFullError(RuntimeError):
    """Admission control: the target net's queue is at ``max_queue``.

    Raised synchronously by ``submit`` — the request was never enqueued.
    The HTTP front-end maps this to ``429 Too Many Requests``.
    """

    def __init__(self, net_name: str, depth: int, bound: int):
        super().__init__(
            f"queue for network {net_name!r} is full "
            f"({depth}/{bound} queued); retry later or raise "
            f"SchedulerConfig.max_queue")
        self.net_name, self.depth, self.bound = net_name, depth, bound


class DeadlineExceededError(RuntimeError):
    """The request's ``deadline_us`` budget elapsed before launch; it was
    shed by the collector and never executed.  Delivered through the
    request's future."""

    def __init__(self, net_name: str, deadline_us: float, waited_us: float):
        super().__init__(
            f"request for network {net_name!r} shed: deadline_us="
            f"{deadline_us:.0f} elapsed after {waited_us:.0f}us in queue")
        self.net_name = net_name
        self.deadline_us, self.waited_us = deadline_us, waited_us


class LaunchTimeoutError(RuntimeError):
    """A supervised launch exceeded its watchdog timeout and was abandoned
    (the backend call may still be blocked on an orphaned worker thread).
    Retried like any other launch failure; surfaces to futures only inside
    a :class:`BackendFaultError` once retries are exhausted."""

    def __init__(self, net_name: str, timeout_s: float):
        super().__init__(
            f"launch for network {net_name!r} exceeded its watchdog "
            f"timeout ({timeout_s:.3f}s) and was abandoned")
        self.net_name, self.timeout_s = net_name, timeout_s


class BackendFaultError(RuntimeError):
    """The dispatcher exhausted its retry budget for one batch: every
    attempt raised or timed out.  Delivered through each affected request's
    future (never a hang); ``cause`` (also ``__cause__``) carries the last
    attempt's causal exception.  The HTTP front-end maps this to 500."""

    def __init__(self, net_name: str, attempts: int, cause: BaseException):
        super().__init__(
            f"backend for network {net_name!r} failed {attempts} "
            f"launch attempt(s); last: {type(cause).__name__}: {cause}")
        self.net_name, self.attempts, self.cause = net_name, attempts, cause


class CircuitOpenError(RuntimeError):
    """Admission refused: the net's circuit breaker is open (N consecutive
    launch failures) and no fallback backend is configured.  Raised
    synchronously by ``submit`` — the request was never enqueued.  The HTTP
    front-end maps this to 503 with a ``Retry-After`` of ``retry_after_s``
    (the time left until the breaker's half-open probe)."""

    def __init__(self, net_name: str, retry_after_s: float):
        super().__init__(
            f"circuit for network {net_name!r} is open after repeated "
            f"backend failures; retry in {retry_after_s:.2f}s")
        self.net_name, self.retry_after_s = net_name, retry_after_s


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Micro-batching + SLA knobs (per-net dispatchers all share one config).

    ``max_batch``    — coalescing ceiling per dispatch.
    ``max_wait_us``  — longest the head request is held for stragglers.
    ``adaptive``     — skip the wait entirely while traffic is solo
                       (EMA of coalesce sizes stays ~1).
    ``shard``        — shard coalesced batches lane-wise across devices when
                       the backend is shardable and >1 device is visible.
    ``max_queue``    — per-net queue bound; ``submit`` past it raises
                       ``QueueFullError`` (None = unbounded, the pre-serving
                       behaviour).
    ``buckets``      — the batch-shape ladder: every coalesced dispatch pads
                       to the smallest rung >= its size, and ``Session``
                       warmup precompiles exactly these shapes.  Defaults to
                       ``perfmodel.bucket_ladder(max_batch)`` (powers of two
                       up to ``max_batch``).  This is the ONE source of truth
                       for batch shapes — mis-shaped ladders (non-monotonic,
                       rungs past ``max_batch``, non-power-of-two rungs while
                       ``adaptive``) fail here at construction, not deep in
                       the dispatcher.
    ``latency_window`` — ring-buffer size for per-request latency samples.
    ``close_timeout_s`` — the no-progress window ``close()`` allows before
                       force-cancelling outstanding futures: as long as the
                       dispatcher keeps completing work the wait continues
                       (a slow drain is not a hang), but a window in which
                       nothing completes means a hung backend — and a hung
                       backend must never leave a caller blocked on
                       ``result()``.

    Fault-tolerance knobs (the supervisor around every launch):

    ``max_retries``  — failed/timed-out launches are retried up to this many
                       times (inputs are still held, so a retry is idempotent
                       by construction); past it the batch's futures resolve
                       with ``BackendFaultError``.
    ``retry_backoff_s`` — base of the exponential backoff between retries
                       (doubles per attempt, with deterministic ±20% jitter).
    ``watchdog_timeout_s`` — absolute per-launch watchdog timeout; ``None``
                       derives it from the cost model instead:
                       ``max(watchdog_floor_s, predicted_batch_ms/1000 *
                       watchdog_mult)``.  The floor is generous because a
                       cold bucket's first launch pays an XLA compile that
                       dwarfs any modeled execution time.
    ``watchdog_mult`` / ``watchdog_floor_s`` — see above.
    ``breaker_threshold`` — consecutive failed launch attempts that trip the
                       net's circuit breaker open (``None`` disables the
                       breaker).  While open, submits fail fast with
                       ``CircuitOpenError`` (HTTP 503 + Retry-After) unless
                       a fallback backend serves degraded traffic.
    ``breaker_reset_s`` — how long the breaker stays open before the next
                       launch runs as a half-open probe of the primary;
                       a successful probe closes the breaker.
    """
    max_batch: int = 8
    max_wait_us: float = 200.0
    adaptive: bool = True
    shard: bool = True
    max_queue: Optional[int] = None
    buckets: Optional[tuple] = None
    latency_window: int = 2048
    close_timeout_s: float = 30.0
    max_retries: int = 2
    retry_backoff_s: float = 0.01
    watchdog_timeout_s: Optional[float] = None
    watchdog_mult: float = 50.0
    watchdog_floor_s: float = 30.0
    breaker_threshold: Optional[int] = 5
    breaker_reset_s: float = 5.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(
                f"SchedulerConfig.max_batch must be >= 1, got {self.max_batch}")
        if self.max_retries < 0:
            raise ValueError(f"SchedulerConfig.max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(f"SchedulerConfig.retry_backoff_s must be >= 0, "
                             f"got {self.retry_backoff_s}")
        if self.watchdog_timeout_s is not None and self.watchdog_timeout_s <= 0:
            raise ValueError(f"SchedulerConfig.watchdog_timeout_s must be "
                             f"> 0 or None, got {self.watchdog_timeout_s}")
        if self.watchdog_floor_s <= 0:
            raise ValueError(f"SchedulerConfig.watchdog_floor_s must be > 0, "
                             f"got {self.watchdog_floor_s}")
        if self.breaker_threshold is not None and self.breaker_threshold < 1:
            raise ValueError(f"SchedulerConfig.breaker_threshold must be "
                             f">= 1 or None, got {self.breaker_threshold}")
        if self.breaker_reset_s <= 0:
            raise ValueError(f"SchedulerConfig.breaker_reset_s must be > 0, "
                             f"got {self.breaker_reset_s}")
        if self.buckets is None:
            object.__setattr__(self, "buckets",
                               perfmodel.bucket_ladder(self.max_batch))
            return
        try:
            bs = tuple(int(b) for b in self.buckets)
        except (TypeError, ValueError):
            raise ValueError(
                f"SchedulerConfig.buckets must be a sequence of ints, got "
                f"{self.buckets!r}") from None
        if not bs or any(b < 1 for b in bs):
            raise ValueError(
                f"SchedulerConfig.buckets must be a non-empty sequence of "
                f"positive batch sizes, got {self.buckets!r}")
        if any(b >= b2 for b, b2 in zip(bs, bs[1:])):
            raise ValueError(
                f"SchedulerConfig.buckets must be strictly increasing "
                f"(each dispatch pads to the smallest rung >= its size), "
                f"got {bs}")
        if bs[-1] > self.max_batch:
            raise ValueError(
                f"SchedulerConfig.buckets rung {bs[-1]} exceeds "
                f"max_batch={self.max_batch} — the dispatcher would pad past "
                f"its own coalescing ceiling")
        if self.adaptive:
            bad = [b for b in bs if b & (b - 1)]
            if bad:
                raise ValueError(
                    f"SchedulerConfig.buckets rungs {bad} are not powers of "
                    f"two; adaptive coalescing assumes the power-of-two "
                    f"compile-once grid (set adaptive=False to use custom "
                    f"rungs)")
        object.__setattr__(self, "buckets", bs)

    def bucket_for(self, n: int) -> int:
        """Smallest ladder rung >= n.  Oversize pre-formed groups (past
        ``max_batch``) still round up to a power of two so batch shapes stay
        drawn from a bounded set."""
        for b in self.buckets:
            if n <= b:
                return b
        return bucket_size(n, self.max_batch)


@dataclasses.dataclass
class _Request:
    net: object                  # the Session's _Net record
    x: np.ndarray
    future: Future
    t_submit: float
    priority: int = 0            # higher = more urgent
    deadline: float = math.inf   # absolute perf_counter() launch deadline
    deadline_us: float = 0.0     # the caller's relative budget (for errors)
    seq: int = 0                 # arrival order (heap tiebreak, FIFO w/in class)
    group_n: int = 1             # size of the submit_many group this came in
                                 # with: a pre-formed batch may exceed
                                 # max_batch and still dispatch as one program
    trace: object = None         # RequestTrace when sampled, else None

    def sort_key(self):
        return (-self.priority, self.deadline, self.seq)


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch for coalesced traffic
    (compile-once shapes); oversize pre-formed groups still round up to a
    power of two so batch shapes stay drawn from a bounded set."""
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch) if n <= max_batch else b


def _resolve_future(future: Future, set_fn, value) -> None:
    """set_result/set_exception tolerant of a concurrent ``cancel()`` from
    ``close()`` — losing that race must not kill the dispatcher thread."""
    if future.cancelled():
        return
    try:
        set_fn(value)
    except InvalidStateError:
        pass                                # cancelled between check and set


def pad_batch(xs: List[np.ndarray], bucket: int) -> np.ndarray:
    """Stack request inputs into a (bucket, ...) batch, zero-padding the tail
    lanes.  Padding changes no live lane's bytes — the batch program is
    lane-independent — so results stay bit-exact."""
    X = np.stack([np.asarray(x) for x in xs])
    if X.shape[0] < bucket:
        pad = np.zeros((bucket - X.shape[0],) + X.shape[1:], X.dtype)
        X = np.concatenate([X, pad])
    return X


class _Launcher:
    """Watchdog-supervised executor calls for one dispatcher.

    A persistent worker thread executes launches so the dispatcher can
    *abandon* one that hangs: ``call`` hands the closure to the worker and
    waits up to ``timeout_s``; past it, the worker is orphaned (it may still
    be blocked inside the backend — a sentinel tells it to exit if it ever
    unblocks) and the next call spawns a fresh worker.  One persistent
    thread, not one per dispatch, so the steady-state cost is a queue
    hand-off + event wait, not thread creation."""

    def __init__(self, name: str):
        self.name = name
        self._q: Optional[queue.SimpleQueue] = None
        self._thread: Optional[threading.Thread] = None

    def call(self, fn, timeout_s: float):
        if self._thread is None or not self._thread.is_alive():
            self._spawn()
        done = threading.Event()
        box: dict = {}
        self._q.put((fn, box, done))
        if not done.wait(timeout_s):
            self._q.put(None)        # exit-if-you-ever-unblock sentinel
            self._thread = None      # abandon; next call gets a fresh worker
            raise LaunchTimeoutError(self.name, timeout_s)
        if "exc" in box:
            raise box["exc"]
        return box["res"]

    def _spawn(self) -> None:
        self._q = q = queue.SimpleQueue()

        def loop():
            while True:
                job = q.get()
                if job is None:
                    return
                fn, box, done = job
                try:
                    box["res"] = fn()
                except BaseException as e:   # noqa: BLE001 — relayed to caller
                    box["exc"] = e
                done.set()

        self._thread = threading.Thread(target=loop,
                                        name=f"repro-exec-{self.name}",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._q.put(None)
            self._thread = None


# circuit-breaker states (per net, owned by its dispatcher)
_CLOSED, _OPEN, _HALF_OPEN = "closed", "open", "half_open"


class _NetDispatcher:
    """One resident network's queue + dispatcher thread.

    The heap orders requests by ``(-priority, deadline, seq)``; the collector
    sheds expired-deadline requests at launch-selection time and admits
    late arrivals into the forming batch until it actually launches.

    Every launch is supervised (``_Launcher`` watchdog + retry with
    exponential backoff), the arena is integrity-checked after failures, and
    a per-net circuit breaker (closed -> open after ``breaker_threshold``
    consecutive failed attempts -> half-open probe after ``breaker_reset_s``)
    sheds fast or routes to the net's fallback executor while open.
    """

    def __init__(self, net, config: SchedulerConfig, scheduler: "Scheduler"):
        self.net = net
        self.config = config
        self.scheduler = scheduler
        # plain Lock (not the default RLock): the condition is hot on submit
        self._cond = threading.Condition(threading.Lock())
        self._heap: List[tuple] = []         # (sort_key, _Request)
        self._thread: Optional[threading.Thread] = None
        self._stop = False                   # exit now, cancel queued
        self._drain = False                  # exit once the queue empties
        self._inflight: List[_Request] = []  # batch currently executing
        self._ema_coalesce = 1.0
        name = getattr(net, "name", "?")
        self._launcher = _Launcher(name)
        self._breaker = _CLOSED              # guarded by _cond
        self._consec_failures = 0
        self._opened_at = 0.0
        self._retry_rng = random.Random(f"repro-retry-{name}")
        self._model_ms: Optional[float] = None   # cost-model batch-1 ms
        self._model_ms_known = False

    def _tel_record(self, latency_us: float, status: str,
                    good: Optional[bool] = None) -> None:
        """Feed the windowed telemetry (every request, unlike the tracer's
        sampled subset).  The telemetry lock is a leaf: safe under _cond."""
        tel = getattr(self.scheduler, "telemetry", None)
        if tel is not None:
            tel.record(getattr(self.net, "name", "?"), latency_us,
                       status=status, good=good)

    # -- client side ---------------------------------------------------------
    def enqueue(self, reqs: List[_Request]) -> None:
        """Admit ``reqs`` (all-or-nothing) and wake the dispatcher if needed.
        Raises ``QueueFullError`` past the configured queue bound."""
        with self._cond:
            if self._stop or self._drain:
                raise RuntimeError("scheduler is closed; create a new Session")
            if self._breaker == _OPEN \
                    and getattr(self.net, "fallback", None) is None:
                # no fallback to absorb traffic: shed fast while open, and
                # let the first submit past the reset window in as the probe
                wait_s = (self._opened_at + self.config.breaker_reset_s
                          - time.perf_counter())
                if wait_s > 0:
                    self.net.stats.note_circuit_reject(len(reqs))
                    for _ in reqs:
                        self._tel_record(0.0, "rejected", good=False)
                    raise CircuitOpenError(getattr(self.net, "name", "?"),
                                           wait_s)
                self._set_breaker(_HALF_OPEN)
            bound = self.config.max_queue
            if bound is not None and len(self._heap) + len(reqs) > bound:
                self.net.stats.note_reject(len(reqs))
                for _ in reqs:
                    self._tel_record(0.0, "rejected", good=False)
                raise QueueFullError(getattr(self.net, "name", "?"),
                                     len(self._heap), bound)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop,
                    name=f"repro-dispatch-{getattr(self.net, 'name', '?')}",
                    daemon=True)
                self._thread.start()
            was_empty = not self._heap
            for r in reqs:
                heapq.heappush(self._heap, (r.sort_key(), r))
            depth = len(self._heap)
            self.net.stats.note_submit(len(reqs), depth)
            # wake the dispatcher only on the transitions it acts on — queue
            # went non-empty, or a full batch is now available.  Intermediate
            # submits land silently (the dispatcher's hold-wait re-checks on
            # wake or deadline), avoiding a context switch per request.
            if was_empty or depth >= self.config.max_batch:
                self._cond.notify()

    def queue_depth(self) -> int:
        with self._cond:
            return len(self._heap)

    def circuit_state(self) -> str:
        """``closed`` / ``open`` / ``half_open`` (``Session.health`` input)."""
        with self._cond:
            return self._breaker

    def close(self, drain: bool = False) -> None:
        """Stop the dispatcher.  ``drain=False`` cancels queued requests
        immediately; ``drain=True`` lets the queue empty first.  Either way,
        every future this dispatcher ever accepted is resolved when this
        returns: results for dispatched work, ``CancelledError`` for
        cancelled work — a caller blocked in ``Future.result()`` always
        wakes up, even if the backend hangs (``close_timeout_s``)."""
        with self._cond:
            pending: List[_Request] = []
            if drain:
                self._drain = True
            else:
                self._stop = True
                pending = [r for _, r in self._heap]
                self._heap.clear()
            self._cond.notify_all()
        for req in pending:
            req.future.cancel()
        thread = self._thread
        if thread is not None:
            # the timeout guards a HUNG backend, not a slow drain: keep
            # waiting as long as the dispatcher is making progress, and
            # fall through to force-cancel after ONE full window in which
            # nothing completed
            with self._cond:
                last_remaining = len(self._heap) + len(self._inflight)
            while True:
                thread.join(timeout=self.config.close_timeout_s)
                if not thread.is_alive():
                    break
                with self._cond:
                    remaining = len(self._heap) + len(self._inflight)
                if remaining >= last_remaining:
                    break
                last_remaining = remaining
        with self._cond:
            self._stop = True                # drain path: no further batches
            self._cond.notify_all()
            leftovers = [r for _, r in self._heap] + list(self._inflight)
            self._heap.clear()
        for req in leftovers:
            # join timed out (hung backend) or drain left stragglers: never
            # leave a caller blocked forever on result()
            req.future.cancel()

    # -- dispatcher side -----------------------------------------------------
    def _batch_cap(self, head: _Request) -> int:
        # a pre-formed submit_many group dispatches whole even past the
        # config cap, but a backend's declared hard ceiling always wins
        cap = max(self.config.max_batch, head.group_n)
        try:
            backend_max = self.net.executor.capabilities().max_batch
        except Exception:
            backend_max = None
        if backend_max is not None:
            cap = min(cap, backend_max)
        return max(cap, 1)

    @staticmethod
    def _compatible(head: _Request, r: _Request) -> bool:
        """Requests may share a dispatch when their input dtypes match (int8
        lanes pass through quantisation; stacking them with float32 lanes
        would promote the batch and re-quantise them — wrong bytes).  Same
        net is implied: this dispatcher serves exactly one network."""
        return getattr(r.x, "dtype", None) == getattr(head.x, "dtype", None)

    def _shed(self, req: _Request, now: float) -> None:
        self.net.stats.note_shed(1)
        self._tel_record((now - req.t_submit) * 1e6, "shed", good=False)
        if req.trace is not None:
            req.trace.add_span("queue", req.t_submit, now)
            req.trace.event("shed", deadline_us=req.deadline_us,
                            waited_us=(now - req.t_submit) * 1e6)
        _resolve_future(req.future, req.future.set_exception,
                        DeadlineExceededError(
                            getattr(self.net, "name", "?"), req.deadline_us,
                            (now - req.t_submit) * 1e6))

    def _collect(self) -> Optional[List[_Request]]:
        """Block for the next batch: best-(priority, deadline) head plus
        compatible stragglers, shedding expired-deadline requests.

        Queued requests stay on the heap during the hold so the producer-side
        full-batch wake-up keeps seeing the true depth, and so late arrivals
        (including higher-priority ones, which displace the head) join the
        forming batch right up to launch; the hold ends when a full batch is
        available or the head has waited ``max_wait_us``.  Returns ``None``
        to stop, ``[]`` when a pass shed everything it popped.
        """
        cfg = self.config
        expired: List[_Request] = []
        try:
            with self._cond:
                while not self._heap:
                    if self._stop or self._drain:
                        return None
                    self._cond.wait()
                if self._stop:
                    return None
                head = self._heap[0][1]
                cap = self._batch_cap(head)
                hold = (not self._drain
                        and (not cfg.adaptive
                             or self._ema_coalesce > _COALESCE_THRESHOLD))
                t_hold0 = t_hold1 = 0.0
                if hold:
                    t_hold0 = time.perf_counter()
                    deadline = head.t_submit + cfg.max_wait_us * 1e-6
                    while not self._stop:
                        same = sum(1 for _, r in self._heap
                                   if self._compatible(head, r))
                        if same >= cap:
                            break
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0:
                            break
                        self._cond.wait(remaining)
                    t_hold1 = time.perf_counter()
                if self._stop:
                    return None
                # launch: pop in (priority, deadline) order; shed expired,
                # push dtype-incompatible requests back for the next pass
                now = time.perf_counter()
                head = self._heap[0][1]        # may have changed during hold
                cap = self._batch_cap(head)
                batch: List[_Request] = []
                putback: List[tuple] = []
                while self._heap and len(batch) < cap:
                    _, r = heapq.heappop(self._heap)
                    if r.deadline < now:
                        expired.append(r)
                    elif self._compatible(head, r):
                        batch.append(r)
                    else:
                        putback.append((r.sort_key(), r))
                for item in putback:
                    heapq.heappush(self._heap, item)
                self._inflight = list(batch)
                for r in batch:
                    if r.trace is not None:
                        r.trace.add_span("queue", r.t_submit, now,
                                         coalesced=len(batch))
                        if t_hold1 > t_hold0:
                            # clamp: a late arrival joined mid-hold, its
                            # wait started at its own submit
                            r.trace.add_span("hold",
                                             max(t_hold0, r.t_submit),
                                             t_hold1)
            return batch
        finally:
            # resolve shed futures outside the lock (done-callbacks may run)
            now = time.perf_counter()
            for r in expired:
                self._shed(r, now)

    # -- supervision ---------------------------------------------------------
    def _set_breaker(self, state: str) -> None:
        """Transition the breaker (``_cond`` held) and mirror it to stats."""
        if state == self._breaker:
            return
        self._breaker = state
        if state == _OPEN:
            self._opened_at = time.perf_counter()
        self.net.stats.note_circuit(state)
        tracer = getattr(self.scheduler, "tracer", None)
        if tracer is not None:      # tracer lock takes no scheduler locks
            tracer.note_circuit(getattr(self.net, "name", "?"), state)

    def force_open(self) -> None:
        """Externally trip the breaker open (the SLO engine's breach
        trigger).  Identical downstream behavior to a failure-driven open:
        fallback routing (or fast sheds) while open, half-open probe after
        ``breaker_reset_s`` — so the breaker self-heals, and a persisting
        breach simply re-trips it on the next evaluation."""
        with self._cond:
            if self._breaker != _OPEN:
                self._set_breaker(_OPEN)

    def _route(self) -> tuple:
        """``(executor, degraded)`` for the next launch attempt.  While the
        breaker is open, traffic routes to the net's fallback executor
        (degraded) — except once per ``breaker_reset_s`` window, when the
        primary gets a half-open probe; a closed/half-open breaker always
        routes primary."""
        with self._cond:
            if self._breaker == _OPEN:
                if (time.perf_counter() - self._opened_at
                        >= self.config.breaker_reset_s):
                    self._set_breaker(_HALF_OPEN)   # this launch is the probe
                    return self.net.executor, False
                fb = getattr(self.net, "fallback", None)
                if fb is not None:
                    return fb, True
            return self.net.executor, False

    def _note_launch_failure(self, ex, degraded: bool, exc) -> bool:
        """Record one failed attempt; returns whether the arena was reset
        (the dispatcher mirrors that onto the affected traces)."""
        stats = self.net.stats
        stats.note_failure(timeout=isinstance(exc, LaunchTimeoutError))
        # a crashed call may have scribbled on the resident arena: verify the
        # preload checksum and restore the pristine image before any retry
        reset = False
        try:
            if hasattr(ex, "arena_ok") and not ex.arena_ok():
                ex.reset_arena()
                stats.note_arena_reset()
                reset = True
        except Exception:        # noqa: BLE001 — never mask the real failure
            pass
        if degraded:
            return reset         # fallback failures don't drive the breaker
        with self._cond:
            self._consec_failures += 1
            bt = self.config.breaker_threshold
            if self._breaker == _HALF_OPEN:
                self._set_breaker(_OPEN)            # failed probe: reopen
            elif self._breaker == _CLOSED and bt is not None \
                    and self._consec_failures >= bt:
                self._set_breaker(_OPEN)
        return reset

    def _note_launch_success(self, degraded: bool) -> None:
        if degraded:
            return               # fallback health says nothing about primary
        with self._cond:
            self._consec_failures = 0
            if self._breaker != _CLOSED:
                self._set_breaker(_CLOSED)          # successful probe

    def _sync_fault_counter(self) -> None:
        n = getattr(self.net.executor, "faults_injected", None)
        if n is not None:
            self.net.stats.note_faults(n)

    def _launch_timeout_s(self, bucket: int) -> float:
        """Watchdog budget for one launch: the absolute override, or the
        cost model's predicted batch time x ``watchdog_mult``, floored
        generously (a cold bucket's first launch pays an XLA compile)."""
        cfg = self.config
        if cfg.watchdog_timeout_s is not None:
            return cfg.watchdog_timeout_s
        if not self._model_ms_known:
            self._model_ms_known = True
            try:
                ex = self.net.executor
                cycles = sum(perfmodel.descriptor_cost(d, ex.cfg).cycles
                             for d in ex.descs)
                self._model_ms = ex.cfg.cycles_to_ms(cycles)
            except Exception:    # stub/opaque backends: floor only
                self._model_ms = None
        if not self._model_ms:
            return cfg.watchdog_floor_s
        return max(cfg.watchdog_floor_s,
                   self._model_ms * 1e-3 * bucket * cfg.watchdog_mult)

    def _backoff_s(self, attempt: int) -> float:
        """Exponential backoff with deterministic ±20% jitter: monotonically
        increasing per attempt (2x base always beats +20% jitter)."""
        base = self.config.retry_backoff_s * (2 ** (attempt - 1))
        return base * self._retry_rng.uniform(0.8, 1.2)

    def _launch(self, ex, batch: List[_Request], attempt: int = 1,
                degraded: bool = False) -> tuple:
        """One supervised execution attempt -> ``(outs, bucket, compiles)``.

        Traced requests get a ``device_execute`` span timed inside the
        launcher worker (bounded by the backend's own blocking), and when a
        sampled request asked for per-layer profiling on a profileable
        backend the launch runs the executor's profiled path and attaches
        the kernel samples to the trace."""
        k = len(batch)
        bucket = 1
        compiles0 = getattr(ex, "compile_count", 0)
        caps = ex.capabilities()
        traced = [r for r in batch if r.trace is not None]
        profiled = bool(traced) and caps.profileable \
            and any(r.trace.profile for r in traced)
        if k == 1:
            x = batch[0].x
            run1 = ex.run_profiled if profiled else ex.run

            def call():
                t0 = time.perf_counter()
                res = run1(x)
                return res, t0, time.perf_counter()
        else:
            # bucket-pad only for native batch programs (compile-once
            # shapes); sequential fallbacks would just discard the pad.
            # The backend's declared hard ceiling bounds even the padded
            # shape (a non-power-of-two ceiling beats a ladder rung).
            bucket = (self.config.bucket_for(k)
                      if caps.native_batching else k)
            if caps.max_batch is not None:
                bucket = min(bucket, caps.max_batch)
            tp0 = time.perf_counter()
            padded = pad_batch([r.x for r in batch], bucket)
            tp1 = time.perf_counter()
            for r in traced:
                r.trace.add_span("pad", tp0, tp1, bucket=bucket, lanes=k)
            if caps.shardable:
                ex.batch_sharding = self.scheduler._lane_sharding(bucket)
            runk = ex.run_batch_profiled if profiled else ex.run_batch

            def call():
                t0 = time.perf_counter()
                res = runk(padded, lanes=k)
                return res, t0, time.perf_counter()
        res, t0, t1 = self._launcher.call(call, self._launch_timeout_s(bucket))
        layers = None
        if profiled:
            res, layers = res
        for r in traced:
            r.trace.add_span("device_execute", t0, t1, bucket=bucket,
                             lanes=k, attempt=attempt, degraded=degraded,
                             profiled=profiled)
            if layers and r.trace.profile:
                r.trace.add_layers(layers)
        if k == 1:
            outs = [res]
        else:
            outs = [ExecResult(output_int8=res.output_int8[i],
                               output=res.output[i]) for i in range(k)]
        return outs, bucket, getattr(ex, "compile_count", 0) - compiles0

    def _dispatch(self, batch: List[_Request]) -> None:
        net = self.net
        attempt = 1
        traced = [r for r in batch if r.trace is not None]
        while True:
            ex, degraded = self._route()
            try:
                outs, bucket, compiles = self._launch(ex, batch, attempt,
                                                      degraded)
            except BaseException as e:  # noqa: BLE001 — forwarded to callers
                reset = self._note_launch_failure(ex, degraded, e)
                self._sync_fault_counter()
                for r in traced:
                    r.trace.event("launch_failure", attempt=attempt,
                                  error=type(e).__name__, degraded=degraded)
                    if isinstance(e, LaunchTimeoutError):
                        r.trace.event("watchdog_fire",
                                      timeout_s=e.timeout_s)
                    if reset:
                        r.trace.event("arena_reset")
                with self._cond:
                    stopping = self._stop
                if attempt <= self.config.max_retries and not stopping:
                    # the inputs are still held, so a retry is idempotent;
                    # an open breaker reroutes the retry to the fallback
                    net.stats.note_retry()
                    tb0 = time.perf_counter()
                    time.sleep(self._backoff_s(attempt))
                    tb1 = time.perf_counter()
                    for r in traced:
                        r.trace.add_span("backoff", tb0, tb1,
                                         attempt=attempt)
                    attempt += 1
                    continue
                err = BackendFaultError(getattr(net, "name", "?"), attempt, e)
                err.__cause__ = e
                now = time.perf_counter()
                for r in batch:
                    self._tel_record((now - r.t_submit) * 1e6, "error",
                                     good=False)
                    _resolve_future(r.future, r.future.set_exception, err)
                return
            self._note_launch_success(degraded)
            self._sync_fault_counter()
            k = len(batch)
            done = time.perf_counter()
            net.stats.note_dispatch(
                k, [(done - r.t_submit) * 1e6 for r in batch], bucket=bucket,
                compiles=compiles, degraded=k if degraded else 0)
            if degraded:
                outs = [dataclasses.replace(o, degraded=True) for o in outs]
            for r in batch:
                lat_us = (done - r.t_submit) * 1e6
                self._tel_record(lat_us, "degraded" if degraded else "ok",
                                 good=(not r.deadline_us
                                       or lat_us <= r.deadline_us))
            for r, out in zip(batch, outs):
                if r.trace is not None:
                    # recorded before set_result: resolving the future runs
                    # the done-callback that seals this trace
                    r.trace.add_span("respond", done, time.perf_counter())
                _resolve_future(r.future, r.future.set_result, out)
            self._ema_coalesce = ((1 - _EMA_ALPHA) * self._ema_coalesce
                                  + _EMA_ALPHA * k)
            return

    def _loop(self) -> None:
        try:
            while True:
                batch = self._collect()
                if batch is None:
                    return
                if batch:
                    self._dispatch(batch)
                with self._cond:
                    self._inflight = []
        finally:
            self._launcher.stop()


class Scheduler:
    """Per-net dispatcher threads behind a ``Session``.

    Each resident network owns an independent queue and dispatcher thread
    (created lazily on its first submit), so traffic for one net never
    head-of-line blocks another's.  The public surface is unchanged from the
    single-dispatcher era — ``submit`` / ``submit_many`` / ``queue_depth`` /
    ``close`` — plus per-request ``priority`` and ``deadline_us``.
    """

    def __init__(self, config: Optional[SchedulerConfig] = None, tracer=None,
                 telemetry=None):
        self.config = config or SchedulerConfig()
        self.tracer = tracer            # repro.obs Tracer, or None (untraced)
        self.telemetry = telemetry      # repro.obs Telemetry, or None
        self._lock = threading.Lock()
        self._dispatchers: Dict[int, _NetDispatcher] = {}
        self._retired: Dict[int, object] = {}   # unloaded nets, by id
        self._closed = False
        self._seq = itertools.count()
        self._mesh = None
        self._mesh_checked = False

    # -- client side ---------------------------------------------------------
    def submit(self, net, x: np.ndarray, priority: int = 0,
               deadline_us: Optional[float] = None,
               trace_id: Optional[str] = None) -> Future:
        """Enqueue one request against resident network ``net``."""
        return self.submit_many(net, [x], priority=priority,
                                deadline_us=deadline_us, trace_id=trace_id)[0]

    def submit_many(self, net, xs, priority: int = 0,
                    deadline_us: Optional[float] = None,
                    trace_id: Optional[str] = None) -> List[Future]:
        """Enqueue several requests atomically (one lock hold, one wake-up),
        so a pre-formed batch reaches the dispatcher whole instead of being
        peeled off a request at a time.  When the group reaches the head of
        the queue it may exceed ``max_batch`` and still dispatch as one
        program (explicit ``run_batch`` callers keep the single-program
        semantics; the cap bounds *coalescing* of independent submits).
        Under mixed traffic a group queued behind other requests can split
        across dispatches — results stay bit-exact either way, and batch
        shapes stay on the power-of-two bucket grid.

        ``priority`` (higher = more urgent) and ``deadline_us`` (relative
        latency budget; past it the request is shed with
        ``DeadlineExceededError``) order the per-net queue.  Raises
        ``QueueFullError`` when the net's queue is at ``max_queue``.

        Every returned future carries ``fut.trace_id`` when a tracer is
        attached; ``trace_id`` (applied to the group's first request)
        forces that request into the sampled set.
        """
        if deadline_us is not None and math.isnan(deadline_us):
            raise ValueError("deadline_us must not be NaN (a NaN sort key "
                             "would corrupt the EDF queue order)")
        now = time.perf_counter()
        # deadline_us=0 means an already-expired budget (shed at launch),
        # NOT "no deadline" — only None/inf disable the deadline entirely
        dl = now + deadline_us * 1e-6 if deadline_us is not None else math.inf
        tracer = self.tracer
        reqs = []
        for i, x in enumerate(xs):
            r = _Request(net=net, x=x, future=Future(), t_submit=now,
                         priority=priority, deadline=dl,
                         deadline_us=deadline_us or 0.0,
                         seq=next(self._seq), group_n=len(xs))
            if tracer is not None:
                tid, trace = tracer.start(getattr(net, "name", "?"),
                                          trace_id if i == 0 else None,
                                          t_start=now)
                r.future.trace_id = tid
                if trace is not None:
                    r.trace = trace
                    # the future's terminal state — result, exception or
                    # cancel, whichever path delivers it — completes the
                    # trace exactly once
                    r.future.add_done_callback(
                        functools.partial(tracer.finish_future, trace))
            reqs.append(r)
        try:
            self._dispatcher(net).enqueue(reqs)
        except BaseException as e:
            # rejected at admission (queue full / circuit open / closed):
            # the futures never resolve, so complete the traces here and
            # pin the (first) trace id on the exception for error replies
            if tracer is not None:
                for r in reqs:
                    tracer.finish(r.trace, status=status_for_exception(e),
                                  error=type(e).__name__)
                if reqs:
                    e.trace_id = getattr(reqs[0].future, "trace_id", None)
            raise
        return [r.future for r in reqs]

    def queue_depth(self, net=None) -> int:
        """Queued (not in-flight) requests: one net's, or all nets' summed."""
        with self._lock:
            ds = list(self._dispatchers.values())
        return sum(d.queue_depth() for d in ds
                   if net is None or d.net is net)

    def circuit_state(self, net) -> str:
        """The net's circuit-breaker state: ``closed`` (healthy), ``open``
        (shedding / serving fallback), or ``half_open`` (probing the
        primary).  A net that never dispatched is ``closed``."""
        with self._lock:
            d = self._dispatchers.get(id(net))
        return d.circuit_state() if d is not None else _CLOSED

    def trip_circuit(self, net) -> bool:
        """Force the net's breaker open (the SLO engine's breach trigger).
        Returns False for a net with no dispatcher yet — no traffic means
        nothing to protect."""
        with self._lock:
            d = self._dispatchers.get(id(net))
        if d is None:
            return False
        d.force_open()
        return True

    def close(self, drain: bool = False) -> None:
        """Stop every dispatcher.  ``drain=False`` (default): queued requests
        get ``CancelledError``, the in-flight batch finishes; ``drain=True``:
        queued work completes first.  Every future ever returned by
        ``submit`` is resolved when this returns."""
        with self._lock:
            self._closed = True
            ds = list(self._dispatchers.values())
        for d in ds:
            d.close(drain=drain)

    def close_net(self, net, drain: bool = True) -> None:
        """Stop one net's dispatcher (Session.unload / replace) — without
        this its idle thread would outlive the net's residency.  The net is
        remembered as retired so a racing ``submit`` that already resolved
        it cannot silently respawn a dispatcher for a dead executor."""
        with self._lock:
            d = self._dispatchers.pop(id(net), None)
            self._retired[id(net)] = net    # hold the ref: id() stays unique
        if d is not None:
            d.close(drain=drain)

    # -- internals -----------------------------------------------------------
    def _dispatcher(self, net) -> _NetDispatcher:
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed; create a new Session")
            if id(net) in self._retired:
                raise RuntimeError(
                    f"network {getattr(net, 'name', '?')!r} was unloaded")
            d = self._dispatchers.get(id(net))
            if d is None:
                d = _NetDispatcher(net, self.config, self)
                self._dispatchers[id(net)] = d
            return d

    def _lane_sharding(self, lanes_padded: int):
        """NamedSharding for a shardable batch, or None.  Called from
        dispatcher threads; the mesh probe is cached after the first call."""
        if not self.config.shard:
            return None
        with self._lock:
            if not self._mesh_checked:
                from repro.distributed import sharding as shard_mod
                self._mesh = shard_mod.serving_mesh()
                self._mesh_checked = True
            mesh = self._mesh
        if mesh is None or lanes_padded % mesh.size != 0:
            return None
        from repro.distributed import sharding as shard_mod
        return shard_mod.lane_sharding(mesh)
