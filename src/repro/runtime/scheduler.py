"""Async serving scheduler: per-net dispatchers + SLA-aware micro-batching.

``Session.submit(x)`` enqueues one inference request and returns a
``concurrent.futures.Future``.  Every resident network gets its **own
dispatcher thread and queue** (a slow ResNet batch can never head-of-line
block LeNet traffic); each dispatcher drains its queue, coalesces compatible
requests into one batch, pads it to a power-of-two bucket (so each batch
shape compiles exactly once), executes it through the backend's
``run_batch(padded, lanes)``, and resolves each future with its lane's
``ExecResult`` — bit-exact versus running every request through sequential
``run`` calls, because the batch program itself is bit-exact and padding
lanes are sliced off before anyone sees them.

**SLA-aware ordering.**  Requests carry ``priority`` (higher = more urgent)
and an optional ``deadline_us`` latency budget.  The queue is a heap ordered
by ``(-priority, deadline, arrival)``: urgent traffic launches first, and
within a priority class the tightest deadline wins (EDF).  A request whose
deadline has already passed when the dispatcher would launch it is **shed**
— its future fails fast with :class:`DeadlineExceededError` instead of
burning a batch slot on an answer nobody wants.

**Continuous batching.**  The collector holds a forming batch open (up to
``max_wait_us``) and admits late-arriving compatible requests right up to
launch; after the hold it re-reads the queue head, so a high-priority
arrival during the hold window leads the very next dispatch.

**Admission control.**  ``SchedulerConfig.max_queue`` bounds each net's
queue; past it, ``submit`` fails fast with :class:`QueueFullError` (the HTTP
front-end maps it to 429) instead of growing the queue without bound.

Micro-batching is *adaptive*: each dispatcher tracks an EMA of recent
coalesce sizes.  Under solo traffic (EMA ~ 1) it dispatches immediately —
waiting would only add latency; once concurrency is observed it holds the
head request up to ``max_wait_us`` to let the batch fill towards
``max_batch``.

When several devices are visible and the backend reports
``capabilities().shardable``, a coalesced batch whose bucket divides the
device count is dispatched with its lane axis sharded over a 1-axis data
mesh (``repro.distributed.sharding.serving_mesh``); GSPMD splits the vmapped
program across devices and replicates the resident weight arena.

Padding and lane masking live HERE, not in executors: backends receive an
already-padded batch plus the live-lane count and stay policy-free.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, List, Optional

import numpy as np

from repro.core import perfmodel
from repro.core.executor import ExecResult

# EMA of coalesce sizes above which a dispatcher starts holding the head
# request for stragglers (below it, traffic is effectively solo).
_COALESCE_THRESHOLD = 1.25
_EMA_ALPHA = 0.2


class QueueFullError(RuntimeError):
    """Admission control: the target net's queue is at ``max_queue``.

    Raised synchronously by ``submit`` — the request was never enqueued.
    The HTTP front-end maps this to ``429 Too Many Requests``.
    """

    def __init__(self, net_name: str, depth: int, bound: int):
        super().__init__(
            f"queue for network {net_name!r} is full "
            f"({depth}/{bound} queued); retry later or raise "
            f"SchedulerConfig.max_queue")
        self.net_name, self.depth, self.bound = net_name, depth, bound


class DeadlineExceededError(RuntimeError):
    """The request's ``deadline_us`` budget elapsed before launch; it was
    shed by the collector and never executed.  Delivered through the
    request's future."""

    def __init__(self, net_name: str, deadline_us: float, waited_us: float):
        super().__init__(
            f"request for network {net_name!r} shed: deadline_us="
            f"{deadline_us:.0f} elapsed after {waited_us:.0f}us in queue")
        self.net_name = net_name
        self.deadline_us, self.waited_us = deadline_us, waited_us


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Micro-batching + SLA knobs (per-net dispatchers all share one config).

    ``max_batch``    — coalescing ceiling per dispatch.
    ``max_wait_us``  — longest the head request is held for stragglers.
    ``adaptive``     — skip the wait entirely while traffic is solo
                       (EMA of coalesce sizes stays ~1).
    ``shard``        — shard coalesced batches lane-wise across devices when
                       the backend is shardable and >1 device is visible.
    ``max_queue``    — per-net queue bound; ``submit`` past it raises
                       ``QueueFullError`` (None = unbounded, the pre-serving
                       behaviour).
    ``buckets``      — the batch-shape ladder: every coalesced dispatch pads
                       to the smallest rung >= its size, and ``Session``
                       warmup precompiles exactly these shapes.  Defaults to
                       ``perfmodel.bucket_ladder(max_batch)`` (powers of two
                       up to ``max_batch``).  This is the ONE source of truth
                       for batch shapes — mis-shaped ladders (non-monotonic,
                       rungs past ``max_batch``, non-power-of-two rungs while
                       ``adaptive``) fail here at construction, not deep in
                       the dispatcher.
    ``latency_window`` — ring-buffer size for per-request latency samples.
    ``close_timeout_s`` — the no-progress window ``close()`` allows before
                       force-cancelling outstanding futures: as long as the
                       dispatcher keeps completing work the wait continues
                       (a slow drain is not a hang), but a window in which
                       nothing completes means a hung backend — and a hung
                       backend must never leave a caller blocked on
                       ``result()``.
    """
    max_batch: int = 8
    max_wait_us: float = 200.0
    adaptive: bool = True
    shard: bool = True
    max_queue: Optional[int] = None
    buckets: Optional[tuple] = None
    latency_window: int = 2048
    close_timeout_s: float = 30.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(
                f"SchedulerConfig.max_batch must be >= 1, got {self.max_batch}")
        if self.buckets is None:
            object.__setattr__(self, "buckets",
                               perfmodel.bucket_ladder(self.max_batch))
            return
        try:
            bs = tuple(int(b) for b in self.buckets)
        except (TypeError, ValueError):
            raise ValueError(
                f"SchedulerConfig.buckets must be a sequence of ints, got "
                f"{self.buckets!r}") from None
        if not bs or any(b < 1 for b in bs):
            raise ValueError(
                f"SchedulerConfig.buckets must be a non-empty sequence of "
                f"positive batch sizes, got {self.buckets!r}")
        if any(b >= b2 for b, b2 in zip(bs, bs[1:])):
            raise ValueError(
                f"SchedulerConfig.buckets must be strictly increasing "
                f"(each dispatch pads to the smallest rung >= its size), "
                f"got {bs}")
        if bs[-1] > self.max_batch:
            raise ValueError(
                f"SchedulerConfig.buckets rung {bs[-1]} exceeds "
                f"max_batch={self.max_batch} — the dispatcher would pad past "
                f"its own coalescing ceiling")
        if self.adaptive:
            bad = [b for b in bs if b & (b - 1)]
            if bad:
                raise ValueError(
                    f"SchedulerConfig.buckets rungs {bad} are not powers of "
                    f"two; adaptive coalescing assumes the power-of-two "
                    f"compile-once grid (set adaptive=False to use custom "
                    f"rungs)")
        object.__setattr__(self, "buckets", bs)

    def bucket_for(self, n: int) -> int:
        """Smallest ladder rung >= n.  Oversize pre-formed groups (past
        ``max_batch``) still round up to a power of two so batch shapes stay
        drawn from a bounded set."""
        for b in self.buckets:
            if n <= b:
                return b
        return bucket_size(n, self.max_batch)


@dataclasses.dataclass
class _Request:
    net: object                  # the Session's _Net record
    x: np.ndarray
    future: Future
    t_submit: float
    priority: int = 0            # higher = more urgent
    deadline: float = math.inf   # absolute perf_counter() launch deadline
    deadline_us: float = 0.0     # the caller's relative budget (for errors)
    seq: int = 0                 # arrival order (heap tiebreak, FIFO w/in class)
    group_n: int = 1             # size of the submit_many group this came in
                                 # with: a pre-formed batch may exceed
                                 # max_batch and still dispatch as one program

    def sort_key(self):
        return (-self.priority, self.deadline, self.seq)


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch for coalesced traffic
    (compile-once shapes); oversize pre-formed groups still round up to a
    power of two so batch shapes stay drawn from a bounded set."""
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch) if n <= max_batch else b


def _resolve_future(future: Future, set_fn, value) -> None:
    """set_result/set_exception tolerant of a concurrent ``cancel()`` from
    ``close()`` — losing that race must not kill the dispatcher thread."""
    if future.cancelled():
        return
    try:
        set_fn(value)
    except InvalidStateError:
        pass                                # cancelled between check and set


def pad_batch(xs: List[np.ndarray], bucket: int) -> np.ndarray:
    """Stack request inputs into a (bucket, ...) batch, zero-padding the tail
    lanes.  Padding changes no live lane's bytes — the batch program is
    lane-independent — so results stay bit-exact."""
    X = np.stack([np.asarray(x) for x in xs])
    if X.shape[0] < bucket:
        pad = np.zeros((bucket - X.shape[0],) + X.shape[1:], X.dtype)
        X = np.concatenate([X, pad])
    return X


class _NetDispatcher:
    """One resident network's queue + dispatcher thread.

    The heap orders requests by ``(-priority, deadline, seq)``; the collector
    sheds expired-deadline requests at launch-selection time and admits
    late arrivals into the forming batch until it actually launches.
    """

    def __init__(self, net, config: SchedulerConfig, scheduler: "Scheduler"):
        self.net = net
        self.config = config
        self.scheduler = scheduler
        # plain Lock (not the default RLock): the condition is hot on submit
        self._cond = threading.Condition(threading.Lock())
        self._heap: List[tuple] = []         # (sort_key, _Request)
        self._thread: Optional[threading.Thread] = None
        self._stop = False                   # exit now, cancel queued
        self._drain = False                  # exit once the queue empties
        self._inflight: List[_Request] = []  # batch currently executing
        self._ema_coalesce = 1.0

    # -- client side ---------------------------------------------------------
    def enqueue(self, reqs: List[_Request]) -> None:
        """Admit ``reqs`` (all-or-nothing) and wake the dispatcher if needed.
        Raises ``QueueFullError`` past the configured queue bound."""
        with self._cond:
            if self._stop or self._drain:
                raise RuntimeError("scheduler is closed; create a new Session")
            bound = self.config.max_queue
            if bound is not None and len(self._heap) + len(reqs) > bound:
                self.net.stats.note_reject(len(reqs))
                raise QueueFullError(getattr(self.net, "name", "?"),
                                     len(self._heap), bound)
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop,
                    name=f"repro-dispatch-{getattr(self.net, 'name', '?')}",
                    daemon=True)
                self._thread.start()
            was_empty = not self._heap
            for r in reqs:
                heapq.heappush(self._heap, (r.sort_key(), r))
            depth = len(self._heap)
            self.net.stats.note_submit(len(reqs), depth)
            # wake the dispatcher only on the transitions it acts on — queue
            # went non-empty, or a full batch is now available.  Intermediate
            # submits land silently (the dispatcher's hold-wait re-checks on
            # wake or deadline), avoiding a context switch per request.
            if was_empty or depth >= self.config.max_batch:
                self._cond.notify()

    def queue_depth(self) -> int:
        with self._cond:
            return len(self._heap)

    def close(self, drain: bool = False) -> None:
        """Stop the dispatcher.  ``drain=False`` cancels queued requests
        immediately; ``drain=True`` lets the queue empty first.  Either way,
        every future this dispatcher ever accepted is resolved when this
        returns: results for dispatched work, ``CancelledError`` for
        cancelled work — a caller blocked in ``Future.result()`` always
        wakes up, even if the backend hangs (``close_timeout_s``)."""
        with self._cond:
            pending: List[_Request] = []
            if drain:
                self._drain = True
            else:
                self._stop = True
                pending = [r for _, r in self._heap]
                self._heap.clear()
            self._cond.notify_all()
        for req in pending:
            req.future.cancel()
        thread = self._thread
        if thread is not None:
            # the timeout guards a HUNG backend, not a slow drain: keep
            # waiting as long as the dispatcher is making progress, and
            # fall through to force-cancel after ONE full window in which
            # nothing completed
            with self._cond:
                last_remaining = len(self._heap) + len(self._inflight)
            while True:
                thread.join(timeout=self.config.close_timeout_s)
                if not thread.is_alive():
                    break
                with self._cond:
                    remaining = len(self._heap) + len(self._inflight)
                if remaining >= last_remaining:
                    break
                last_remaining = remaining
        with self._cond:
            self._stop = True                # drain path: no further batches
            self._cond.notify_all()
            leftovers = [r for _, r in self._heap] + list(self._inflight)
            self._heap.clear()
        for req in leftovers:
            # join timed out (hung backend) or drain left stragglers: never
            # leave a caller blocked forever on result()
            req.future.cancel()

    # -- dispatcher side -----------------------------------------------------
    def _batch_cap(self, head: _Request) -> int:
        # a pre-formed submit_many group dispatches whole even past the
        # config cap, but a backend's declared hard ceiling always wins
        cap = max(self.config.max_batch, head.group_n)
        try:
            backend_max = self.net.executor.capabilities().max_batch
        except Exception:
            backend_max = None
        if backend_max is not None:
            cap = min(cap, backend_max)
        return max(cap, 1)

    @staticmethod
    def _compatible(head: _Request, r: _Request) -> bool:
        """Requests may share a dispatch when their input dtypes match (int8
        lanes pass through quantisation; stacking them with float32 lanes
        would promote the batch and re-quantise them — wrong bytes).  Same
        net is implied: this dispatcher serves exactly one network."""
        return getattr(r.x, "dtype", None) == getattr(head.x, "dtype", None)

    def _shed(self, req: _Request, now: float) -> None:
        self.net.stats.note_shed(1)
        _resolve_future(req.future, req.future.set_exception,
                        DeadlineExceededError(
                            getattr(self.net, "name", "?"), req.deadline_us,
                            (now - req.t_submit) * 1e6))

    def _collect(self) -> Optional[List[_Request]]:
        """Block for the next batch: best-(priority, deadline) head plus
        compatible stragglers, shedding expired-deadline requests.

        Queued requests stay on the heap during the hold so the producer-side
        full-batch wake-up keeps seeing the true depth, and so late arrivals
        (including higher-priority ones, which displace the head) join the
        forming batch right up to launch; the hold ends when a full batch is
        available or the head has waited ``max_wait_us``.  Returns ``None``
        to stop, ``[]`` when a pass shed everything it popped.
        """
        cfg = self.config
        expired: List[_Request] = []
        try:
            with self._cond:
                while not self._heap:
                    if self._stop or self._drain:
                        return None
                    self._cond.wait()
                if self._stop:
                    return None
                head = self._heap[0][1]
                cap = self._batch_cap(head)
                hold = (not self._drain
                        and (not cfg.adaptive
                             or self._ema_coalesce > _COALESCE_THRESHOLD))
                if hold:
                    deadline = head.t_submit + cfg.max_wait_us * 1e-6
                    while not self._stop:
                        same = sum(1 for _, r in self._heap
                                   if self._compatible(head, r))
                        if same >= cap:
                            break
                        remaining = deadline - time.perf_counter()
                        if remaining <= 0:
                            break
                        self._cond.wait(remaining)
                if self._stop:
                    return None
                # launch: pop in (priority, deadline) order; shed expired,
                # push dtype-incompatible requests back for the next pass
                now = time.perf_counter()
                head = self._heap[0][1]        # may have changed during hold
                cap = self._batch_cap(head)
                batch: List[_Request] = []
                putback: List[tuple] = []
                while self._heap and len(batch) < cap:
                    _, r = heapq.heappop(self._heap)
                    if r.deadline < now:
                        expired.append(r)
                    elif self._compatible(head, r):
                        batch.append(r)
                    else:
                        putback.append((r.sort_key(), r))
                for item in putback:
                    heapq.heappush(self._heap, item)
                self._inflight = list(batch)
            return batch
        finally:
            # resolve shed futures outside the lock (done-callbacks may run)
            now = time.perf_counter()
            for r in expired:
                self._shed(r, now)

    def _dispatch(self, batch: List[_Request]) -> None:
        net = self.net
        ex = net.executor
        k = len(batch)
        bucket = 1
        compiles0 = getattr(ex, "compile_count", 0)
        try:
            caps = ex.capabilities()
            if k == 1:
                res = ex.run(batch[0].x)
                outs = [res]
            else:
                # bucket-pad only for native batch programs (compile-once
                # shapes); sequential fallbacks would just discard the pad.
                # The backend's declared hard ceiling bounds even the padded
                # shape (a non-power-of-two ceiling beats a ladder rung).
                bucket = (self.config.bucket_for(k)
                          if caps.native_batching else k)
                if caps.max_batch is not None:
                    bucket = min(bucket, caps.max_batch)
                padded = pad_batch([r.x for r in batch], bucket)
                if caps.shardable:
                    ex.batch_sharding = self.scheduler._lane_sharding(bucket)
                res = ex.run_batch(padded, lanes=k)
                outs = [ExecResult(output_int8=res.output_int8[i],
                                   output=res.output[i]) for i in range(k)]
        except BaseException as e:          # noqa: BLE001 — forwarded to callers
            for r in batch:
                _resolve_future(r.future, r.future.set_exception, e)
            return
        done = time.perf_counter()
        net.stats.note_dispatch(
            k, [(done - r.t_submit) * 1e6 for r in batch], bucket=bucket,
            compiles=getattr(ex, "compile_count", 0) - compiles0)
        for r, out in zip(batch, outs):
            _resolve_future(r.future, r.future.set_result, out)
        self._ema_coalesce = ((1 - _EMA_ALPHA) * self._ema_coalesce
                              + _EMA_ALPHA * k)

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            if batch:
                self._dispatch(batch)
            with self._cond:
                self._inflight = []


class Scheduler:
    """Per-net dispatcher threads behind a ``Session``.

    Each resident network owns an independent queue and dispatcher thread
    (created lazily on its first submit), so traffic for one net never
    head-of-line blocks another's.  The public surface is unchanged from the
    single-dispatcher era — ``submit`` / ``submit_many`` / ``queue_depth`` /
    ``close`` — plus per-request ``priority`` and ``deadline_us``.
    """

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._lock = threading.Lock()
        self._dispatchers: Dict[int, _NetDispatcher] = {}
        self._retired: Dict[int, object] = {}   # unloaded nets, by id
        self._closed = False
        self._seq = itertools.count()
        self._mesh = None
        self._mesh_checked = False

    # -- client side ---------------------------------------------------------
    def submit(self, net, x: np.ndarray, priority: int = 0,
               deadline_us: Optional[float] = None) -> Future:
        """Enqueue one request against resident network ``net``."""
        return self.submit_many(net, [x], priority=priority,
                                deadline_us=deadline_us)[0]

    def submit_many(self, net, xs, priority: int = 0,
                    deadline_us: Optional[float] = None) -> List[Future]:
        """Enqueue several requests atomically (one lock hold, one wake-up),
        so a pre-formed batch reaches the dispatcher whole instead of being
        peeled off a request at a time.  When the group reaches the head of
        the queue it may exceed ``max_batch`` and still dispatch as one
        program (explicit ``run_batch`` callers keep the single-program
        semantics; the cap bounds *coalescing* of independent submits).
        Under mixed traffic a group queued behind other requests can split
        across dispatches — results stay bit-exact either way, and batch
        shapes stay on the power-of-two bucket grid.

        ``priority`` (higher = more urgent) and ``deadline_us`` (relative
        latency budget; past it the request is shed with
        ``DeadlineExceededError``) order the per-net queue.  Raises
        ``QueueFullError`` when the net's queue is at ``max_queue``.
        """
        if deadline_us is not None and math.isnan(deadline_us):
            raise ValueError("deadline_us must not be NaN (a NaN sort key "
                             "would corrupt the EDF queue order)")
        now = time.perf_counter()
        # deadline_us=0 means an already-expired budget (shed at launch),
        # NOT "no deadline" — only None/inf disable the deadline entirely
        dl = now + deadline_us * 1e-6 if deadline_us is not None else math.inf
        reqs = [_Request(net=net, x=x, future=Future(), t_submit=now,
                         priority=priority, deadline=dl,
                         deadline_us=deadline_us or 0.0,
                         seq=next(self._seq), group_n=len(xs)) for x in xs]
        self._dispatcher(net).enqueue(reqs)
        return [r.future for r in reqs]

    def queue_depth(self, net=None) -> int:
        """Queued (not in-flight) requests: one net's, or all nets' summed."""
        with self._lock:
            ds = list(self._dispatchers.values())
        return sum(d.queue_depth() for d in ds
                   if net is None or d.net is net)

    def close(self, drain: bool = False) -> None:
        """Stop every dispatcher.  ``drain=False`` (default): queued requests
        get ``CancelledError``, the in-flight batch finishes; ``drain=True``:
        queued work completes first.  Every future ever returned by
        ``submit`` is resolved when this returns."""
        with self._lock:
            self._closed = True
            ds = list(self._dispatchers.values())
        for d in ds:
            d.close(drain=drain)

    def close_net(self, net, drain: bool = True) -> None:
        """Stop one net's dispatcher (Session.unload / replace) — without
        this its idle thread would outlive the net's residency.  The net is
        remembered as retired so a racing ``submit`` that already resolved
        it cannot silently respawn a dispatcher for a dead executor."""
        with self._lock:
            d = self._dispatchers.pop(id(net), None)
            self._retired[id(net)] = net    # hold the ref: id() stays unique
        if d is not None:
            d.close(drain=drain)

    # -- internals -----------------------------------------------------------
    def _dispatcher(self, net) -> _NetDispatcher:
        with self._lock:
            if self._closed:
                raise RuntimeError("scheduler is closed; create a new Session")
            if id(net) in self._retired:
                raise RuntimeError(
                    f"network {getattr(net, 'name', '?')!r} was unloaded")
            d = self._dispatchers.get(id(net))
            if d is None:
                d = _NetDispatcher(net, self.config, self)
                self._dispatchers[id(net)] = d
            return d

    def _lane_sharding(self, lanes_padded: int):
        """NamedSharding for a shardable batch, or None.  Called from
        dispatcher threads; the mesh probe is cached after the first call."""
        if not self.config.shard:
            return None
        with self._lock:
            if not self._mesh_checked:
                from repro.distributed import sharding as shard_mod
                self._mesh = shard_mod.serving_mesh()
                self._mesh_checked = True
            mesh = self._mesh
        if mesh is None or lanes_padded % mesh.size != 0:
            return None
        from repro.distributed import sharding as shard_mod
        return shard_mod.lane_sharding(mesh)
