"""Async serving scheduler: request queue + adaptive micro-batching engine.

``Session.submit(x)`` enqueues one inference request and returns a
``concurrent.futures.Future``.  A background dispatcher thread drains the
queue, coalesces pending same-network requests into one batch, pads it to a
power-of-two bucket (so each batch shape compiles exactly once), executes it
through the backend's ``run_batch(padded, lanes)``, and resolves each future
with its lane's ``ExecResult`` — bit-exact versus running every request
through sequential ``run`` calls, because the batch program itself is
bit-exact and padding lanes are sliced off before anyone sees them.

Micro-batching is *adaptive*: the dispatcher tracks an EMA of recent
coalesce sizes.  Under solo traffic (EMA ~ 1) it dispatches immediately —
waiting would only add latency; once concurrency is observed it holds the
head request up to ``max_wait_us`` to let the batch fill towards
``max_batch``.  Requests for different resident networks never coalesce.

When several devices are visible and the backend reports
``capabilities().shardable``, a coalesced batch whose bucket divides the
device count is dispatched with its lane axis sharded over a 1-axis data
mesh (``repro.distributed.sharding.serving_mesh``); GSPMD splits the vmapped
program across devices and replicates the resident weight arena.

Padding and lane masking live HERE, not in executors: backends receive an
already-padded batch plus the live-lane count and stay policy-free.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

import numpy as np

from repro.core.executor import ExecResult

# EMA of coalesce sizes above which the dispatcher starts holding the head
# request for stragglers (below it, traffic is effectively solo).
_COALESCE_THRESHOLD = 1.25
_EMA_ALPHA = 0.2


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Micro-batching knobs.

    ``max_batch``    — coalescing ceiling per dispatch.
    ``max_wait_us``  — longest the head request is held for stragglers.
    ``adaptive``     — skip the wait entirely while traffic is solo
                       (EMA of coalesce sizes stays ~1).
    ``shard``        — shard coalesced batches lane-wise across devices when
                       the backend is shardable and >1 device is visible.
    ``latency_window`` — ring-buffer size for per-request latency samples.
    """
    max_batch: int = 8
    max_wait_us: float = 200.0
    adaptive: bool = True
    shard: bool = True
    latency_window: int = 2048


@dataclasses.dataclass
class _Request:
    net: object                  # the Session's _Net record
    x: np.ndarray
    future: Future
    t_submit: float
    group_n: int = 1             # size of the submit_many group this came in
                                 # with: a pre-formed batch may exceed
                                 # max_batch and still dispatch as one program


def bucket_size(n: int, max_batch: int) -> int:
    """Smallest power of two >= n, capped at max_batch for coalesced traffic
    (compile-once shapes); oversize pre-formed groups still round up to a
    power of two so batch shapes stay drawn from a bounded set."""
    b = 1
    while b < n:
        b *= 2
    return min(b, max_batch) if n <= max_batch else b


def pad_batch(xs: List[np.ndarray], bucket: int) -> np.ndarray:
    """Stack request inputs into a (bucket, ...) batch, zero-padding the tail
    lanes.  Padding changes no live lane's bytes — the batch program is
    lane-independent — so results stay bit-exact."""
    X = np.stack([np.asarray(x) for x in xs])
    if X.shape[0] < bucket:
        pad = np.zeros((bucket - X.shape[0],) + X.shape[1:], X.dtype)
        X = np.concatenate([X, pad])
    return X


class Scheduler:
    """Request queue + dispatcher thread behind a ``Session``.

    One scheduler serves all of a session's resident networks; requests for
    the same network coalesce, requests for different networks dispatch in
    arrival order without blocking each other past the current batch.
    """

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._queue: "collections.deque[_Request]" = collections.deque()
        # plain Lock (not the default RLock): the condition is hot on submit
        self._cond = threading.Condition(threading.Lock())
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._ema_coalesce = 1.0
        self._mesh = None
        self._mesh_checked = False

    # -- client side ---------------------------------------------------------
    def submit(self, net, x: np.ndarray) -> Future:
        """Enqueue one request against resident network ``net``."""
        return self.submit_many(net, [x])[0]

    def submit_many(self, net, xs) -> List[Future]:
        """Enqueue several requests atomically (one lock hold, one wake-up),
        so a pre-formed batch reaches the dispatcher whole instead of being
        peeled off a request at a time.  When the group reaches the head of
        the queue it may exceed ``max_batch`` and still dispatch as one
        program (explicit ``run_batch`` callers keep the single-program
        semantics; the cap bounds *coalescing* of independent submits).
        Under mixed traffic a group queued behind other requests can split
        across dispatches — results stay bit-exact either way, and batch
        shapes stay on the power-of-two bucket grid."""
        now = time.perf_counter()
        reqs = [_Request(net=net, x=x, future=Future(), t_submit=now,
                         group_n=len(xs)) for x in xs]
        with self._cond:
            if self._stop:
                raise RuntimeError("scheduler is closed; create a new Session")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._loop, name="repro-scheduler", daemon=True)
                self._thread.start()
            was_empty = not self._queue
            self._queue.extend(reqs)
            st = net.stats
            st.submits += len(reqs)
            depth = sum(1 for r in self._queue if r.net is net)
            st.queue_depth_peak = max(st.queue_depth_peak, depth)
            # wake the dispatcher only on the transitions it acts on — queue
            # went non-empty, or a full batch is now available.  Intermediate
            # submits land silently (the dispatcher's hold-wait re-checks on
            # wake or deadline), avoiding a context switch per request.
            if was_empty or depth >= self.config.max_batch:
                self._cond.notify()
        return [r.future for r in reqs]

    def queue_depth(self) -> int:
        with self._cond:
            return len(self._queue)

    def close(self) -> None:
        """Stop the dispatcher; pending requests get CancelledError."""
        with self._cond:
            self._stop = True
            pending = list(self._queue)
            self._queue.clear()
            self._cond.notify_all()
        for req in pending:
            req.future.cancel()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # -- dispatcher side -----------------------------------------------------
    def _batch_cap(self, head: _Request) -> int:
        # a pre-formed submit_many group dispatches whole even past the
        # config cap, but a backend's declared hard ceiling always wins
        cap = max(self.config.max_batch, head.group_n)
        try:
            backend_max = head.net.executor.capabilities().max_batch
        except Exception:
            backend_max = None
        if backend_max is not None:
            cap = min(cap, backend_max)
        return max(cap, 1)

    @staticmethod
    def _compatible(head: _Request, r: _Request) -> bool:
        """Requests may share a dispatch: same network AND same input dtype
        (int8 lanes pass through quantisation; stacking them with float32
        lanes would promote the batch and re-quantise them — wrong bytes)."""
        return r.net is head.net and \
            getattr(r.x, "dtype", None) == getattr(head.x, "dtype", None)

    def _take_same_net(self, batch: List[_Request]) -> None:
        """Move queued requests compatible with batch[0] into ``batch``
        (stable order for everyone else), up to the batch cap.  Caller holds
        the lock."""
        head, cap = batch[0], self._batch_cap(batch[0])
        keep: "collections.deque[_Request]" = collections.deque()
        while self._queue and len(batch) < cap:
            r = self._queue.popleft()
            (batch if self._compatible(head, r) else keep).append(r)
        keep.extend(self._queue)
        self._queue.clear()
        self._queue.extend(keep)

    def _collect(self) -> Optional[List[_Request]]:
        """Block for the next batch: head request + same-net stragglers.

        The head stays queued during the hold so the producer-side full-batch
        wake-up condition keeps seeing the true depth; the hold ends when a
        full batch is available or the head has waited ``max_wait_us``.
        """
        cfg = self.config
        with self._cond:
            while not self._queue and not self._stop:
                self._cond.wait()
            if self._stop:
                return None
            head = self._queue[0]
            cap = self._batch_cap(head)
            hold = not cfg.adaptive or self._ema_coalesce > _COALESCE_THRESHOLD
            if hold:
                deadline = head.t_submit + cfg.max_wait_us * 1e-6
                while not self._stop:
                    same = sum(1 for r in self._queue
                               if self._compatible(head, r))
                    if same >= cap:
                        break
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
            if self._stop:
                return None
            batch = [self._queue.popleft()]
            self._take_same_net(batch)
        return batch

    def _lane_sharding(self, lanes_padded: int):
        """NamedSharding for a shardable batch, or None."""
        if not self.config.shard:
            return None
        if not self._mesh_checked:
            from repro.distributed import sharding as shard_mod
            self._mesh = shard_mod.serving_mesh()
            self._mesh_checked = True
        if self._mesh is None or lanes_padded % self._mesh.size != 0:
            return None
        from repro.distributed import sharding as shard_mod
        return shard_mod.lane_sharding(self._mesh)

    def _dispatch(self, batch: List[_Request]) -> None:
        net = batch[0].net
        ex = net.executor
        k = len(batch)
        try:
            caps = ex.capabilities()
            if k == 1:
                res = ex.run(batch[0].x)
                outs = [res]
            else:
                # bucket-pad only for native batch programs (compile-once
                # shapes); sequential fallbacks would just discard the pad.
                # The backend's declared hard ceiling bounds even the padded
                # shape (a non-power-of-two ceiling beats a pow2 bucket).
                bucket = (bucket_size(k, self.config.max_batch)
                          if caps.native_batching else k)
                if caps.max_batch is not None:
                    bucket = min(bucket, caps.max_batch)
                padded = pad_batch([r.x for r in batch], bucket)
                if caps.shardable:
                    ex.batch_sharding = self._lane_sharding(bucket)
                res = ex.run_batch(padded, lanes=k)
                outs = [ExecResult(output_int8=res.output_int8[i],
                                   output=res.output[i]) for i in range(k)]
        except BaseException as e:          # noqa: BLE001 — forwarded to callers
            for r in batch:
                if not r.future.cancelled():
                    r.future.set_exception(e)
            return
        done = time.perf_counter()
        st = net.stats
        st.dispatches += 1
        st.coalesced_images += k
        st.coalesce_max = max(st.coalesce_max, k)
        for r, out in zip(batch, outs):
            st.latencies_us.append((done - r.t_submit) * 1e6)
            if not r.future.cancelled():
                r.future.set_result(out)
        self._ema_coalesce = ((1 - _EMA_ALPHA) * self._ema_coalesce
                              + _EMA_ALPHA * k)

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            self._dispatch(batch)
