"""Serving-side Session API.

A ``Session`` owns one or more compiled networks, each bound to a registered
executor backend, and serves single inputs (``run``) or batches
(``run_batch``).  The bare-metal backend keeps its preloaded DRAM arena
resident on device across calls and executes batches as one vmapped XLA
program, so steady-state serving pays only the input-surface transfer.

    art = CompilerPipeline(graph.lenet5()).run()
    ses = Session(art)                       # default backend: baremetal
    y = ses.run(x)                           # one image
    ys = ses.run_batch(X)                    # (N, ...) batch, bit-exact vs N runs

    ses.load(other_art, backend="linuxstack")  # multi-network residency
    ses.run(x2, net=other_art.graph_name)

    ses = Session.from_bundle("bundle_dir/")   # serve a saved bundle,
                                               # no recompilation or VP run
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.executor import ExecResult
from repro.core.pipeline import Artifacts
from repro.runtime import registry


@dataclasses.dataclass
class NetStats:
    """Per-network serving counters."""
    calls: int = 0
    batch_calls: int = 0
    images: int = 0


@dataclasses.dataclass
class _Net:
    name: str
    backend: str
    executor: object
    artifacts: Artifacts
    stats: NetStats = dataclasses.field(default_factory=NetStats)


class Session:
    """Multi-network inference session over registered executor backends."""

    def __init__(self, artifacts: Optional[Artifacts] = None,
                 backend: str = "baremetal", name: Optional[str] = None):
        self._nets: Dict[str, _Net] = {}
        self._order: List[str] = []
        self.default_backend = backend
        if artifacts is not None:
            self.load(artifacts, name=name, backend=backend)

    # -- residency -----------------------------------------------------------
    def load(self, artifacts: Artifacts, name: Optional[str] = None,
             backend: Optional[str] = None, replace: bool = False,
             **executor_kw) -> str:
        """Make ``artifacts`` resident under ``name``; returns the name."""
        name = name or artifacts.graph_name
        backend = backend or self.default_backend
        if name in self._nets and not replace:
            raise ValueError(f"network {name!r} already resident "
                             f"(pass replace=True or a different name)")
        ex = registry.create(backend, artifacts, **executor_kw)
        if name not in self._nets:
            self._order.append(name)
        self._nets[name] = _Net(name=name, backend=backend, executor=ex,
                                artifacts=artifacts)
        return name

    def unload(self, name: str) -> None:
        self._resolve(name)
        del self._nets[name]
        self._order.remove(name)

    @classmethod
    def from_bundle(cls, path, backend: str = "baremetal",
                    name: Optional[str] = None) -> "Session":
        """Build a Session straight from a saved bundle — no recompilation."""
        return cls(Artifacts.load(path), backend=backend, name=name)

    # -- lookup --------------------------------------------------------------
    @property
    def networks(self) -> List[str]:
        return list(self._order)

    def _resolve(self, net: Optional[str]) -> _Net:
        if net is None:
            if not self._order:
                raise ValueError("session has no resident network; "
                                 "load(artifacts) first")
            net = self._order[0]
        try:
            return self._nets[net]
        except KeyError:
            raise KeyError(f"no resident network {net!r}; resident: "
                           f"{', '.join(self._order) or '(none)'}") from None

    def executor(self, net: Optional[str] = None):
        return self._resolve(net).executor

    def artifacts(self, net: Optional[str] = None) -> Artifacts:
        return self._resolve(net).artifacts

    def stats(self, net: Optional[str] = None) -> NetStats:
        return self._resolve(net).stats

    # -- serving -------------------------------------------------------------
    def run(self, x: np.ndarray, net: Optional[str] = None) -> ExecResult:
        """One inference on one input image."""
        n = self._resolve(net)
        res = n.executor.run(x)
        n.stats.calls += 1
        n.stats.images += 1
        return res

    def run_batch(self, X: np.ndarray, net: Optional[str] = None) -> ExecResult:
        """Batched inference over ``X`` of shape ``(N, ...)``.

        Bit-exact (INT8) against N sequential ``run`` calls; on the bare-metal
        backend the whole batch executes as a single vmapped XLA program over
        the resident arena.
        """
        X = np.asarray(X)
        n = self._resolve(net)
        res = n.executor.run_batch(X)
        n.stats.batch_calls += 1
        n.stats.images += int(X.shape[0])
        return res
