"""Serving-side Session API.

A ``Session`` owns one or more compiled networks, each bound to a registered
executor backend, and serves them through an async request queue with
adaptive micro-batching (``repro.runtime.scheduler``):

    art = CompilerPipeline(graph.lenet5()).run()
    ses = Session(art)                       # default backend: baremetal
    fut = ses.submit(x)                      # async: Future[ExecResult]
    y = fut.result()
    y = ses.run(x)                           # sync sugar over submit
    ys = ses.run_batch(X)                    # (N, ...) batch, bit-exact vs
                                             # N sequential runs

    ses.load(other_art, backend="linuxstack")  # multi-network residency
    ses.run(x2, net=other_art.graph_name)

    ses = Session.from_bundle("bundle_dir/")   # serve a saved bundle,
                                               # no recompilation or VP run

Layering: ``Session`` resolves networks and owns residency; the scheduler
owns queueing, coalescing, padding and lane masking; backends (anything
satisfying ``repro.core.executor.ExecutorBackend``) own execution only.
Concurrent ``submit`` calls against the same network coalesce into one
vmapped batch program on backends that support native batching — results
stay bit-exact versus sequential ``run`` calls.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future
from typing import Dict, List, Optional

import numpy as np

from repro.core.executor import ExecResult
from repro.core.pipeline import Artifacts
from repro.obs.trace import TraceConfig, Tracer
from repro.obs.timeseries import Telemetry
from repro.runtime import registry
from repro.runtime.scheduler import Scheduler, SchedulerConfig

# NetStats.circuit_state gauge values (Prometheus-friendly ints)
_CIRCUIT_STATES = {"closed": 0, "half_open": 1, "open": 2}


@dataclasses.dataclass
class NetStats:
    """Per-network serving counters, safe to mutate and read concurrently.

    The first block counts API-level traffic (kept from the pre-scheduler
    Session); the second block is filled by the net's dispatcher thread.
    With one dispatcher per resident net *plus* the ``/metrics`` endpoint
    reading from HTTP threads, every mutation goes through a ``note_*``
    method under the internal lock, and readers take a coherent
    ``snapshot()``.  Bare attribute reads remain fine for tests/debugging
    (ints are torn-read-free under CPython), but cross-counter invariants
    are only guaranteed by ``snapshot()``.
    """
    calls: int = 0               # Session.run invocations
    batch_calls: int = 0         # Session.run_batch invocations
    images: int = 0
    submits: int = 0             # requests enqueued (run/run_batch included)
    dispatches: int = 0          # coalesced batches executed
    coalesced_images: int = 0    # requests served through dispatches
    coalesce_max: int = 0        # largest coalesced batch so far
    queue_depth_peak: int = 0
    rejected: int = 0            # admission control (QueueFullError)
    shed: int = 0                # deadline passed before launch
    compile_count: int = 0       # executor program builds observed (warmup +
                                 # dispatch) — nonzero deltas after warmup
                                 # mean a request paid a compile stall
    warmup_ms: float = 0.0       # time spent in Session.warmup for this net
    # -- fault-tolerance counters (dispatcher supervisor) --------------------
    retries: int = 0             # launch attempts beyond each batch's first
    backend_failures: int = 0    # failed launch attempts (incl. retried ones)
    watchdog_timeouts: int = 0   # launches abandoned by the watchdog
    arena_resets: int = 0        # poisoned-arena restores (checksum mismatch)
    degraded: int = 0            # requests served by the fallback backend
    faults_injected: int = 0     # injected faults observed (FaultyExecutor)
    circuit_state: int = 0       # breaker gauge: 0 closed, 1 half-open, 2 open
    circuit_opens: int = 0       # closed/half-open -> open transitions
    circuit_rejected: int = 0    # submits shed while the circuit was open
    latency_total_us: float = 0.0  # summed submit->result latency: together
    latency_count: int = 0         # with this count, the Prometheus summary
                                   # _sum/_count pair (unwindowed, unlike the
                                   # percentile ring buffer)
    bucket_launches: Dict[int, int] = dataclasses.field(
        default_factory=dict)    # dispatched-batch count per padded bucket
    latencies_us: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=2048), repr=False)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    # -- writers (scheduler + Session threads) -------------------------------
    def note_call(self, images: int = 1, batch: bool = False) -> None:
        with self._lock:
            if batch:
                self.batch_calls += 1
            else:
                self.calls += 1
            self.images += images

    def note_submit(self, n: int, depth: int) -> None:
        with self._lock:
            self.submits += n
            self.queue_depth_peak = max(self.queue_depth_peak, depth)

    def note_reject(self, n: int) -> None:
        with self._lock:
            self.rejected += n

    def note_shed(self, n: int) -> None:
        with self._lock:
            self.shed += n

    def note_dispatch(self, k: int, latencies_us, bucket: Optional[int] = None,
                      compiles: int = 0, degraded: int = 0) -> None:
        with self._lock:
            self.dispatches += 1
            self.coalesced_images += k
            self.coalesce_max = max(self.coalesce_max, k)
            if bucket is not None:
                self.bucket_launches[int(bucket)] = \
                    self.bucket_launches.get(int(bucket), 0) + 1
            self.compile_count += compiles
            self.degraded += degraded
            self.latencies_us.extend(latencies_us)
            self.latency_total_us += float(sum(latencies_us))
            self.latency_count += len(latencies_us)

    def note_warmup(self, ms: float, compiles: int = 0) -> None:
        with self._lock:
            self.warmup_ms += ms
            self.compile_count += compiles

    def note_retry(self, n: int = 1) -> None:
        with self._lock:
            self.retries += n

    def note_failure(self, timeout: bool = False) -> None:
        with self._lock:
            self.backend_failures += 1
            if timeout:
                self.watchdog_timeouts += 1

    def note_arena_reset(self) -> None:
        with self._lock:
            self.arena_resets += 1

    def note_faults(self, total: int) -> None:
        """Mirror the FaultyExecutor's absolute injection count."""
        with self._lock:
            self.faults_injected = max(self.faults_injected, int(total))

    def note_circuit(self, state: str) -> None:
        s = _CIRCUIT_STATES[state]
        with self._lock:
            if s == 2 and self.circuit_state != 2:
                self.circuit_opens += 1
            self.circuit_state = s

    def note_circuit_reject(self, n: int) -> None:
        with self._lock:
            self.circuit_rejected += n

    # -- readers -------------------------------------------------------------
    @property
    def coalesce_mean(self) -> float:
        return self.coalesced_images / self.dispatches if self.dispatches else 0.0

    def latency_us(self, pct: float) -> float:
        """Submit->result latency percentile (e.g. 50, 90, 99) over the
        recent-request window; 0.0 before any request completes."""
        with self._lock:
            samples = list(self.latencies_us)
        if not samples:
            return 0.0
        return float(np.percentile(np.asarray(samples), pct))

    def latency_summary(self) -> Dict[str, float]:
        return {f"p{p:g}": self.latency_us(p) for p in (50, 90, 99)}

    def snapshot(self) -> Dict[str, float]:
        """One coherent copy of every counter plus latency percentiles —
        the unit ``/metrics`` renders.  Taken under the same lock the
        dispatcher mutates under, so no cross-counter tearing."""
        with self._lock:
            out = {}
            for f in dataclasses.fields(self):
                if f.name in ("latencies_us", "_lock"):
                    continue
                v = getattr(self, f.name)
                out[f.name] = dict(v) if isinstance(v, dict) else v
            samples = list(self.latencies_us)
        arr = np.asarray(samples) if samples else None
        for p in (50, 90, 99):
            out[f"latency_p{p}_us"] = (
                float(np.percentile(arr, p)) if arr is not None else 0.0)
        out["latency_samples"] = len(samples)
        return out


@dataclasses.dataclass
class _Net:
    name: str
    backend: str
    executor: object
    artifacts: Artifacts
    stats: NetStats = dataclasses.field(default_factory=NetStats)
    input_elems: Optional[int] = None    # cached expected input size
    dtype: str = "int8"                  # engine datapath (capabilities())
    fallback: object = None              # degraded-mode executor (or None)
    fallback_backend: Optional[str] = None


class Session:
    """Multi-network inference session over registered executor backends."""

    def __init__(self, artifacts: Optional[Artifacts] = None,
                 backend: str = "baremetal", name: Optional[str] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 warmup: bool = False, trace=None, telemetry=None):
        self._nets: Dict[str, _Net] = {}
        self._order: List[str] = []
        self.default_backend = backend
        # ``trace``: a TraceConfig (or a pre-built Tracer) — every Session
        # gets one; lifecycle spans are a handful of perf_counter calls per
        # request, and TraceConfig(enabled=False) disables recording while
        # keeping the trace-id contract
        self.tracer = trace if isinstance(trace, Tracer) \
            else Tracer(trace if isinstance(trace, TraceConfig)
                        else TraceConfig())
        # ``telemetry``: a Telemetry (or TimeSeriesConfig) — every Session
        # gets one; the scheduler records every resolved request into its
        # sliding windows (a bisect + counters per request), feeding the
        # windowed /metrics series and the SLO burn-rate engine
        self.telemetry = telemetry if isinstance(telemetry, Telemetry) \
            else Telemetry(telemetry)
        self.slo = None                     # SloEngine via attach_slo()
        self._scheduler = Scheduler(scheduler, tracer=self.tracer,
                                    telemetry=self.telemetry)
        # ``warmup=True``: every net precompiles its bucket ladder at load
        # time (see ``warmup()``), so no first request ever compile-stalls
        self._warmup_on_load = bool(warmup)
        # stop the dispatcher thread when the Session is garbage-collected,
        # so un-close()d sessions don't leak threads for the process lifetime
        self._finalizer = weakref.finalize(self, Scheduler.close,
                                           self._scheduler)
        if artifacts is not None:
            self.load(artifacts, name=name, backend=backend)

    # -- residency -----------------------------------------------------------
    def load(self, artifacts: Artifacts, name: Optional[str] = None,
             backend: Optional[str] = None, replace: bool = False,
             fallback_backend: Optional[str] = None, fault_plan=None,
             **executor_kw) -> str:
        """Make ``artifacts`` resident under ``name``; returns the name.

        ``fallback_backend`` names a second registered backend (e.g.
        ``"ref"``) built over the same artifacts: when the net's circuit
        breaker opens, traffic routes there with results marked
        ``degraded=True`` instead of shedding.  ``fault_plan`` wraps the
        primary executor in a :class:`repro.runtime.faults.FaultyExecutor`
        (the chaos/test harness's injection point)."""
        name = name or artifacts.graph_name
        backend = backend or self.default_backend
        if name in self._nets and not replace:
            raise ValueError(f"network {name!r} already resident "
                             f"(pass replace=True or a different name)")
        ex = registry.create(backend, artifacts, **executor_kw)
        if fault_plan is not None:
            from repro.runtime.faults import FaultyExecutor
            ex = FaultyExecutor(ex, fault_plan)
        fallback = (registry.create(fallback_backend, artifacts)
                    if fallback_backend else None)
        if name not in self._nets:
            self._order.append(name)
        else:                               # replace=True: retire the old
            self._scheduler.close_net(self._nets[name])  # net's dispatcher
        stats = NetStats(latencies_us=collections.deque(
            maxlen=self._scheduler.config.latency_window))
        dims = getattr(ex, "input_dims", None)
        # a capabilities() failure must be loud at load time — a silent
        # int8 fallback would mis-handle a bf16 net's inputs at serve time
        dtype = ex.capabilities().dtype
        self._nets[name] = _Net(
            name=name, backend=backend, executor=ex, artifacts=artifacts,
            stats=stats, dtype=dtype,
            input_elems=int(np.prod(dims[1:])) if dims is not None else None,
            fallback=fallback, fallback_backend=fallback_backend)
        if self._warmup_on_load:
            self.warmup(name)
        return name

    def warmup(self, net: Optional[str] = None) -> Dict[str, float]:
        """Precompile every (net, bucket) program before traffic arrives.

        For each targeted net (all resident nets when ``net`` is None): one
        zero-input inference at batch 1, plus one ``run_batch`` per rung of
        the scheduler's bucket ladder (``SchedulerConfig.buckets``) on
        natively batching backends — exactly the shapes the dispatcher pads
        to, so the first real request of any bucket shape never pays a
        compile stall.  Sharding mirrors the dispatcher's lane placement so
        warmed programs are the ones that serve.  Call before admitting
        traffic (the serve front-end holds requests until this returns);
        per-net wall time and compile counts land in ``NetStats``.  Returns
        ``{net_name: warmup_ms}``.
        """
        names = [net] if net is not None else list(self._order)
        out: Dict[str, float] = {}
        for nm in names:
            n = self._resolve(nm)
            ex = n.executor
            dims = getattr(ex, "input_dims", None)
            if dims is None:
                continue
            shape = tuple(dims[1:])
            caps = ex.capabilities()
            compiles0 = getattr(ex, "compile_count", 0)
            t0 = time.perf_counter()
            ex.run(np.zeros(shape, np.float32))
            if caps.native_batching:
                for b in self._scheduler.config.buckets:
                    if b <= 1 or (caps.max_batch is not None
                                  and b > caps.max_batch):
                        continue
                    if caps.shardable:
                        ex.batch_sharding = self._scheduler._lane_sharding(b)
                    ex.run_batch(np.zeros((b,) + shape, np.float32), lanes=b)
            ms = (time.perf_counter() - t0) * 1e3
            n.stats.note_warmup(ms, getattr(ex, "compile_count", 0) - compiles0)
            out[nm] = ms
        return out

    def unload(self, name: str) -> None:
        """Drop a resident network; its dispatcher drains and stops."""
        net = self._resolve(name)
        del self._nets[name]
        self._order.remove(name)
        self._scheduler.close_net(net)

    def attach_slo(self, policies, start: bool = False,
                   period_s: float = 5.0):
        """Attach an SLO burn-rate engine (``repro.obs.slo``) over this
        session's telemetry.  ``policies`` is a sequence of ``SloPolicy``
        (e.g. from ``load_policies(path)``).  ``start=True`` runs the
        evaluator on a daemon thread every ``period_s``; either way
        ``/metrics`` and ``/v1/slo`` evaluate on demand.  A policy with
        ``open_circuit_on_breach`` trips the breached net's circuit breaker
        (same downstream behavior as failure-driven opens: fallback routing
        or fast sheds, then a half-open probe).  Returns the engine."""
        from repro.obs.slo import SloEngine
        if self.slo is not None:
            self.slo.close()
        self.slo = SloEngine(policies, self.telemetry, tracer=self.tracer,
                             breaker=self._trip_circuit)
        if start:
            self.slo.start(period_s)
        return self.slo

    def _trip_circuit(self, name: str) -> None:
        net = self._nets.get(name)
        if net is not None:
            self._scheduler.trip_circuit(net)

    def close(self, drain: bool = False) -> None:
        """Stop the per-net dispatcher threads.  ``drain=False`` (default)
        cancels queued requests; ``drain=True`` completes them first.
        Either way every outstanding future is resolved on return."""
        if self.slo is not None:
            self.slo.close()
        self._scheduler.close(drain=drain)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @classmethod
    def from_bundle(cls, path, backend: str = "baremetal",
                    name: Optional[str] = None) -> "Session":
        """Build a Session straight from a saved bundle — no recompilation."""
        return cls(Artifacts.load(path), backend=backend, name=name)

    # -- lookup --------------------------------------------------------------
    @property
    def networks(self) -> List[str]:
        return list(self._order)

    @property
    def scheduler(self) -> Scheduler:
        return self._scheduler

    def _resolve(self, net: Optional[str]) -> _Net:
        if net is None:
            if not self._order:
                raise ValueError("session has no resident network; "
                                 "load(artifacts) first")
            net = self._order[0]
        try:
            return self._nets[net]
        except KeyError:
            raise KeyError(f"no resident network {net!r}; resident: "
                           f"{', '.join(self._order) or '(none)'}") from None

    def executor(self, net: Optional[str] = None):
        return self._resolve(net).executor

    def artifacts(self, net: Optional[str] = None) -> Artifacts:
        return self._resolve(net).artifacts

    def stats(self, net: Optional[str] = None) -> NetStats:
        return self._resolve(net).stats

    def health(self, net: Optional[str] = None) -> Dict[str, Dict]:
        """Per-net serving health, derived from the circuit breaker.

        ``{name: {"state", "circuit", "fallback"}}`` where ``state`` is
        ``healthy`` (breaker closed), ``degraded`` (breaker not closed but a
        fallback backend is absorbing traffic), or ``circuit_open`` (breaker
        not closed and nothing to fall back to — submits shed with 503).
        ``/healthz`` renders this, returning non-200 unless all healthy."""
        names = [net] if net is not None else list(self._order)
        out: Dict[str, Dict] = {}
        for nm in names:
            n = self._resolve(nm)
            circuit = self._scheduler.circuit_state(n)
            if circuit == "closed":
                state = "healthy"
            elif n.fallback is not None:
                state = "degraded"
            else:
                state = "circuit_open"
            out[nm] = {"state": state, "circuit": circuit,
                       "fallback": n.fallback_backend}
        return out

    def queue_depth(self, net: Optional[str] = None) -> int:
        """Requests currently queued (not in-flight) — one net's, or every
        resident net's summed when ``net`` is None."""
        return self._scheduler.queue_depth(
            self._resolve(net) if net is not None else None)

    # -- serving -------------------------------------------------------------
    def _check_input(self, n: _Net, x) -> np.ndarray:
        """Fail fast on malformed inputs so one bad submit can never poison
        the futures of well-formed requests coalesced into the same batch,
        and canonicalise shape/dtype so every lane of a coalesced batch
        stacks cleanly: flat, and either int8 (pre-quantised, passed
        through) or float32 (converted by the backend).  The scheduler never
        coalesces int8 with float32 lanes.

        A bf16 (nv_full) net has no pre-quantised int8 notion — every input
        is canonicalised to float32, so all of a bf16 net's lanes share one
        dtype and its batches form their own buckets (a launch never mixes
        engine dtypes; each dispatcher serves exactly one net/config)."""
        x = np.asarray(x)
        want = n.input_elems
        if want is not None and (x.dtype == object or x.size != want):
            raise ValueError(
                f"bad input for network {n.name!r}: got dtype={x.dtype} "
                f"size={x.size}, expected {want} elements")
        if want is not None:
            if x.dtype != np.int8 or n.dtype != "int8":
                x = x.astype(np.float32, copy=False)
            x = x.reshape(-1)
        return x

    def submit(self, x: np.ndarray, net: Optional[str] = None,
               priority: int = 0,
               deadline_us: Optional[float] = None,
               trace_id: Optional[str] = None) -> "Future[ExecResult]":
        """Enqueue one inference; returns a Future resolving to its
        ``ExecResult``.  Concurrent submits against the same network coalesce
        into one padded vmapped batch (bit-exact vs sequential ``run``).

        ``priority`` (higher = more urgent) and ``deadline_us`` (relative
        latency budget) feed the net's SLA-aware queue: urgent-first,
        earliest-deadline within a class; a request still queued past its
        deadline is shed (its future raises ``DeadlineExceededError``), and
        a queue at ``SchedulerConfig.max_queue`` rejects the submit outright
        with ``QueueFullError``.

        The returned future carries ``fut.trace_id``; passing ``trace_id``
        (a client-supplied ``X-Repro-Trace-Id``) forces the request into
        the tracer's sampled set.
        """
        n = self._resolve(net)
        return self._scheduler.submit(n, self._check_input(n, x),
                                      priority=priority,
                                      deadline_us=deadline_us,
                                      trace_id=trace_id)

    def run(self, x: np.ndarray, net: Optional[str] = None) -> ExecResult:
        """One inference on one input image (synchronous ``submit``)."""
        n = self._resolve(net)
        fut = self._scheduler.submit(n, self._check_input(n, x))
        n.stats.note_call()
        return fut.result()

    def run_batch(self, X: np.ndarray, net: Optional[str] = None) -> ExecResult:
        """Batched inference over ``X`` of shape ``(N, ...)``.

        Thin wrapper over N ``submit`` calls: the scheduler coalesces them
        (together with any other pending requests) into padded vmapped batch
        programs.  Bit-exact (INT8) against N sequential ``run`` calls.
        """
        X = np.asarray(X)
        n = self._resolve(net)
        futs = self._scheduler.submit_many(
            n, [self._check_input(n, x) for x in X])
        n.stats.note_call(int(X.shape[0]), batch=True)
        outs = [f.result() for f in futs]
        return ExecResult(output_int8=np.stack([o.output_int8 for o in outs]),
                          output=np.stack([o.output for o in outs]))
