"""Serving-side Session API.

A ``Session`` owns one or more compiled networks, each bound to a registered
executor backend, and serves them through an async request queue with
adaptive micro-batching (``repro.runtime.scheduler``):

    art = CompilerPipeline(graph.lenet5()).run()
    ses = Session(art)                       # default backend: baremetal
    fut = ses.submit(x)                      # async: Future[ExecResult]
    y = fut.result()
    y = ses.run(x)                           # sync sugar over submit
    ys = ses.run_batch(X)                    # (N, ...) batch, bit-exact vs
                                             # N sequential runs

    ses.load(other_art, backend="linuxstack")  # multi-network residency
    ses.run(x2, net=other_art.graph_name)

    ses = Session.from_bundle("bundle_dir/")   # serve a saved bundle,
                                               # no recompilation or VP run

Layering: ``Session`` resolves networks and owns residency; the scheduler
owns queueing, coalescing, padding and lane masking; backends (anything
satisfying ``repro.core.executor.ExecutorBackend``) own execution only.
Concurrent ``submit`` calls against the same network coalesce into one
vmapped batch program on backends that support native batching — results
stay bit-exact versus sequential ``run`` calls.
"""

from __future__ import annotations

import collections
import dataclasses
import weakref
from concurrent.futures import Future
from typing import Dict, List, Optional

import numpy as np

from repro.core.executor import ExecResult
from repro.core.pipeline import Artifacts
from repro.runtime import registry
from repro.runtime.scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class NetStats:
    """Per-network serving counters.

    The first block counts API-level traffic (kept from the pre-scheduler
    Session); the second block is filled by the scheduler's dispatcher.
    """
    calls: int = 0               # Session.run invocations
    batch_calls: int = 0         # Session.run_batch invocations
    images: int = 0
    submits: int = 0             # requests enqueued (run/run_batch included)
    dispatches: int = 0          # coalesced batches executed
    coalesced_images: int = 0    # requests served through dispatches
    coalesce_max: int = 0        # largest coalesced batch so far
    queue_depth_peak: int = 0
    latencies_us: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=2048), repr=False)

    @property
    def coalesce_mean(self) -> float:
        return self.coalesced_images / self.dispatches if self.dispatches else 0.0

    def latency_us(self, pct: float) -> float:
        """Submit->result latency percentile (e.g. 50, 90, 99) over the
        recent-request window; 0.0 before any request completes."""
        # the dispatcher thread appends concurrently; snapshot with a retry
        # (deque appends are atomic, but iteration can observe a mutation)
        for _ in range(8):
            try:
                samples = list(self.latencies_us)
                break
            except RuntimeError:
                continue
        else:
            samples = []
        if not samples:
            return 0.0
        return float(np.percentile(np.asarray(samples), pct))

    def latency_summary(self) -> Dict[str, float]:
        return {f"p{p:g}": self.latency_us(p) for p in (50, 90, 99)}


@dataclasses.dataclass
class _Net:
    name: str
    backend: str
    executor: object
    artifacts: Artifacts
    stats: NetStats = dataclasses.field(default_factory=NetStats)
    input_elems: Optional[int] = None    # cached expected input size


class Session:
    """Multi-network inference session over registered executor backends."""

    def __init__(self, artifacts: Optional[Artifacts] = None,
                 backend: str = "baremetal", name: Optional[str] = None,
                 scheduler: Optional[SchedulerConfig] = None):
        self._nets: Dict[str, _Net] = {}
        self._order: List[str] = []
        self.default_backend = backend
        self._scheduler = Scheduler(scheduler)
        # stop the dispatcher thread when the Session is garbage-collected,
        # so un-close()d sessions don't leak threads for the process lifetime
        self._finalizer = weakref.finalize(self, Scheduler.close,
                                           self._scheduler)
        if artifacts is not None:
            self.load(artifacts, name=name, backend=backend)

    # -- residency -----------------------------------------------------------
    def load(self, artifacts: Artifacts, name: Optional[str] = None,
             backend: Optional[str] = None, replace: bool = False,
             **executor_kw) -> str:
        """Make ``artifacts`` resident under ``name``; returns the name."""
        name = name or artifacts.graph_name
        backend = backend or self.default_backend
        if name in self._nets and not replace:
            raise ValueError(f"network {name!r} already resident "
                             f"(pass replace=True or a different name)")
        ex = registry.create(backend, artifacts, **executor_kw)
        if name not in self._nets:
            self._order.append(name)
        stats = NetStats(latencies_us=collections.deque(
            maxlen=self._scheduler.config.latency_window))
        dims = getattr(ex, "input_dims", None)
        self._nets[name] = _Net(
            name=name, backend=backend, executor=ex, artifacts=artifacts,
            stats=stats,
            input_elems=int(np.prod(dims[1:])) if dims is not None else None)
        return name

    def unload(self, name: str) -> None:
        self._resolve(name)
        del self._nets[name]
        self._order.remove(name)

    def close(self) -> None:
        """Stop the scheduler thread; pending futures are cancelled."""
        self._scheduler.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @classmethod
    def from_bundle(cls, path, backend: str = "baremetal",
                    name: Optional[str] = None) -> "Session":
        """Build a Session straight from a saved bundle — no recompilation."""
        return cls(Artifacts.load(path), backend=backend, name=name)

    # -- lookup --------------------------------------------------------------
    @property
    def networks(self) -> List[str]:
        return list(self._order)

    @property
    def scheduler(self) -> Scheduler:
        return self._scheduler

    def _resolve(self, net: Optional[str]) -> _Net:
        if net is None:
            if not self._order:
                raise ValueError("session has no resident network; "
                                 "load(artifacts) first")
            net = self._order[0]
        try:
            return self._nets[net]
        except KeyError:
            raise KeyError(f"no resident network {net!r}; resident: "
                           f"{', '.join(self._order) or '(none)'}") from None

    def executor(self, net: Optional[str] = None):
        return self._resolve(net).executor

    def artifacts(self, net: Optional[str] = None) -> Artifacts:
        return self._resolve(net).artifacts

    def stats(self, net: Optional[str] = None) -> NetStats:
        return self._resolve(net).stats

    # -- serving -------------------------------------------------------------
    def _check_input(self, n: _Net, x) -> np.ndarray:
        """Fail fast on malformed inputs so one bad submit can never poison
        the futures of well-formed requests coalesced into the same batch,
        and canonicalise shape/dtype so every lane of a coalesced batch
        stacks cleanly: flat, and either int8 (pre-quantised, passed
        through) or float32 (quantised by the backend).  The scheduler never
        coalesces int8 with float32 lanes."""
        x = np.asarray(x)
        want = n.input_elems
        if want is not None and (x.dtype == object or x.size != want):
            raise ValueError(
                f"bad input for network {n.name!r}: got dtype={x.dtype} "
                f"size={x.size}, expected {want} elements")
        if want is not None:
            if x.dtype != np.int8:
                x = x.astype(np.float32, copy=False)
            x = x.reshape(-1)
        return x

    def submit(self, x: np.ndarray, net: Optional[str] = None) -> "Future[ExecResult]":
        """Enqueue one inference; returns a Future resolving to its
        ``ExecResult``.  Concurrent submits against the same network coalesce
        into one padded vmapped batch (bit-exact vs sequential ``run``)."""
        n = self._resolve(net)
        return self._scheduler.submit(n, self._check_input(n, x))

    def run(self, x: np.ndarray, net: Optional[str] = None) -> ExecResult:
        """One inference on one input image (synchronous ``submit``)."""
        n = self._resolve(net)
        fut = self._scheduler.submit(n, self._check_input(n, x))
        n.stats.calls += 1
        n.stats.images += 1
        return fut.result()

    def run_batch(self, X: np.ndarray, net: Optional[str] = None) -> ExecResult:
        """Batched inference over ``X`` of shape ``(N, ...)``.

        Thin wrapper over N ``submit`` calls: the scheduler coalesces them
        (together with any other pending requests) into padded vmapped batch
        programs.  Bit-exact (INT8) against N sequential ``run`` calls.
        """
        X = np.asarray(X)
        n = self._resolve(net)
        futs = self._scheduler.submit_many(
            n, [self._check_input(n, x) for x in X])
        n.stats.batch_calls += 1
        n.stats.images += int(X.shape[0])
        outs = [f.result() for f in futs]
        return ExecResult(output_int8=np.stack([o.output_int8 for o in outs]),
                          output=np.stack([o.output for o in outs]))
