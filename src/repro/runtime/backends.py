"""Registered executor backends.

``baremetal``  — one fused XLA program over the flat arena (the paper's SoC).
``linuxstack`` — per-op dispatch + driver tensor table (the baseline stack).
``ref``        — pure-numpy descriptor replay on the reference ops; the slow
                 golden model, useful to adjudicate when the two fast backends
                 disagree or when jax is misbehaving on a platform.

All three consume ONLY the two bare-metal artifacts (configuration trace +
weight image), so every backend can serve a bundle loaded from disk.

Every backend satisfies the uniform ``ExecutorBackend`` protocol (``run`` /
``run_batch(padded, lanes)`` / ``capabilities()``); the Session scheduler
never special-cases a backend — it consults ``capabilities()`` to decide
whether to coalesce into native batch programs (``baremetal``) or rely on
the sequential ``run_batch`` fallback (``linuxstack`` / ``ref``), and
whether a coalesced batch may be sharded lane-wise across devices.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine, refops
from repro.core.executor import (BareMetalExecutor, ExecResult,
                                 ExecutorCapabilities, LinuxStackExecutor,
                                 _ExecutorBase)
from repro.runtime.registry import register_backend


def _executor_kwargs(art) -> dict:
    return dict(input_scale=art.input_scale, output_scale=art.output_scale,
                output_elems=art.output_elems)


@register_backend("baremetal")
def _make_baremetal(art, **kw):
    return BareMetalExecutor(art.trace, art.weight_image, art.cfg,
                             **_executor_kwargs(art), **kw)


@register_backend("linuxstack")
def _make_linuxstack(art, **kw):
    return LinuxStackExecutor(art.trace, art.weight_image, art.cfg,
                              **_executor_kwargs(art), **kw)


class RefExecutor(_ExecutorBase):
    """Numpy golden model: replays the decoded descriptors with core/refops
    (integer-exact for int8; f32-accumulate ``refops.*_bf16`` for nv_full)."""

    def capabilities(self) -> ExecutorCapabilities:
        # the golden model ignores the kernel plan: always scalar refops
        return ExecutorCapabilities(dtype=self.cfg.dtype, kernels=("refops",))

    def run(self, x: np.ndarray) -> ExecResult:
        xq = self._quant_in(x)
        dram = self.arena0.copy()
        x_bytes = np.ascontiguousarray(xq.reshape(-1)).view(np.uint8)
        dram[self.input_off:self.input_off + x_bytes.size] = x_bytes
        ex = self._exec if self.cfg.dtype == "int8" else self._exec_bf16
        for d in self.descs:
            ex(d, dram)
        out = dram[self.output_off:self.output_off + self.output_bytes]
        return self._finish_out(out.copy().view(np.int8))

    def _exec(self, d: engine.Descriptor, dram: np.ndarray) -> None:
        base = self.base
        _, c, h, w = d.src_dims
        _, k, p, q = d.dst_dims

        def surf(addr, dims):
            _, c_, h_, w_ = dims
            off = addr - base
            return dram[off:off + c_ * h_ * w_].view(np.int8).reshape(c_, h_, w_)

        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            cin_g = c // d.groups if d.unit == "CONV" else c * h * w
            wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
            wo, bo, so = d.wt_addr - base, d.bias_addr - base, d.scale_addr - base
            wq = dram[wo:wo + wt_n].view(np.int8).reshape(k, -1)
            bias = dram[bo:bo + 4 * k].view(np.int32)
            words = dram[so:so + 4 * k].view(np.uint32)
            x = surf(d.src_addr, d.src_dims)
            if d.unit == "CONV":
                y = refops.conv_int8(x, wq, bias, words, r, d.stride, d.pad,
                                     d.groups, d.relu)
            else:
                y = refops.fc_int8(x, wq, bias, words, d.relu)
        elif d.unit == "PDP":
            x = surf(d.src_addr, d.src_dims)
            r, s = d.kernel
            if d.pool_mode == 1:
                y = refops.maxpool_int8(x, r, d.stride, d.pad)
            else:
                word = engine._pack_scale(d.out_scale)
                if (r, s) == (h, w) and d.pad == 0:
                    y = refops.gap_int8(x, word)
                else:
                    y = refops.avgpool_int8(x, r, d.stride, d.pad, word)
        elif d.unit == "EW":
            a = surf(d.src_addr, d.src_dims)
            b = surf(d.aux_addr, d.src_dims)
            y = refops.add_int8(a, b, engine._pack_scale(d.out_scale),
                                engine._pack_scale(d.aux_scale), d.relu)
        else:
            raise ValueError(d.unit)
        flat = np.asarray(y).reshape(-1)
        doff = d.dst_addr - base
        dram[doff:doff + flat.size] = flat.view(np.uint8)

    def _exec_bf16(self, d: engine.Descriptor, dram: np.ndarray) -> None:
        """nv_full replay: mirrors ``VirtualPlatform._execute_bf16`` over the
        resident arena copy (bf16 surfaces, f32 accumulate, no requant)."""
        import ml_dtypes
        base = self.base
        _, c, h, w = d.src_dims
        _, k, p, q = d.dst_dims

        def surf(addr, dims):
            _, c_, h_, w_ = dims
            off = addr - base
            return dram[off:off + c_ * h_ * w_ * 2] \
                .view(ml_dtypes.bfloat16).reshape(c_, h_, w_)

        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            cin_g = c // d.groups if d.unit == "CONV" else c * h * w
            wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
            wo, bo = d.wt_addr - base, d.bias_addr - base
            wq = dram[wo:wo + 2 * wt_n].view(ml_dtypes.bfloat16).reshape(k, -1)
            bias = dram[bo:bo + 4 * k].view(np.float32)
            x = surf(d.src_addr, d.src_dims)
            if d.unit == "CONV":
                y = refops.conv_bf16(x, wq, bias, r, d.stride, d.pad,
                                     d.groups, d.relu)
            else:
                y = refops.fc_bf16(x, wq, bias, d.relu)
        elif d.unit == "PDP":
            x = surf(d.src_addr, d.src_dims).astype(np.float32)
            r, s = d.kernel
            if d.pool_mode == 1:
                y = refops.pool_f32(x, r, s, d.stride, d.pad, "max")
            elif (r, s) == (h, w) and d.pad == 0:
                y = x.mean(axis=(1, 2), keepdims=True)
            else:
                y = refops.pool_f32(x, r, s, d.stride, d.pad, "avg")
        elif d.unit == "EW":
            a = surf(d.src_addr, d.src_dims).astype(np.float32)
            b = surf(d.aux_addr, d.src_dims).astype(np.float32)
            y = a + b
            if d.relu:
                y = np.maximum(y, 0)
        else:
            raise ValueError(d.unit)
        flat = np.ascontiguousarray(
            np.asarray(y, np.float32).astype(ml_dtypes.bfloat16).reshape(-1))
        doff = d.dst_addr - base
        dram[doff:doff + flat.size * 2] = flat.view(np.uint8)


@register_backend("ref")
def _make_ref(art, **kw):
    return RefExecutor(art.trace, art.weight_image, art.cfg,
                       **_executor_kwargs(art), **kw)
