"""Deterministic fault injection for executor backends.

The chaos harness's single source of truth: a :class:`FaultPlan` wraps any
registered executor in a :class:`FaultyExecutor` that injects faults at the
``run`` / ``run_batch`` boundary — exactly where a real device call would
fail — while delegating everything else (capabilities, arena geometry,
compile counters) to the wrapped backend.  Injection is deterministic: a
seeded RNG drives per-call probabilities, and a ``schedule`` of call indices
scripts exact storms, so a chaos run replays bit-identically.

Fault taxonomy (``FaultSpec.kind``):

    ``error``          — the call raises :class:`InjectedFaultError` (a
                         crashed device submission).
    ``hang``           — the call blocks indefinitely (a wedged accelerator);
                         only the scheduler's watchdog, or an explicit
                         ``release_hangs()``, unblocks it.  A released hang
                         still raises — a call that hung never produced data.
    ``slow``           — the call completes correctly but late, by
                         ``latency_mult`` x its own duration (or an absolute
                         ``delay_s``) — host/accelerator contention.
    ``corrupt_output`` — the call returns, with flipped output bytes.  This
                         is the one *silent* fault: nothing downstream can
                         detect it without a reference — chaos soaks script
                         it only where a reference is available.
    ``corrupt_arena``  — weight-region bytes are scribbled over and the call
                         raises: a crashed DMA poisoning the resident arena.
                         The supervisor's checksum (``arena_ok``) catches it
                         and ``reset_arena()`` heals before the retry.

    Session.load(art, fault_plan=FaultPlan(specs=(
        FaultSpec("error", probability=0.01),
        FaultSpec("hang", schedule=(7,)),
    ), seed=42))
"""

from __future__ import annotations

import dataclasses
import threading
import time
import random
from typing import Optional, Tuple

import numpy as np

from repro.core.executor import ExecResult

FAULT_KINDS = ("error", "hang", "slow", "corrupt_output", "corrupt_arena")


class InjectedFaultError(RuntimeError):
    """Raised by ``FaultyExecutor`` in place of a real backend failure."""

    def __init__(self, kind: str, call_index: int):
        super().__init__(f"injected fault {kind!r} at call {call_index}")
        self.kind, self.call_index = kind, call_index


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault source: what to inject and when.

    A call triggers the spec when its index is in ``schedule`` OR the seeded
    coin with ``probability`` comes up; ``max_faults`` caps total injections
    (None = unbounded) so a scripted outage can end and let recovery happen.
    """
    kind: str
    probability: float = 0.0
    schedule: Tuple[int, ...] = ()
    latency_mult: float = 10.0           # "slow": multiplier on the call's
                                         # own duration
    delay_s: Optional[float] = None      # "slow": absolute delay instead
    max_faults: Optional[int] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if not 0.0 <= self.probability <= 1.0:
            raise ValueError(f"probability must be in [0, 1], "
                             f"got {self.probability}")
        object.__setattr__(self, "schedule",
                           tuple(int(i) for i in self.schedule))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded set of fault sources, injectable into any executor."""
    specs: Tuple[FaultSpec, ...]
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))


class FaultyExecutor:
    """Executor-protocol wrapper injecting a :class:`FaultPlan`.

    Satisfies ``ExecutorBackend`` by delegation: every attribute the
    scheduler or Session consults (``capabilities``, ``input_dims``,
    ``compile_count``, ``arena_ok``/``reset_arena``, ...) resolves on the
    wrapped executor; only ``run`` / ``run_batch`` pass through the
    injection point.  ``faults_injected`` counts injections (mirrored into
    ``NetStats`` by the dispatcher); ``release_hangs()`` unblocks any call
    stuck in a ``hang`` fault (tests/benchmarks call it at teardown so
    abandoned watchdog workers don't linger).
    """

    def __init__(self, inner, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._lock = threading.Lock()
        self._hang = threading.Event()
        self._spec_counts = [0] * len(plan.specs)
        self.call_index = 0              # calls seen (run and run_batch alike)
        self.faults_injected = 0
        self.faults_by_kind = {k: 0 for k in FAULT_KINDS}

    # -- delegation ----------------------------------------------------------
    def __getattr__(self, name):
        return getattr(self.inner, name)

    @property
    def batch_sharding(self):
        return getattr(self.inner, "batch_sharding", None)

    @batch_sharding.setter
    def batch_sharding(self, value):     # the dispatcher assigns this
        setattr(self.inner, "batch_sharding", value)

    def capabilities(self):
        return self.inner.capabilities()

    def release_hangs(self) -> None:
        """Unblock every call stuck in a ``hang`` fault (they then raise)."""
        self._hang.set()

    # -- injection -----------------------------------------------------------
    def _pick(self) -> Tuple[Optional[FaultSpec], int]:
        with self._lock:
            idx = self.call_index
            self.call_index += 1
            for i, spec in enumerate(self.plan.specs):
                if spec.max_faults is not None \
                        and self._spec_counts[i] >= spec.max_faults:
                    continue
                hit = idx in spec.schedule
                if not hit and spec.probability:
                    hit = self._rng.random() < spec.probability
                if hit:
                    self._spec_counts[i] += 1
                    self.faults_injected += 1
                    self.faults_by_kind[spec.kind] += 1
                    return spec, idx
            return None, idx

    def _corrupt_arena(self, idx: int) -> None:
        """Scribble over a weight-region byte range OUTSIDE the input surface
        (the input is rewritten per call — corrupting it would self-heal),
        then drop device copies so the poison is what the next launch sees."""
        inner = self.inner
        eb = inner.cfg.elem_bytes
        in_lo = inner.input_off
        in_hi = in_lo + int(np.prod(inner.input_dims[1:])) * eb
        for off, b in inner._preload:
            lo, hi = off, off + b.size
            if hi <= in_lo or lo >= in_hi:       # disjoint from the input
                span = min(64, b.size)
                inner.arena0[lo:lo + span] ^= 0xA5
                inner._drop_device_state()
                return
        raise RuntimeError("no weight region outside the input surface "
                           "to corrupt")

    def _corrupt_output(self, res: ExecResult) -> ExecResult:
        bad = np.array(res.output_int8, copy=True)
        bad.reshape(-1).view(np.uint8)[...] ^= 0x55
        out = np.array(res.output, copy=True)
        out.reshape(-1)[...] += 1e3
        return ExecResult(output_int8=bad, output=out,
                          degraded=getattr(res, "degraded", False))

    def _call(self, fn):
        spec, idx = self._pick()
        if spec is None:
            return fn()
        if spec.kind == "error":
            raise InjectedFaultError("error", idx)
        if spec.kind == "hang":
            self._hang.wait()                    # until release_hangs()
            raise InjectedFaultError("hang", idx)
        if spec.kind == "slow":
            t0 = time.perf_counter()
            res = fn()
            dt = time.perf_counter() - t0
            time.sleep(spec.delay_s if spec.delay_s is not None
                       else dt * max(spec.latency_mult - 1.0, 0.0))
            return res
        if spec.kind == "corrupt_output":
            return self._corrupt_output(fn())
        self._corrupt_arena(idx)                 # "corrupt_arena"
        raise InjectedFaultError("corrupt_arena", idx)

    # -- executor protocol ---------------------------------------------------
    def run(self, x: np.ndarray) -> ExecResult:
        return self._call(lambda: self.inner.run(x))

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult:
        return self._call(lambda: self.inner.run_batch(X, lanes=lanes))
