"""Runtime layer: executor-backend registry + scheduler + serving Session.

Importing this package registers the built-in backends (``baremetal``,
``linuxstack``, ``ref``).  Layering:

    Session  — residency + name resolution (``repro.runtime.session``)
    Scheduler — request queue, adaptive micro-batching, padding/lane
                masking, multi-device dispatch (``repro.runtime.scheduler``)
    Backends — anything satisfying ``ExecutorBackend``
               (``repro.runtime.registry.register_backend`` to add one)
"""

from repro.core.executor import ExecutorBackend, ExecutorCapabilities
from repro.runtime import backends as _backends  # noqa: F401  (registers builtins)
from repro.runtime.registry import backend_names, create as create_executor, \
    register_backend
from repro.runtime.scheduler import Scheduler, SchedulerConfig
from repro.runtime.session import NetStats, Session

__all__ = ["Session", "NetStats", "Scheduler", "SchedulerConfig",
           "ExecutorBackend", "ExecutorCapabilities", "register_backend",
           "create_executor", "backend_names"]
