"""Runtime layer: executor-backend registry + serving Session.

Importing this package registers the built-in backends (``baremetal``,
``linuxstack``, ``ref``).  See ``repro.runtime.session.Session`` for the
serving API and ``repro.runtime.registry.register_backend`` for adding
custom backends.
"""

from repro.runtime import backends as _backends  # noqa: F401  (registers builtins)
from repro.runtime.registry import backend_names, create as create_executor, \
    register_backend
from repro.runtime.session import NetStats, Session

__all__ = ["Session", "NetStats", "register_backend", "create_executor",
           "backend_names"]
