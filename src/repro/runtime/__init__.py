"""Runtime layer: executor-backend registry + scheduler + serving Session.

Importing this package registers the built-in backends (``baremetal``,
``linuxstack``, ``ref``).  Layering:

    Session  — residency + name resolution (``repro.runtime.session``)
    Scheduler — one dispatcher thread + SLA-ordered queue per resident net:
                adaptive micro-batching, priority/deadline scheduling,
                admission control, padding/lane masking, multi-device
                dispatch (``repro.runtime.scheduler``)
    Backends — anything satisfying ``ExecutorBackend``
               (``repro.runtime.registry.register_backend`` to add one)

The traffic-facing HTTP front-end over this layer lives in ``repro.serve``.
"""

from repro.core.executor import ExecutorBackend, ExecutorCapabilities
from repro.runtime import backends as _backends  # noqa: F401  (registers builtins)
from repro.runtime.faults import (FaultPlan, FaultSpec, FaultyExecutor,
                                  InjectedFaultError)
from repro.runtime.registry import backend_names, create as create_executor, \
    register_backend
from repro.runtime.scheduler import (BackendFaultError, CircuitOpenError,
                                     DeadlineExceededError,
                                     LaunchTimeoutError, QueueFullError,
                                     Scheduler, SchedulerConfig)
from repro.runtime.session import NetStats, Session
from repro.obs.trace import TraceConfig, Tracer

__all__ = ["Session", "NetStats", "Scheduler", "SchedulerConfig",
           "QueueFullError", "DeadlineExceededError", "BackendFaultError",
           "CircuitOpenError", "LaunchTimeoutError",
           "FaultPlan", "FaultSpec", "FaultyExecutor", "InjectedFaultError",
           "ExecutorBackend", "ExecutorCapabilities", "register_backend",
           "create_executor", "backend_names",
           "TraceConfig", "Tracer"]
