"""Pure-jnp oracle for the fused bf16 conv/FC kernels.

Same f32-accumulate semantics as the Pallas kernel (bf16 operands, exact
products, f32 accumulation, f32 bias, round to bf16 at the end) in one
unblocked ``dot_general`` — the independent second implementation is numpy
``core/refops.conv_bf16``; parity against it is tolerance-bounded
(``core/tolerances.py``), never bit-asserted.  ``im2col`` comes from
``core/intmath.py`` — it is dtype-generic, so the int8 and bf16 families
share the one patch-matrix implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.intmath import im2col


def _gemm_epilogue(wq, cols, bias, relu):
    acc = jax.lax.dot_general(wq, cols, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc = acc + bias[:, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(jnp.bfloat16)


def conv2d_bf16_ref(x, wq, bias, k, stride, pad, groups=1,
                    relu=False) -> jax.Array:
    """(C,H,W) bf16 conv oracle: f32-accumulate GEMM + bias/ReLU epilogue."""
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        return _gemm_epilogue(wq, im2col(x, k, stride, pad), bias,
                              relu).reshape(kk, p, q)
    cg, kg = c // groups, kk // groups
    outs = []
    for g in range(groups):
        cols = im2col(x[g * cg:(g + 1) * cg], k, stride, pad)
        outs.append(_gemm_epilogue(wq[g * kg:(g + 1) * kg], cols,
                                   bias[g * kg:(g + 1) * kg], relu))
    return jnp.concatenate(outs, 0).reshape(kk, p, q)


def fc_bf16_ref(x, wq, bias, relu=False) -> jax.Array:
    """x flat bf16, wq (K_out, Cin): FC oracle -> (K_out, 1, 1) bf16."""
    return _gemm_epilogue(wq, x.reshape(-1, 1), bias, relu).reshape(-1, 1, 1)
