"""Pallas TPU kernel: fused BF16 conv-as-GEMM with the nv_full SDP epilogue.

Layout matches ``kernels/int8_conv``: weights (K, C*R*S) times im2col'ed
activations (C*R*S, P*Q) giving (K, P*Q) — output *channels on the M axis*,
so the epilogue (f32 bias add, optional ReLU) broadcasts per row.

Grid (M/bm, N/bn, K/bk), K innermost; the float32 accumulator tile lives in a
VMEM scratch that persists across the K loop (the CACC), and the epilogue runs
in the same kernel on the last K step — the f32 accumulator never round-trips
through HBM, and only the final bf16 tile is written out.  bf16 x bf16
products are exact in f32 (8+8 significand bits < 24), so the only
implementation freedom is f32 summation order — which is what the tolerance
model in ``core/tolerances.py`` budgets for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bf16_conv_kernel(w_ref, x_ref, bias_ref, o_ref, acc_ref, *,
                      relu: bool, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # bf16 x bf16 -> f32: exact products, f32 accumulation on the MXU
    acc_ref[...] += jax.lax.dot_general(
        w_ref[...], x_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        acc = acc_ref[...] + bias_ref[...][:, None]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(jnp.bfloat16)


def bf16_conv_gemm(w: jax.Array, cols: jax.Array, bias: jax.Array, *,
                   relu: bool = False, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = True) -> jax.Array:
    """``bf16((w @ cols) + bias[:,None])`` with f32 accumulate — channels on rows.

    w: (M, K) bfloat16 — weights, M = output channels
    cols: (K, N) bfloat16 — im2col'ed activations, N = output positions P*Q
    bias: (M,) float32
    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = w.shape
    k2, n = cols.shape
    assert k == k2 and bias.shape == (m,)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_bf16_conv_kernel, relu=relu, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.bfloat16),
        # f32 accumulator tile, persistent across the K loop (CACC analogue)
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(w, cols, bias)
