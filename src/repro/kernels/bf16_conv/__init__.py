"""Fused BF16 convolution / FC kernel family (NVDLA nv_full CONV->SDP).

The bf16 twin of ``kernels/int8_conv``: im2col + fused-epilogue GEMM where the
float32 accumulator never leaves VMEM — bf16 x bf16 products are exact in f32
(8-bit significands multiply into 16 bits), accumulation happens in a
persistent f32 scratch tile (the CACC analogue), and the SDP epilogue (f32
bias add, optional ReLU, round back to bf16) runs in the kernel on the last K
step.  No requantisation: nv_full's SDP is a float pipeline.

``ops.conv2d_bf16`` / ``ops.fc_bf16`` are the public entry points used by the
executors through ``perfmodel.select_kernel``; ``ref.py`` holds the pure-jnp
oracle the kernel is tested against (itself tolerance-checked against numpy
``core/refops.conv_bf16`` — see ``core/tolerances.py`` for why bf16 parity is
bounded rather than bit-exact).
"""

from repro.kernels.bf16_conv.ops import (conv2d_bf16, conv2d_bf16_batch,  # noqa: F401
                                         fc_bf16, fc_bf16_batch)
from repro.kernels.bf16_conv.ref import conv2d_bf16_ref, fc_bf16_ref  # noqa: F401
