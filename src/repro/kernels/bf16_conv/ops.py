"""Public fused bf16-conv entry points: pad to MXU blocks, dispatch kernel.

These are the functions ``core/executor.py`` routes CONV/FC descriptors to
when ``perfmodel.select_kernel`` resolves ``pallas_bf16_fused`` on an nv_full
artifact.  They are jit- and vmap-compatible (the batched executor path vmaps
them per lane), and ``interpret=True`` runs the very same kernel through the
Pallas interpreter on CPU — the path the tolerance-parity tests exercise.

Zero padding is epilogue-safe here for the same reason it is in the int8
family: padded K contributes exact 0.0 products to the f32 accumulator, and
padded M/N rows/columns are sliced off before the caller sees them.

``conv2d_bf16_batch`` / ``fc_bf16_batch`` are the natively batched variants:
the coalesced bucket runs as ONE fused launch with the lanes folded onto the
Pallas grid's N axis, so bf16 weights and f32 bias stream from HBM once per
launch.  Folding preserves each column's f32 accumulation order, so the
batched kernel is *bit-identical* to vmapping the single-image kernel over
lanes (the tolerance bound is only needed vs the differently-ordered refops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.intmath import im2col
from repro.kernels.bf16_conv.kernel import bf16_conv_gemm
from repro.kernels.bf16_conv.ref import conv2d_bf16_ref, fc_bf16_ref


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fused_gemm(wq, cols, bias, relu, block_m, block_n, block_k, interpret):
    """Pad operands to block multiples, run the fused kernel, unpad."""
    m, n = wq.shape[0], cols.shape[1]
    wp = _pad_to(_pad_to(wq, block_m, 0), block_k, 1)
    cp = _pad_to(_pad_to(cols, block_k, 0), block_n, 1)
    bp = _pad_to(bias, block_m, 0)
    out = bf16_conv_gemm(wp, cp, bp, relu=relu, block_m=block_m,
                         block_n=block_n, block_k=block_k, interpret=interpret)
    return out[:m, :n]


def _fused_gemm_batch(wq, cols_b, bias, relu, block_m, block_n, block_k,
                      interpret):
    """One fused launch over a (B, K, N) column stack -> (B, M, N); lanes
    fold onto the GEMM N axis so the weight blocks stream once per launch."""
    b, k, n = cols_b.shape
    m = wq.shape[0]
    folded = jnp.moveaxis(cols_b, 0, 1).reshape(k, b * n)
    out = _fused_gemm(wq, folded, bias, relu, block_m, block_n, block_k,
                      interpret)
    return jnp.moveaxis(out.reshape(m, b, n), 0, 1)


def conv2d_bf16(x: jax.Array, wq: jax.Array, bias: jax.Array, k: int,
                stride: int, pad: int, groups: int = 1, relu: bool = False, *,
                use_kernel: bool = True, block_m: int = 128,
                block_n: int = 128, block_k: int = 128,
                interpret: bool = True) -> jax.Array:
    """Fused CONV+SDP: (C,H,W) bf16 -> (K,P,Q) bf16, f32 accumulate.

    x (C,H,W) bfloat16; wq (K, C/g*k*k) bfloat16; bias (K,) float32.
    """
    if not use_kernel:
        return conv2d_bf16_ref(x, wq, bias, k, stride, pad, groups, relu)
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = im2col(x, k, stride, pad)
        out = _fused_gemm(wq, cols, bias, relu, block_m, block_n, block_k,
                          interpret)
        return out.reshape(kk, p, q)
    cg, kg = c // groups, kk // groups
    outs = []
    for g in range(groups):
        cols = im2col(x[g * cg:(g + 1) * cg], k, stride, pad)
        outs.append(_fused_gemm(wq[g * kg:(g + 1) * kg], cols,
                                bias[g * kg:(g + 1) * kg], relu,
                                block_m, block_n, block_k, interpret))
    return jnp.concatenate(outs, 0).reshape(kk, p, q)


def fc_bf16(x: jax.Array, wq: jax.Array, bias: jax.Array,
            relu: bool = False, *, use_kernel: bool = True,
            block_m: int = 128, block_n: int = 128, block_k: int = 128,
            interpret: bool = True) -> jax.Array:
    """Fused FC+SDP: flat bf16 input, wq (K_out, Cin) -> (K_out,1,1) bf16."""
    if not use_kernel:
        return fc_bf16_ref(x, wq, bias, relu)
    cols = x.reshape(-1, 1)
    out = _fused_gemm(wq, cols, bias, relu, block_m, block_n, block_k,
                      interpret)
    return out.reshape(-1, 1, 1)


def conv2d_bf16_batch(xs: jax.Array, wq: jax.Array, bias: jax.Array, k: int,
                      stride: int, pad: int, groups: int = 1,
                      relu: bool = False, *, use_kernel: bool = True,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, interpret: bool = True) -> jax.Array:
    """Natively batched fused CONV+SDP: (B,C,H,W) bf16 -> (B,K,P,Q) bf16.

    ONE kernel launch serves the whole bucket — the batch rides the Pallas
    grid's N axis, bf16 weights and f32 bias stream from HBM once, and the
    fused epilogue + persistent f32 VMEM accumulator are unchanged.
    Bit-identical to ``jax.vmap(conv2d_bf16)`` over the lanes.
    """
    if not use_kernel:
        return jax.vmap(lambda x: conv2d_bf16_ref(x, wq, bias, k, stride,
                                                  pad, groups, relu))(xs)
    b, c, h, w_in = xs.shape
    kk = wq.shape[0]
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = jax.vmap(lambda x: im2col(x, k, stride, pad))(xs)
        out = _fused_gemm_batch(wq, cols, bias, relu, block_m, block_n,
                                block_k, interpret)
        return out.reshape(b, kk, p, q)
    cg, kg = c // groups, kk // groups
    outs = []
    for g in range(groups):
        cols = jax.vmap(
            lambda x: im2col(x[g * cg:(g + 1) * cg], k, stride, pad))(xs)
        outs.append(_fused_gemm_batch(wq[g * kg:(g + 1) * kg], cols,
                                      bias[g * kg:(g + 1) * kg], relu,
                                      block_m, block_n, block_k, interpret))
    return jnp.concatenate(outs, 1).reshape(b, kk, p, q)


def fc_bf16_batch(xs: jax.Array, wq: jax.Array, bias: jax.Array,
                  relu: bool = False, *, use_kernel: bool = True,
                  block_m: int = 128, block_n: int = 128, block_k: int = 128,
                  interpret: bool = True) -> jax.Array:
    """Natively batched fused FC+SDP: (B, Cin) bf16 -> (B, K_out, 1, 1) bf16.

    The bucket IS the GEMM N axis: (K_out, Cin) weights stream once against
    a (Cin, B) activation block instead of once per GEMV lane.
    """
    if not use_kernel:
        return jax.vmap(lambda x: fc_bf16_ref(x, wq, bias, relu))(xs)
    b = xs.shape[0]
    cols = xs.reshape(b, -1).T
    out = _fused_gemm(wq, cols, bias, relu, block_m, block_n, block_k,
                      interpret)
    return out.T.reshape(b, -1, 1, 1)
