"""Pure-jnp oracle for the fused conv/FC kernels (bit-exact vs core/refops).

The SDP epilogue and im2col come from ``core/intmath.py`` — the oracle, the
Pallas kernel and the executors all share ONE copy of the requant semantics,
so a fix cannot silently diverge between arms (the independent second
implementation the parity tests check against is numpy ``core/refops``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.intmath import im2col, row_epilogue as _row_epilogue


def conv2d_int8_ref(x, wq, bias, words, k, stride, pad, groups=1,
                    relu=False) -> jax.Array:
    """(C,H,W) int8 conv oracle: int32-exact GEMM + row epilogue."""
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = im2col(x, k, stride, pad)
        acc = jax.lax.dot_general(wq, cols, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        return _row_epilogue(acc, bias, words, relu).reshape(kk, p, q)
    cg, kg = c // groups, kk // groups
    outs = []
    for g in range(groups):
        cols = im2col(x[g * cg:(g + 1) * cg], k, stride, pad)
        acc = jax.lax.dot_general(wq[g * kg:(g + 1) * kg], cols,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        outs.append(_row_epilogue(acc, bias[g * kg:(g + 1) * kg],
                                  words[g * kg:(g + 1) * kg], relu))
    return jnp.concatenate(outs, 0).reshape(kk, p, q)


def fc_int8_ref(x, wq, bias, words, relu=False) -> jax.Array:
    """x flat int8, wq (K_out, Cin): FC oracle -> (K_out, 1, 1) int8."""
    acc = jax.lax.dot_general(wq, x.reshape(-1, 1), (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return _row_epilogue(acc, bias, words, relu).reshape(-1, 1, 1)
