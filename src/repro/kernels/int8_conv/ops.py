"""Public fused-conv entry points: pad to MXU blocks, dispatch kernel/oracle.

These are the functions ``core/executor.py`` routes CONV/FC descriptors to
when ``perfmodel.select_kernel`` resolves ``pallas_fused``.  They are jit- and
vmap-compatible (the batched executor path vmaps them per lane), and
``interpret=True`` runs the very same kernel through the Pallas interpreter on
CPU — that is the path the parity tests exercise.

``conv2d_int8_batch`` / ``fc_int8_batch`` are the **natively batched**
variants: the whole coalesced bucket runs as ONE fused kernel launch with the
batch dimension folded onto the Pallas grid's N axis (each lane's im2col
columns stacked side by side), so the weight/bias/scale blocks stream from
HBM once per launch and are reused across every lane, instead of once per
vmapped single-image program.  Folding is bit-exact: GEMM columns are
independent, so stacking lanes along N changes neither any product nor any
column's accumulation order, and the fused CONV->SDP epilogue broadcasts per
*row* (output channel) — identical maths for every lane.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.int8_conv.kernel import int8_conv_gemm
from repro.kernels.int8_conv.ref import (conv2d_int8_ref, fc_int8_ref,
                                         im2col)


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _fused_gemm(wq, cols, bias, words, relu, block_m, block_n, block_k,
                interpret):
    """Pad operands to block multiples, run the fused kernel, unpad."""
    m, n = wq.shape[0], cols.shape[1]
    wp = _pad_to(_pad_to(wq, block_m, 0), block_k, 1)
    cp = _pad_to(_pad_to(cols, block_k, 0), block_n, 1)
    bp = _pad_to(bias, block_m, 0)
    sp = _pad_to(words, block_m, 0)
    out = int8_conv_gemm(wp, cp, bp, sp, relu=relu, block_m=block_m,
                         block_n=block_n, block_k=block_k, interpret=interpret)
    return out[:m, :n]


def _fused_gemm_batch(wq, cols_b, bias, words, relu, block_m, block_n,
                      block_k, interpret):
    """One fused launch over a (B, K, N) column stack -> (B, M, N).

    Lanes fold onto the GEMM N axis (column index = lane * N + position), so
    the Pallas grid's j dimension walks every lane while the weight block
    index depends only on (i, k) — weights stream once per launch.  N-axis
    padding lands after the last lane's columns and is sliced off before the
    unfold.
    """
    b, k, n = cols_b.shape
    m = wq.shape[0]
    folded = jnp.moveaxis(cols_b, 0, 1).reshape(k, b * n)
    out = _fused_gemm(wq, folded, bias, words, relu, block_m, block_n,
                      block_k, interpret)
    return jnp.moveaxis(out.reshape(m, b, n), 0, 1)


def conv2d_int8(x: jax.Array, wq: jax.Array, bias: jax.Array,
                words: jax.Array, k: int, stride: int, pad: int,
                groups: int = 1, relu: bool = False, *,
                use_kernel: bool = True, block_m: int = 128,
                block_n: int = 128, block_k: int = 128,
                interpret: bool = True) -> jax.Array:
    """Fused CONV+SDP: (C,H,W) int8 -> (K,P,Q) int8, bit-exact vs refops.

    x (C,H,W) int8; wq (K, C/g*k*k) int8; bias/words (K,) int32.
    """
    if not use_kernel:
        return conv2d_int8_ref(x, wq, bias, words, k, stride, pad, groups, relu)
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = im2col(x, k, stride, pad)
        out = _fused_gemm(wq, cols, bias, words, relu, block_m, block_n,
                          block_k, interpret)
        return out.reshape(kk, p, q)
    cg, kg = c // groups, kk // groups
    outs = []
    for g in range(groups):
        cols = im2col(x[g * cg:(g + 1) * cg], k, stride, pad)
        outs.append(_fused_gemm(wq[g * kg:(g + 1) * kg], cols,
                                bias[g * kg:(g + 1) * kg],
                                words[g * kg:(g + 1) * kg], relu,
                                block_m, block_n, block_k, interpret))
    return jnp.concatenate(outs, 0).reshape(kk, p, q)


def fc_int8(x: jax.Array, wq: jax.Array, bias: jax.Array, words: jax.Array,
            relu: bool = False, *, use_kernel: bool = True,
            block_m: int = 128, block_n: int = 128, block_k: int = 128,
            interpret: bool = True) -> jax.Array:
    """Fused FC+SDP: flat int8 input, wq (K_out, Cin) -> (K_out,1,1) int8."""
    if not use_kernel:
        return fc_int8_ref(x, wq, bias, words, relu)
    cols = x.reshape(-1, 1)
    out = _fused_gemm(wq, cols, bias, words, relu, block_m, block_n, block_k,
                      interpret)
    return out.reshape(-1, 1, 1)


def conv2d_int8_batch(xs: jax.Array, wq: jax.Array, bias: jax.Array,
                      words: jax.Array, k: int, stride: int, pad: int,
                      groups: int = 1, relu: bool = False, *,
                      use_kernel: bool = True, block_m: int = 128,
                      block_n: int = 128, block_k: int = 128,
                      interpret: bool = True) -> jax.Array:
    """Natively batched fused CONV+SDP: (B,C,H,W) int8 -> (B,K,P,Q) int8.

    ONE kernel launch serves the whole bucket — the batch rides the Pallas
    grid's N axis, so weights/bias/scale stream from HBM once and the fused
    epilogue + persistent VMEM accumulator are unchanged.  Bit-exact vs
    ``jax.vmap(conv2d_int8)`` over the lanes (column independence).
    """
    if not use_kernel:
        return jax.vmap(lambda x: conv2d_int8_ref(x, wq, bias, words, k,
                                                  stride, pad, groups,
                                                  relu))(xs)
    b, c, h, w_in = xs.shape
    kk = wq.shape[0]
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = jax.vmap(lambda x: im2col(x, k, stride, pad))(xs)
        out = _fused_gemm_batch(wq, cols, bias, words, relu, block_m,
                                block_n, block_k, interpret)
        return out.reshape(b, kk, p, q)
    cg, kg = c // groups, kk // groups
    outs = []
    for g in range(groups):
        cols = jax.vmap(
            lambda x: im2col(x[g * cg:(g + 1) * cg], k, stride, pad))(xs)
        outs.append(_fused_gemm_batch(wq[g * kg:(g + 1) * kg], cols,
                                      bias[g * kg:(g + 1) * kg],
                                      words[g * kg:(g + 1) * kg], relu,
                                      block_m, block_n, block_k, interpret))
    return jnp.concatenate(outs, 1).reshape(b, kk, p, q)


def fc_int8_batch(xs: jax.Array, wq: jax.Array, bias: jax.Array,
                  words: jax.Array, relu: bool = False, *,
                  use_kernel: bool = True, block_m: int = 128,
                  block_n: int = 128, block_k: int = 128,
                  interpret: bool = True) -> jax.Array:
    """Natively batched fused FC+SDP: (B, Cin) int8 -> (B, K_out, 1, 1) int8.

    The bucket IS the GEMM N axis — the single-image path is a GEMV that
    re-streams the whole weight matrix per lane; here (K_out, Cin) streams
    once against a (Cin, B) activation block.
    """
    if not use_kernel:
        return jax.vmap(lambda x: fc_int8_ref(x, wq, bias, words, relu))(xs)
    b = xs.shape[0]
    cols = xs.reshape(b, -1).T
    out = _fused_gemm(wq, cols, bias, words, relu, block_m, block_n, block_k,
                      interpret)
    return out.T.reshape(b, -1, 1, 1)
