"""Pallas TPU kernel: fused INT8 conv-as-GEMM with NVDLA CONV->SDP epilogue.

The conv layout keeps NVDLA's feature-data orientation: weights (K, C*R*S)
times im2col'ed activations (C*R*S, P*Q) giving (K, P*Q) — output *channels on
the M axis*, so the SDP epilogue (int32 bias add, per-channel fixed-point
requant ``((acc >> pre) * m) >> post`` with round-half-away, optional ReLU,
int8 clip) broadcasts per *row*.  This is the transpose of
``kernels/int8_gemm`` (per-column epilogue) and saves the two P*Q-sized
transposes an adapter would need on the executor hot path.

Grid (M/bm, N/bn, K/bk), K innermost; the int32 accumulator tile lives in a
VMEM scratch that persists across the K loop (the CACC), and the epilogue runs
in the same kernel on the last K step — the accumulator never round-trips
through HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the SDP epilogue is plain jnp and shared with the executors' op closures —
# ONE copy of the requant semantics (see core/intmath.py)
from repro.core.intmath import row_epilogue as _row_epilogue


def _int8_conv_kernel(w_ref, x_ref, bias_ref, scale_ref, o_ref, acc_ref, *,
                      relu: bool, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        w_ref[...], x_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = _row_epilogue(acc_ref[...], bias_ref[...], scale_ref[...],
                                   relu)


def int8_conv_gemm(w: jax.Array, cols: jax.Array, bias: jax.Array,
                   scale_words: jax.Array, *, relu: bool = False,
                   block_m: int = 128, block_n: int = 128, block_k: int = 128,
                   interpret: bool = True) -> jax.Array:
    """``clip8(requant((w @ cols) + bias[:,None]))`` — channels on rows.

    w: (M, K) int8 — weights, M = output channels
    cols: (K, N) int8 — im2col'ed activations, N = output positions P*Q
    bias: (M,) int32; scale_words: (M,) int32 packed (m,pre,post)
    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = w.shape
    k2, n = cols.shape
    assert k == k2 and bias.shape == (m,) and scale_words.shape == (m,)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_int8_conv_kernel, relu=relu, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m,), lambda i, j, kk: (i,)),
            pl.BlockSpec((block_m,), lambda i, j, kk: (i,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        # int32 accumulator tile, persistent across the K loop (CACC analogue)
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(w, cols, bias, scale_words)
