"""Fused INT8 convolution / FC kernel family (NVDLA CONV->SDP, TPU-native).

Extends ``kernels/int8_gemm`` from a bare GEMM into the executor-facing conv
path: im2col + fused-epilogue GEMM where the int32 accumulator never leaves
VMEM — bias add, per-output-channel fixed-point requantisation, ReLU and the
int8 clip all happen in the kernel epilogue (NVDLA's CACC->SDP pipeline).

``ops.conv2d_int8`` / ``ops.fc_int8`` are the public entry points used by the
executors through ``perfmodel.select_kernel``; ``ref.py`` holds the pure-jnp
oracle the kernel is tested against (itself bit-exact vs ``core/refops``).
"""

from repro.kernels.int8_conv.ops import (conv2d_int8, conv2d_int8_batch,  # noqa: F401
                                         fc_int8, fc_int8_batch)
from repro.kernels.int8_conv.ref import conv2d_int8_ref, fc_int8_ref  # noqa: F401
