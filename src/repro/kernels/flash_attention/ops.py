"""Jit'd wrapper: GQA head handling + padding + kernel/oracle dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel", "block_q",
                                             "block_k", "interpret"))
def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        use_kernel: bool = True, block_q: int = 128, block_k: int = 128,
        interpret: bool = True) -> jax.Array:
    """Multi-head attention with GQA.

    q (B, Hq, Sq, D); k/v (B, Hkv, Skv, D); Hq % Hkv == 0 -> (B, Hq, Sq, D).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1).reshape(b * hq, skv, d)
    vf = jnp.repeat(v, group, axis=1).reshape(b * hq, skv, d)
    qf = q.reshape(b * hq, sq, d)
    if not use_kernel:
        return attention_ref(qf, kf, vf, causal=causal).reshape(b, hq, sq, d)
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    qp = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention(qp, kp, vp, causal=causal, block_q=bq, block_k=bk,
                          kv_len=skv, interpret=interpret)
    return out[:, :sq].reshape(b, hq, sq, d)
