"""Pallas TPU kernel: blocked flash attention (prefill path).

Streaming-softmax attention tiled for VMEM: grid (batch*heads, Sq/bq, Skv/bk)
with the running max / normaliser / f32 accumulator held in VMEM scratch across
the KV loop (FlashAttention-2 schedule).  Used by the serving layer for prefill
shapes; the decode path has its own split-K kernel (kernels/decode_attention).

Causal masking is applied with block-level early-out arithmetic (fully-masked
blocks still iterate in interpret mode; on TPU the mask folds into the MXU
epilogue).  GQA is handled by the ops.py wrapper (KV heads broadcast to Q heads
before the kernel; a production TPU variant would index KV blocks instead).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv: int, kv_len: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale            # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    v = v_ref[0].astype(jnp.float32)                       # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = k_pos < kv_len                      # mask padded KV columns
    if causal:
        iq = pl.program_id(1)
        q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        valid = valid & (q_pos >= k_pos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                    # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                 # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                        # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    kv_len: int | None = None, interpret: bool = True) -> jax.Array:
    """q (BH, Sq, D), k/v (BH, Skv, D) -> (BH, Sq, D). Sq%bq == Skv%bk == 0.

    ``kv_len``: true (unpadded) KV length; columns beyond it are masked.
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = kv_len if kv_len is not None else skv
    assert sq % block_q == 0 and skv % block_k == 0
    n_kv = skv // block_k
    grid = (bh, sq // block_q, n_kv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k, n_kv=n_kv,
                          kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # normaliser
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
