"""Pure-jnp oracle: exact softmax attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, sm_scale: float | None = None) -> jax.Array:
    """q (BH, Sq, D), k/v (BH, Skv, D) -> (BH, Sq, D), f32 math."""
    d = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
