"""Jit'd wrapper for decode attention: GQA + padding + partial-combine export."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "block_k", "interpret"))
def gqa_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
               use_kernel: bool = True, block_k: int = 512,
               interpret: bool = True) -> jax.Array:
    """q (B, Hq, 1, D); k/v (B, Hkv, S, D) -> (B, Hq, 1, D)."""
    b, hq, _, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    qf = q.reshape(b * hq, 1, d)
    kf = jnp.repeat(k, group, axis=1).reshape(b * hq, s, d)
    vf = jnp.repeat(v, group, axis=1).reshape(b * hq, s, d)
    if not use_kernel:
        return decode_attention_ref(qf, kf, vf).reshape(b, hq, 1, d)
    bk = min(block_k, s)
    pad = (-s) % bk
    kp = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = decode_attention(qf, kp, vp, block_k=bk, kv_len=s, interpret=interpret)
    return out.reshape(b, hq, 1, d)


def partial_softmax(q: jax.Array, k: jax.Array, v: jax.Array,
                    sm_scale: float | None = None):
    """One device's partial (acc, m, l) for distributed flash-decode.

    q (BH, 1, D), k/v (BH, Sshard, D) -> (acc (BH,1,D) f32, m (BH,1,1), l (BH,1,1)).
    Combine across shards with: m* = max m_i; l* = sum l_i exp(m_i - m*);
    out = sum acc_i l_i exp(m_i - m*) / l*.  (Pure jnp: it must lower through
    shard_map for the dry-run; the Pallas kernel is the intra-chip tier.)
    """
    d = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    m = jnp.max(s, axis=-1, keepdims=True)                      # (BH, 1, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)                      # (BH, 1, 1)
    acc = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)) / jnp.maximum(l, 1e-30)
    return acc, m, l
