"""Pure-jnp oracle for decode attention (one query vs KV cache)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         sm_scale: float | None = None,
                         kv_len: int | None = None) -> jax.Array:
    """q (BH, 1, D), k/v (BH, S, D) -> (BH, 1, D)."""
    d = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if kv_len is not None:
        pos = jnp.arange(k.shape[1])[None, None, :]
        s = jnp.where(pos < kv_len, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
