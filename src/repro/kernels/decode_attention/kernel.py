"""Pallas TPU kernel: single-token decode attention (flash-decode split-K).

Decode shape: one query token against a long KV cache — the dominant op of the
``decode_32k`` / ``long_500k`` cells.  The KV sequence is split into blocks
(split-K); each block computes a partial (max, normaliser, accumulator) triple
carried in VMEM scratch, combined online across the grid's KV dimension.

The same partial-softmax combine (m, l, acc) is reused ACROSS DEVICES by the
distributed sequence-parallel decode path (distributed/sp_decode.py): each
device runs this kernel over its KV shard and the shards are merged with one
psum — the kernel is the intra-chip tier of a two-tier flash-decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                   sm_scale: float, block_k: int, n_kv: int, kv_len: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * sm_scale               # (1, d)
    k = k_ref[0].astype(jnp.float32)                          # (bk, d)
    v = v_ref[0].astype(jnp.float32)                          # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (1, bk)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     sm_scale: float | None = None, block_k: int = 512,
                     kv_len: int | None = None,
                     interpret: bool = True) -> jax.Array:
    """q (BH, 1, D), k/v (BH, S, D) -> (BH, 1, D); S % block_k == 0."""
    bh, _, d = q.shape
    skv = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = kv_len if kv_len is not None else skv
    assert skv % block_k == 0
    n_kv = skv // block_k
    return pl.pallas_call(
        functools.partial(_decode_kernel, sm_scale=sm_scale, block_k=block_k,
                          n_kv=n_kv, kv_len=kv_len),
        grid=(bh, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
