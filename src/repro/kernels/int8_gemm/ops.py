"""Jit'd public wrapper: pads to block multiples, dispatches kernel or oracle."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int8_gemm.kernel import int8_gemm
from repro.kernels.int8_gemm.ref import int8_gemm_ref


def _pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("relu", "use_kernel", "block_m",
                                             "block_n", "block_k", "interpret"))
def quantized_matmul(x: jax.Array, w: jax.Array, bias: jax.Array,
                     scale_words: jax.Array, *, relu: bool = False,
                     use_kernel: bool = True, block_m: int = 128,
                     block_n: int = 128, block_k: int = 128,
                     interpret: bool = True) -> jax.Array:
    """W8A8 matmul with fused SDP epilogue; pads/unpads to MXU-aligned blocks.

    x (M,K) int8, w (K,N) int8, bias (N,) int32, scale_words (N,) int32
    -> (M,N) int8.  ``use_kernel=False`` runs the pure-jnp oracle (used on CPU
    hot paths; the Pallas kernel is the TPU-target implementation, validated in
    interpret mode by tests/test_kernels.py).
    """
    m, k = x.shape
    n = w.shape[1]
    if not use_kernel:
        return int8_gemm_ref(x, w, bias, scale_words, relu=relu)
    xp = _pad_to(_pad_to(x, block_m, 0), block_k, 1)
    wp = _pad_to(_pad_to(w, block_k, 0), block_n, 1)
    bp = _pad_to(bias, block_n, 0)
    sp = _pad_to(scale_words, block_n, 0)
    out = int8_gemm(xp, wp, bp, sp, relu=relu, block_m=block_m, block_n=block_n,
                    block_k=block_k, interpret=interpret)
    return out[:m, :n]
