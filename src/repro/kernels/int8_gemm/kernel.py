"""Pallas TPU kernel: W8A8 INT8 GEMM with fused NVDLA-SDP epilogue.

This is the MAC-array of the paper's engine, re-blocked for the TPU MXU:
NVDLA's direct-convolution dataflow (weight-stationary 64-MAC array fed by the
CBUF) becomes an im2col GEMM tiled over VMEM, with the SDP post-processing —
int32 bias add, per-output-channel fixed-point requantisation
(``((acc >> pre) * m) >> post``, round-half-away), optional ReLU, int8 clip —
fused into the epilogue so the accumulator never leaves VMEM.  That fusion is
exactly NVDLA's CACC->SDP pipeline, expressed TPU-natively.

Grid: (M/bm, N/bn, K/bk), K innermost; int32 accumulation lives in a VMEM
scratch tile that persists across the K loop.  Block sizes default to
128x128x128 (MXU-aligned; int8 feeds the MXU at full rate on v5e).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rha_shift(x, k):
    """Round-half-away arithmetic right shift on int32."""
    half = jnp.where(k > 0, jnp.left_shift(jnp.int32(1), jnp.maximum(k - 1, 0)), 0)
    return jnp.sign(x) * jnp.right_shift(jnp.abs(x) + half, k)


def _epilogue(acc, bias, words, relu):
    """SDP: +bias, per-channel fixed-point requant, relu, clip to int8."""
    acc = acc + bias[None, :]
    m = jnp.right_shift(words, 16) & 0xFFFF
    m = jnp.where(m >= 0x8000, m - 0x10000, m)
    pre = jnp.right_shift(words, 8) & 0xFF
    post = words & 0xFF
    out = _rha_shift(_rha_shift(acc, pre[None, :]) * m[None, :], post[None, :])
    if relu:
        out = jnp.maximum(out, 0)
    return jnp.clip(out, -128, 127).astype(jnp.int8)


def _int8_gemm_kernel(x_ref, w_ref, bias_ref, scale_ref, o_ref, acc_ref, *,
                      relu: bool, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], bias_ref[...], scale_ref[...], relu)


def int8_gemm(x: jax.Array, w: jax.Array, bias: jax.Array, scale_words: jax.Array,
              *, relu: bool = False, block_m: int = 128, block_n: int = 128,
              block_k: int = 128, interpret: bool = True) -> jax.Array:
    """``clip8(requant((x @ w) + bias))``.

    x: (M, K) int8 — im2col'ed activations
    w: (K, N) int8 — weights (output channel = N)
    bias: (N,) int32; scale_words: (N,) int32 packed (m,pre,post) — see core/quant.py
    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and bias.shape == (n,) and scale_words.shape == (n,)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_int8_gemm_kernel, relu=relu, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        # int32 accumulator tile, persistent across the K loop (CACC analogue)
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x, w, bias, scale_words)
