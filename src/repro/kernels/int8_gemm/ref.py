"""Pure-jnp oracle for the int8 GEMM + SDP epilogue kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _rha_shift(x, k):
    half = jnp.where(k > 0, jnp.left_shift(jnp.int32(1), jnp.maximum(k - 1, 0)), 0)
    return jnp.sign(x) * jnp.right_shift(jnp.abs(x) + half, k)


def int8_gemm_ref(x: jax.Array, w: jax.Array, bias: jax.Array,
                  scale_words: jax.Array, *, relu: bool = False) -> jax.Array:
    acc = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    acc = acc + bias[None, :].astype(jnp.int32)
    words = scale_words
    m = jnp.right_shift(words, 16) & 0xFFFF
    m = jnp.where(m >= 0x8000, m - 0x10000, m)
    pre = jnp.right_shift(words, 8) & 0xFF
    post = words & 0xFF
    out = _rha_shift(_rha_shift(acc, pre[None, :]) * m[None, :], post[None, :])
    if relu:
        out = jnp.maximum(out, 0)
    return jnp.clip(out, -128, 127).astype(jnp.int8)
