"""Tensor payload codecs for the serving front-end.

Two request formats, negotiated by Content-Type:

  * ``application/json`` — ``{"input": <nested list>, "dtype": "float32",
    "priority": 0, "deadline_us": 50000}``.  ``dtype`` is optional
    (``float32`` default; ``int8`` means pre-quantised, passed through).
    ``priority`` / ``deadline_us`` may also come as query parameters.
  * ``application/x-npy`` (or ``application/octet-stream``) — the body is
    one ``.npy`` file, exactly what ``np.save`` writes.  Scheduling fields
    travel as query parameters.

Responses mirror the negotiation: JSON by default (int8 logits are small —
exact integers survive JSON round-trips, which is what the bit-exactness
tests assert), or a raw ``.npy`` of ``output_int8`` when the client sends
``Accept: application/x-npy``.  On a bf16 (``nv_full``) net, ``output_int8``
carries the raw bf16 byte stream (the engine's output surface, uint8) and
``output`` the decoded float values — check ``GET /v1/nets`` ``dtype`` to
know which you are talking to.

Malformed payloads raise ``ValueError`` — the layer above maps it to 400.
"""

from __future__ import annotations

import io
import json
from typing import Dict, Tuple

import numpy as np

NPY_TYPES = ("application/x-npy", "application/octet-stream")
JSON_TYPE = "application/json"

# inputs a client may legitimately send: float32 activations (quantised by
# the backend) or pre-quantised int8
_INPUT_DTYPES = {"float32": np.float32, "int8": np.int8}


def decode_request(body: bytes, content_type: str) -> Tuple[np.ndarray, Dict]:
    """Parse one inference request body -> (input array, scheduling meta).

    ``meta`` may carry ``priority`` / ``deadline_us`` (JSON bodies only;
    npy clients use query parameters).  Raises ``ValueError`` on anything
    malformed — never an exception from deep inside numpy/json.
    """
    ctype = (content_type or "").split(";")[0].strip().lower()
    if ctype in NPY_TYPES:
        try:
            x = np.load(io.BytesIO(body), allow_pickle=False)
        except Exception as e:
            raise ValueError(f"bad npy payload: {e}") from None
        return x, {}
    if ctype in ("", JSON_TYPE):            # default to JSON
        try:
            doc = json.loads(body.decode("utf-8"))
        except Exception as e:
            raise ValueError(f"bad JSON payload: {e}") from None
        if not isinstance(doc, dict) or "input" not in doc:
            raise ValueError('JSON payload must be an object with an "input" '
                             'field (nested list of numbers)')
        dtype = doc.get("dtype", "float32")
        if dtype not in _INPUT_DTYPES:
            raise ValueError(f"unsupported dtype {dtype!r}; expected one of "
                             f"{sorted(_INPUT_DTYPES)}")
        try:
            x = np.asarray(doc["input"], dtype=_INPUT_DTYPES[dtype])
        except Exception as e:
            raise ValueError(f'bad "input" field: {e}') from None
        meta = {}
        for key, cast in (("priority", int), ("deadline_us", float)):
            if doc.get(key) is not None:
                try:
                    meta[key] = cast(doc[key])
                except (TypeError, ValueError):
                    raise ValueError(f"bad {key!r}: {doc[key]!r}") from None
        return x, meta
    raise ValueError(f"unsupported Content-Type {content_type!r}; send "
                     f"{JSON_TYPE} or {NPY_TYPES[0]}")


def encode_result(net: str, res, latency_us: float,
                  accept: str = "") -> Tuple[bytes, str]:
    """Serialise an ``ExecResult`` -> (body, content_type)."""
    if any(t in (accept or "") for t in NPY_TYPES):
        buf = io.BytesIO()
        np.save(buf, np.asarray(res.output_int8))
        return buf.getvalue(), NPY_TYPES[0]
    out_i8 = np.asarray(res.output_int8)
    out = np.asarray(res.output, dtype=np.float64)
    doc = {
        "net": net,
        "output_int8": out_i8.tolist(),
        "output": out.tolist(),
        # argmax over the float output: identical to argmax(output_int8) on
        # int8 nets (dequant is a positive per-tensor scale) and the only
        # meaningful choice on bf16 nets, where output_int8 carries the raw
        # bf16 byte stream
        "argmax": int(np.argmax(out)),
        "latency_us": round(float(latency_us), 1),
    }
    if getattr(res, "degraded", False):
        # served by the fallback backend while the primary's circuit was
        # open; npy responses signal this via the X-Repro-Degraded header
        doc["degraded"] = True
    return json.dumps(doc).encode("utf-8"), JSON_TYPE


def encode_error(status: int, code: str, message: str,
                 retry_after_s=None, trace_id=None) -> Tuple[bytes, str]:
    doc = {"error": {"status": status, "code": code, "message": message}}
    if retry_after_s is not None:
        doc["error"]["retry_after_s"] = round(float(retry_after_s), 3)
    if trace_id is not None:
        # rejected/shed requests stay correlatable: the same id rides the
        # X-Repro-Trace-Id response header and the tracer's record
        doc["error"]["trace_id"] = trace_id
    return json.dumps(doc).encode("utf-8"), JSON_TYPE
