"""CLI: serve saved artifact bundles — or import+compile models — over HTTP.

    PYTHONPATH=src python -m repro.serve --artifacts lenet5_bundle \
        --artifacts resnet18_bundle --backend baremetal --port 8000 \
        --max-queue 256 --max-batch 8 --max-wait-us 200

    # no pre-compiled bundle needed: builder names and model files
    # (ONNX / repro-net-v1 JSON) compile on startup via repro.frontend
    PYTHONPATH=src python -m repro.serve --model lenet5 \
        --model examples/models/tinynet.json

Each ``--artifacts`` directory is an ``Artifacts.save`` bundle; it becomes
resident under its manifest ``graph_name`` (override one with
``--artifacts dir:name``).  ``--model`` accepts anything
``repro.frontend.resolve.resolve_net`` does (builder name or model file; an
unsupported model fails here at startup, with the frontend's descriptive
error).  Every net gets its own dispatcher thread; ``--max-queue`` bounds
each queue (admission control -> HTTP 429).
"""

from __future__ import annotations

import argparse

from repro.core.pipeline import Artifacts, CompilerPipeline
from repro.runtime import Session, SchedulerConfig
from repro.serve.config import ServeConfig
from repro.serve.http import serve_forever


def _split_name(spec: str) -> tuple:
    """``SPEC[:NAME]`` — the trailing ``:NAME`` must look like a bare name
    (no path separators / suffix dots), so ``dir/net.onnx`` stays a path."""
    head, sep, tail = spec.rpartition(":")
    if sep and tail and "/" not in tail and "\\" not in tail \
            and "." not in tail:
        return head, tail
    return spec, None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="multi-tenant HTTP serving front-end over repro.runtime")
    ap.add_argument("--artifacts", action="append", default=[],
                    metavar="DIR[:NAME]",
                    help="saved Artifacts bundle to serve (repeatable)")
    ap.add_argument("--model", action="append", default=[],
                    metavar="SPEC[:NAME]",
                    help="builder name or ONNX/JSON model file to import, "
                         "compile and serve (repeatable)")
    ap.add_argument("--backend", default="baremetal",
                    help="executor backend for every net (default: baremetal)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="0 picks an ephemeral port")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="coalescing ceiling per dispatch")
    ap.add_argument("--max-wait-us", type=float, default=200.0,
                    help="longest the head request is held for stragglers")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="per-net queue bound; past it submits get 429 "
                         "(0 = unbounded)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="failed launches are retried this many times with "
                         "exponential backoff before futures fail")
    ap.add_argument("--fallback-backend", default=None, metavar="BACKEND",
                    help="degraded-mode backend (e.g. 'ref') every net "
                         "falls back to while its circuit breaker is open; "
                         "default: shed with 503 + Retry-After")
    ap.add_argument("--warmup", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="precompile every (net, bucket) program before "
                         "admitting traffic; inference returns 503 and "
                         "/healthz reports 'warming' until done "
                         "(--no-warmup serves immediately, first requests "
                         "may compile-stall)")
    ap.add_argument("--trace", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="record request lifecycle traces (GET /v1/trace; "
                         "--no-trace keeps trace ids but records nothing)")
    ap.add_argument("--trace-sample", type=int, default=1, metavar="N",
                    help="trace every Nth request per net (1 = all, 0 = "
                         "only requests carrying X-Repro-Trace-Id)")
    ap.add_argument("--profile", action="store_true",
                    help="run sampled requests through the per-layer "
                         "profiled path (slower; for calibration runs)")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="dump the trace ring buffer as Chrome trace-event "
                         "JSON (DIR/trace.json) on shutdown")
    ap.add_argument("--slo", default=None, metavar="FILE",
                    help="JSON file of SLO policies (per-net latency/"
                         "error-rate/goodput objectives); the burn-rate "
                         "engine evaluates them continuously and surfaces "
                         "state on /metrics, /healthz and GET /v1/slo")
    ap.add_argument("--slo-period-s", type=float, default=5.0,
                    help="background SLO evaluation cadence (seconds)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-request access logs")
    args = ap.parse_args(argv)
    if not args.artifacts and not args.model:
        ap.error("nothing to serve: pass --artifacts and/or --model")

    cfg = SchedulerConfig(max_batch=args.max_batch,
                          max_wait_us=args.max_wait_us,
                          max_queue=args.max_queue or None,
                          max_retries=args.max_retries)
    serve_cfg = ServeConfig(fallback_backend=args.fallback_backend,
                            warmup=args.warmup, trace=args.trace,
                            trace_sample=args.trace_sample,
                            trace_profile=args.profile,
                            trace_dir=args.trace_dir,
                            slo_path=args.slo,
                            slo_period_s=args.slo_period_s)
    ses = Session(scheduler=cfg, backend=args.backend,
                  trace=serve_cfg.trace_config())
    if serve_cfg.slo_path:
        from repro.obs.slo import load_policies
        policies = load_policies(serve_cfg.slo_path)
        ses.attach_slo(policies, start=True, period_s=serve_cfg.slo_period_s)
        print(f"[repro.serve] slo: {len(policies)} policy(ies) from "
              f"{serve_cfg.slo_path}, evaluating every "
              f"{serve_cfg.slo_period_s:g}s")
    for spec in args.artifacts:
        path, _, name = spec.partition(":")
        loaded = ses.load(Artifacts.load(path), name=name or None,
                          fallback_backend=serve_cfg.fallback_backend)
        print(f"[repro.serve] resident: {loaded} <- {path}")
    for spec in args.model:
        from repro.frontend.resolve import resolve_net
        src, name = _split_name(spec)
        g, params = resolve_net(src)
        art = CompilerPipeline(g, params=params).run()
        loaded = ses.load(art, name=name or None,
                          fallback_backend=serve_cfg.fallback_backend)
        print(f"[repro.serve] resident: {loaded} <- compiled {src}")
    serve_forever(ses, host=args.host, port=args.port,
                  verbose=not args.quiet, warmup=serve_cfg.warmup,
                  trace_dir=serve_cfg.trace_dir)


if __name__ == "__main__":
    main()
