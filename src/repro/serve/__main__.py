"""CLI: serve saved artifact bundles over HTTP.

    PYTHONPATH=src python -m repro.serve --artifacts lenet5_bundle \
        --artifacts resnet18_bundle --backend baremetal --port 8000 \
        --max-queue 256 --max-batch 8 --max-wait-us 200

Each ``--artifacts`` directory is an ``Artifacts.save`` bundle; it becomes
resident under its manifest ``graph_name`` (override one with
``--artifacts dir:name``).  Every net gets its own dispatcher thread;
``--max-queue`` bounds each queue (admission control -> HTTP 429).
"""

from __future__ import annotations

import argparse

from repro.core.pipeline import Artifacts
from repro.runtime import Session, SchedulerConfig
from repro.serve.http import serve_forever


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="multi-tenant HTTP serving front-end over repro.runtime")
    ap.add_argument("--artifacts", action="append", required=True,
                    metavar="DIR[:NAME]",
                    help="saved Artifacts bundle to serve (repeatable)")
    ap.add_argument("--backend", default="baremetal",
                    help="executor backend for every net (default: baremetal)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="0 picks an ephemeral port")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="coalescing ceiling per dispatch")
    ap.add_argument("--max-wait-us", type=float, default=200.0,
                    help="longest the head request is held for stragglers")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="per-net queue bound; past it submits get 429 "
                         "(0 = unbounded)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-request access logs")
    args = ap.parse_args(argv)

    cfg = SchedulerConfig(max_batch=args.max_batch,
                          max_wait_us=args.max_wait_us,
                          max_queue=args.max_queue or None)
    ses = Session(scheduler=cfg, backend=args.backend)
    for spec in args.artifacts:
        path, _, name = spec.partition(":")
        loaded = ses.load(Artifacts.load(path), name=name or None)
        print(f"[repro.serve] resident: {loaded} <- {path}")
    serve_forever(ses, host=args.host, port=args.port,
                  verbose=not args.quiet)


if __name__ == "__main__":
    main()
