"""Prometheus text-format rendering of per-net serving stats.

``render(session)`` walks every resident network, takes one coherent
``NetStats.snapshot()`` each (the snapshot is the concurrency boundary —
this module only formats), and emits the Prometheus exposition format
(text/plain; version 0.0.4) that ``GET /metrics`` returns.  Stdlib only.

Conformance notes (`tests/test_serve.py` round-trips this through a strict
parser): every series gets ``# HELP`` + ``# TYPE``; label values escape
``\\``, ``"`` and newlines; HELP text escapes ``\\`` and newlines; the
latency summary carries ``_sum``/``_count`` alongside its quantiles; and
the tracer's per-phase latency histograms render as proper cumulative
``_bucket{le=...}`` series ending at ``le="+Inf"`` with ``_sum``/``_count``.
The windowed telemetry adds ``request_latency_us`` (an every-request
cumulative histogram), per-window ``window_*`` gauges, and — when an SLO
engine is attached — the ``slo_state`` (0 ok / 1 warning / 2 breach) and
``slo_burn_rate`` gauges, all under the same conformance rules.
"""

from __future__ import annotations

from typing import List

from repro.obs.slo import STATE_CODES as _STATE_CODES

# (metric suffix, snapshot key, TYPE, HELP)
_COUNTERS = [
    ("requests_total", "submits", "counter",
     "Requests admitted to the queue (run/run_batch included)"),
    ("rejected_total", "rejected", "counter",
     "Requests rejected by admission control (queue at max_queue -> 429)"),
    ("shed_total", "shed", "counter",
     "Requests shed because deadline_us elapsed before launch"),
    ("dispatches_total", "dispatches", "counter",
     "Coalesced batches executed"),
    ("coalesced_images_total", "coalesced_images", "counter",
     "Requests served through coalesced dispatches"),
    ("images_total", "images", "counter",
     "Images served through the synchronous Session API"),
    ("compile_count_total", "compile_count", "counter",
     "Executor program builds observed (warmup + dispatch); a nonzero "
     "delta after warmup means a request paid a compile stall"),
    ("retries_total", "retries", "counter",
     "Batch launch attempts beyond each batch's first (supervisor retries)"),
    ("backend_failures_total", "backend_failures", "counter",
     "Failed launch attempts (exceptions + watchdog timeouts)"),
    ("watchdog_timeouts_total", "watchdog_timeouts", "counter",
     "Launches abandoned by the per-launch watchdog"),
    ("arena_resets_total", "arena_resets", "counter",
     "Poisoned-arena restores (weight checksum mismatch after a failure)"),
    ("degraded_responses_total", "degraded", "counter",
     "Requests served by the fallback backend while the circuit was open"),
    ("faults_injected_total", "faults_injected", "counter",
     "Injected faults observed (FaultyExecutor chaos harness)"),
    ("circuit_opens_total", "circuit_opens", "counter",
     "Circuit-breaker transitions to open"),
    ("circuit_rejected_total", "circuit_rejected", "counter",
     "Submits shed with 503 while the circuit was open"),
]
_GAUGES = [
    ("queue_depth_peak", "queue_depth_peak", "gauge",
     "Peak queued requests observed for this net"),
    ("coalesce_max", "coalesce_max", "gauge",
     "Largest coalesced batch so far"),
    ("warmup_ms", "warmup_ms", "gauge",
     "Wall time spent precompiling this net's bucket ladder at startup"),
    ("latency_samples", "latency_samples", "gauge",
     "Latency samples in the percentile window"),
    ("circuit_state", "circuit_state", "gauge",
     "Circuit-breaker state: 0 closed, 1 half-open, 2 open"),
]
_QUANTILES = [("0.5", "latency_p50_us"), ("0.9", "latency_p90_us"),
              ("0.99", "latency_p99_us")]

PREFIX = "repro_serve"


def _escape(label: str) -> str:
    """Label-value escaping per the exposition format: backslash, double
    quote and newline."""
    return label.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _escape_help(text: str) -> str:
    """HELP-text escaping per the exposition format: backslash and newline
    only (quotes are legal in HELP)."""
    return text.replace("\\", r"\\").replace("\n", r"\n")


def _fmt_le(le: float) -> str:
    return "+Inf" if le == float("inf") else f"{le:g}"


def render(session) -> str:
    """Render every resident net's snapshot as Prometheus text."""
    snaps = {name: session.stats(name).snapshot()
             for name in session.networks}
    depths = {name: session.queue_depth(name) for name in session.networks}
    lines: List[str] = []

    def emit(suffix, mtype, help_text, values):
        name = f"{PREFIX}_{suffix}"
        lines.append(f"# HELP {name} {_escape_help(help_text)}")
        lines.append(f"# TYPE {name} {mtype}")
        lines.extend(values)

    for suffix, key, mtype, help_text in _COUNTERS + _GAUGES:
        emit(suffix, mtype, help_text,
             [f'{PREFIX}_{suffix}{{net="{_escape(n)}"}} {snap[key]}'
              for n, snap in snaps.items()])
    emit("queue_depth", "gauge", "Requests currently queued (not in-flight)",
         [f'{PREFIX}_queue_depth{{net="{_escape(n)}"}} {d}'
          for n, d in depths.items()])
    emit("bucket_launches_total", "counter",
         "Dispatched batches per padded bucket size",
         [f'{PREFIX}_bucket_launches_total'
          f'{{net="{_escape(n)}",bucket="{b}"}} {c}'
          for n, snap in snaps.items()
          for b, c in sorted(snap.get("bucket_launches", {}).items())])
    # summary: quantiles over the recent window, _sum/_count over all time
    vals = []
    for n, snap in snaps.items():
        vals.extend(
            f'{PREFIX}_latency_us{{net="{_escape(n)}",quantile="{q}"}} '
            f'{snap[key]:.1f}' for q, key in _QUANTILES)
        vals.append(f'{PREFIX}_latency_us_sum{{net="{_escape(n)}"}} '
                    f'{snap.get("latency_total_us", 0.0):.1f}')
        vals.append(f'{PREFIX}_latency_us_count{{net="{_escape(n)}"}} '
                    f'{snap.get("latency_count", 0)}')
    emit("latency_us", "summary",
         "Submit-to-result latency: percentiles over the recent window, "
         "sum/count over the session lifetime", vals)
    # per-phase latency histograms from the tracer (sampled requests only)
    tracer = getattr(session, "tracer", None)
    hists = tracer.phase_histograms() if tracer is not None else {}
    vals = []
    for (net, phase) in sorted(hists):
        h = hists[(net, phase)]
        lbl = f'net="{_escape(net)}",phase="{_escape(phase)}"'
        vals.extend(
            f'{PREFIX}_phase_us_bucket{{{lbl},le="{_fmt_le(le)}"}} {cum}'
            for le, cum in h["buckets"])
        vals.append(f'{PREFIX}_phase_us_sum{{{lbl}}} {h["sum"]:.1f}')
        vals.append(f'{PREFIX}_phase_us_count{{{lbl}}} {h["count"]}')
    emit("phase_us", "histogram",
         "Per-phase request latency from sampled traces (queue, hold, pad, "
         "device_execute, backoff, respond, request, total)", vals)
    # windowed telemetry: since-boot latency histogram (proper cumulative
    # Prometheus histogram, every request — not the tracer's sampled subset)
    # plus sliding-window scalars per configured window
    telemetry = getattr(session, "telemetry", None)
    if telemetry is not None and telemetry.names():
        vals = []
        for n in telemetry.names():
            buckets, sum_us, count, _ = telemetry.series(n).totals()
            lbl = f'net="{_escape(n)}"'
            vals.extend(
                f'{PREFIX}_request_latency_us_bucket{{{lbl},'
                f'le="{_fmt_le(le)}"}} {cum}' for le, cum in buckets)
            vals.append(f'{PREFIX}_request_latency_us_sum{{{lbl}}} '
                        f'{sum_us:.1f}')
            vals.append(f'{PREFIX}_request_latency_us_count{{{lbl}}} {count}')
        emit("request_latency_us", "histogram",
             "Submit-to-result latency of every completed request "
             "(streaming fixed-boundary histogram; since boot)", vals)
        windowed = [
            ("window_latency_us",
             "Windowed latency quantiles over the sliding window "
             "(label q, not quantile — that label is reserved for summaries)",
             [(f'q="{q}"', lambda w, q=qv: w.quantile(q), "%.1f")
              for q, qv in (("0.5", 0.5), ("0.9", 0.9), ("0.99", 0.99))]),
            ("window_error_rate",
             "Fraction of requests ending error/shed over the window",
             [("", lambda w: w.error_rate, "%.6f")]),
            ("window_goodput_rps",
             "Requests completed ok (within deadline when set) per second "
             "over the window",
             [("", lambda w: w.goodput_rps, "%.3f")]),
            ("window_rps",
             "Request arrival rate over the window",
             [("", lambda w: w.rps, "%.3f")]),
        ]
        wstats = {(n, w): telemetry.window(n, w)
                  for n in telemetry.names()
                  for w in telemetry.config.windows}
        for suffix, help_text, series in windowed:
            vals = []
            for (n, w), stats in wstats.items():
                for extra, fn, fmt in series:
                    lbl = f'net="{_escape(n)}",window="{w:g}s"'
                    if extra:
                        lbl += f',{extra}'
                    vals.append(f'{PREFIX}_{suffix}{{{lbl}}} '
                                + (fmt % fn(stats)))
            emit(suffix, "gauge", help_text, vals)
    # SLO engine: per-net state gauge + per-objective burn rates
    slo = getattr(session, "slo", None)
    if slo is not None:
        slo.evaluate()                      # scrape-fresh states
        snap = slo.snapshot()
        emit("slo_state", "gauge",
             "SLO burn-rate state: 0 ok, 1 warning, 2 breach",
             [f'{PREFIX}_slo_state{{net="{_escape(n)}"}} '
              f'{_STATE_CODES[d["state"]]}'
              for n, d in sorted(snap["nets"].items())])
        vals = []
        for n, d in sorted(snap["nets"].items()):
            for obj in d["objectives"]:
                for w, burn in obj["burn"].items():
                    vals.append(
                        f'{PREFIX}_slo_burn_rate{{net="{_escape(n)}",'
                        f'objective="{_escape(obj["objective"])}",'
                        f'window="{w}"}} {burn:.4f}')
        emit("slo_burn_rate", "gauge",
             "Error-budget burn rate per objective and window "
             "(1.0 = consuming exactly the budget)", vals)
    return "\n".join(lines) + "\n"
