"""Prometheus text-format rendering of per-net serving stats.

``render(session)`` walks every resident network, takes one coherent
``NetStats.snapshot()`` each (the snapshot is the concurrency boundary —
this module only formats), and emits the Prometheus exposition format
(text/plain; version 0.0.4) that ``GET /metrics`` returns.  Stdlib only.
"""

from __future__ import annotations

from typing import List

# (metric suffix, snapshot key, TYPE, HELP)
_COUNTERS = [
    ("requests_total", "submits", "counter",
     "Requests admitted to the queue (run/run_batch included)"),
    ("rejected_total", "rejected", "counter",
     "Requests rejected by admission control (queue at max_queue -> 429)"),
    ("shed_total", "shed", "counter",
     "Requests shed because deadline_us elapsed before launch"),
    ("dispatches_total", "dispatches", "counter",
     "Coalesced batches executed"),
    ("coalesced_images_total", "coalesced_images", "counter",
     "Requests served through coalesced dispatches"),
    ("images_total", "images", "counter",
     "Images served through the synchronous Session API"),
    ("compile_count_total", "compile_count", "counter",
     "Executor program builds observed (warmup + dispatch); a nonzero "
     "delta after warmup means a request paid a compile stall"),
    ("retries_total", "retries", "counter",
     "Batch launch attempts beyond each batch's first (supervisor retries)"),
    ("backend_failures_total", "backend_failures", "counter",
     "Failed launch attempts (exceptions + watchdog timeouts)"),
    ("watchdog_timeouts_total", "watchdog_timeouts", "counter",
     "Launches abandoned by the per-launch watchdog"),
    ("arena_resets_total", "arena_resets", "counter",
     "Poisoned-arena restores (weight checksum mismatch after a failure)"),
    ("degraded_responses_total", "degraded", "counter",
     "Requests served by the fallback backend while the circuit was open"),
    ("faults_injected_total", "faults_injected", "counter",
     "Injected faults observed (FaultyExecutor chaos harness)"),
    ("circuit_opens_total", "circuit_opens", "counter",
     "Circuit-breaker transitions to open"),
    ("circuit_rejected_total", "circuit_rejected", "counter",
     "Submits shed with 503 while the circuit was open"),
]
_GAUGES = [
    ("queue_depth_peak", "queue_depth_peak", "gauge",
     "Peak queued requests observed for this net"),
    ("coalesce_max", "coalesce_max", "gauge",
     "Largest coalesced batch so far"),
    ("warmup_ms", "warmup_ms", "gauge",
     "Wall time spent precompiling this net's bucket ladder at startup"),
    ("latency_samples", "latency_samples", "gauge",
     "Latency samples in the percentile window"),
    ("circuit_state", "circuit_state", "gauge",
     "Circuit-breaker state: 0 closed, 1 half-open, 2 open"),
]
_QUANTILES = [("0.5", "latency_p50_us"), ("0.9", "latency_p90_us"),
              ("0.99", "latency_p99_us")]

PREFIX = "repro_serve"


def _escape(label: str) -> str:
    return label.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def render(session) -> str:
    """Render every resident net's snapshot as Prometheus text."""
    snaps = {name: session.stats(name).snapshot()
             for name in session.networks}
    depths = {name: session.queue_depth(name) for name in session.networks}
    lines: List[str] = []

    def emit(suffix, mtype, help_text, values):
        name = f"{PREFIX}_{suffix}"
        lines.append(f"# HELP {name} {help_text}")
        lines.append(f"# TYPE {name} {mtype}")
        lines.extend(values)

    for suffix, key, mtype, help_text in _COUNTERS + _GAUGES:
        emit(suffix, mtype, help_text,
             [f'{PREFIX}_{suffix}{{net="{_escape(n)}"}} {snap[key]}'
              for n, snap in snaps.items()])
    emit("queue_depth", "gauge", "Requests currently queued (not in-flight)",
         [f'{PREFIX}_queue_depth{{net="{_escape(n)}"}} {d}'
          for n, d in depths.items()])
    emit("bucket_launches_total", "counter",
         "Dispatched batches per padded bucket size",
         [f'{PREFIX}_bucket_launches_total'
          f'{{net="{_escape(n)}",bucket="{b}"}} {c}'
          for n, snap in snaps.items()
          for b, c in sorted(snap.get("bucket_launches", {}).items())])
    emit("latency_us", "summary",
         "Submit-to-result latency percentiles over the recent window",
         [f'{PREFIX}_latency_us{{net="{_escape(n)}",quantile="{q}"}} '
          f'{snap[key]:.1f}'
          for n, snap in snaps.items() for q, key in _QUANTILES])
    return "\n".join(lines) + "\n"
