"""Stdlib-only HTTP front-end over :class:`repro.serve.client.ServeClient`.

    POST /v1/infer/<net>[?priority=N&deadline_us=F]  — one inference
         body: JSON {"input": [...], "priority", "deadline_us"} or raw .npy
         (``Content-Type: application/x-npy``); response JSON, or .npy of
         ``output_int8`` under ``Accept: application/x-npy``
    GET  /v1/nets     — resident networks + shapes + queue depths
    GET  /v1/trace[?limit=N] — recent completed traces as Chrome trace-event
                        JSON (chrome://tracing / ui.perfetto.dev)
    GET  /v1/slo      — declared SLO policies + per-net burn-rate states
                        (``{"enabled": false, ...}`` when no --slo attached)
    GET  /healthz     — per-net health (warming / healthy / degraded /
                        circuit_open); non-200 when any net is unhealthy
                        or any SLO is in breach
    GET  /metrics     — Prometheus text format (``NetStats.snapshot()`` +
                        the tracer's per-phase latency histograms + the
                        windowed telemetry and ``slo_state`` gauges)

Every inference response carries ``X-Repro-Trace-Id``: the id the request
arrived with (same header; forces that request into the tracer's sampled
set) or a server-assigned one.  Error replies (429/503/504/500) carry the
header too, plus ``error.trace_id`` in the JSON body, so rejected and shed
requests stay correlatable with their server-side trace.

Status codes: 400 malformed payload, 404 unknown net/route, 429 queue at
``max_queue`` (admission control), 503 circuit open / warming (with
``Retry-After``), 504 deadline shed or client timeout, 500 backend fault
(retries exhausted).  A response served by a net's fallback backend while
its circuit is open carries ``"degraded": true`` in the JSON body and an
``X-Repro-Degraded: 1`` header.

``ThreadingHTTPServer`` gives one handler thread per in-flight request;
concurrent posts against the same net coalesce in that net's dispatcher,
and different nets proceed on independent dispatcher threads — the HTTP
layer adds transport, never scheduling policy.
"""

from __future__ import annotations

import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlparse

from repro.obs.trace import TRACE_HEADER, new_trace_id, valid_trace_id
from repro.serve import payload
from repro.serve.client import BadRequestError, NotFoundError, ServeClient, \
    ServeError

_MAX_BODY = 64 << 20            # 64 MiB — far past any supported input


class ServeHandler(BaseHTTPRequestHandler):
    """One request; ``self.server.client`` is the shared ServeClient."""

    server_version = "repro-serve/1.0"
    protocol_version = "HTTP/1.1"

    # -- plumbing ------------------------------------------------------------
    def log_message(self, fmt, *args):      # pragma: no cover - log noise
        if getattr(self.server, "verbose", False):
            BaseHTTPRequestHandler.log_message(self, fmt, *args)

    def _reply(self, status: int, body: bytes, content_type: str,
               extra_headers: Optional[dict] = None) -> None:
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (extra_headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _reply_json(self, status: int, doc) -> None:
        self._reply(status, json.dumps(doc).encode("utf-8"),
                    payload.JSON_TYPE)

    def _reply_error(self, exc: ServeError,
                     trace_id: Optional[str] = None) -> None:
        # an error reply may be sent before the request body was read
        # (e.g. 404 on the route) — close the connection rather than let a
        # keep-alive client's unread body desync the next request
        self.close_connection = True
        retry_after = getattr(exc, "retry_after_s", None)
        tid = trace_id or getattr(exc, "trace_id", None)
        body, ctype = payload.encode_error(exc.status, exc.code, str(exc),
                                           retry_after_s=retry_after,
                                           trace_id=tid)
        extra = {}
        if exc.status in (429, 503):
            # whole seconds per RFC 9110; a sub-second probe window still
            # tells the client to back off for at least one
            extra["Retry-After"] = str(max(1, math.ceil(retry_after or 1.0)))
        if tid is not None:
            extra[TRACE_HEADER] = tid
        self._reply(exc.status, body, ctype, extra or None)

    # -- routes --------------------------------------------------------------
    def do_GET(self) -> None:               # noqa: N802 (stdlib casing)
        client: ServeClient = self.server.client
        url = urlparse(self.path)
        path = url.path
        try:
            if path == "/healthz":
                doc = client.healthz()
                # non-200 when any resident net is unhealthy, so load
                # balancers/orchestrators act on degraded state
                self._reply_json(200 if doc["status"] == "ok" else 503, doc)
            elif path == "/metrics":
                self._reply(200, client.metrics_text().encode("utf-8"),
                            "text/plain; version=0.0.4")
            elif path == "/v1/nets":
                self._reply_json(200, {"nets": client.nets()})
            elif path == "/v1/trace":
                qs = parse_qs(url.query)
                try:
                    limit = int(qs["limit"][0]) if "limit" in qs else None
                except (TypeError, ValueError):
                    raise BadRequestError("limit must be an int") from None
                self._reply_json(200, client.trace_doc(limit))
            elif path == "/v1/slo":
                self._reply_json(200, client.slo_doc())
            else:
                self._reply_error(NotFoundError(f"no route {path!r}"))
        except ServeError as e:
            self._reply_error(e)
        except Exception as e:              # noqa: BLE001 — last-resort 500
            self._reply_error(ServeError(f"{type(e).__name__}: {e}"))

    def do_POST(self) -> None:              # noqa: N802 (stdlib casing)
        client: ServeClient = self.server.client
        url = urlparse(self.path)
        trace_id = None
        try:
            if not url.path.startswith("/v1/infer/"):
                raise NotFoundError(f"no route {url.path!r}")
            net = url.path[len("/v1/infer/"):]
            if not net or "/" in net:
                raise NotFoundError(f"no route {url.path!r}")
            # a client-supplied trace id forces the request into the
            # tracer's sampled set; absent, the scheduler assigns one (and
            # the sampler decides whether to record)
            trace_id = self.headers.get(TRACE_HEADER)
            if trace_id is not None and not valid_trace_id(trace_id):
                raise BadRequestError(
                    f"{TRACE_HEADER} must be 1-64 chars of [A-Za-z0-9._-]")
            try:
                length = int(self.headers.get("Content-Length", 0))
            except ValueError:
                raise BadRequestError("bad Content-Length") from None
            if not 0 < length <= _MAX_BODY:
                raise BadRequestError(
                    f"Content-Length must be in (0, {_MAX_BODY}]")
            body = self.rfile.read(length)
            try:
                x, meta = payload.decode_request(
                    body, self.headers.get("Content-Type", ""))
            except ValueError as e:
                raise BadRequestError(str(e)) from None
            qs = parse_qs(url.query)
            try:
                priority = int(qs.get("priority", [meta.get("priority", 0)])[0])
                dl = qs.get("deadline_us", [meta.get("deadline_us")])[0]
                deadline_us = float(dl) if dl is not None else None
            except (TypeError, ValueError):
                raise BadRequestError(
                    "priority must be int, deadline_us float") from None
            t0 = time.perf_counter()
            fut = client.infer_async(net, x, priority=priority,
                                     deadline_us=deadline_us,
                                     trace_id=trace_id)
            trace_id = getattr(fut, "trace_id", trace_id)
            res = client.resolve_future(fut,
                                        timeout=client.timeout_for(deadline_us))
            out, ctype = payload.encode_result(
                net, res, (time.perf_counter() - t0) * 1e6,
                accept=self.headers.get("Accept", ""))
            extra = {}
            if getattr(res, "degraded", False):
                extra["X-Repro-Degraded"] = "1"
            if trace_id is not None:
                extra[TRACE_HEADER] = trace_id
            self._reply(200, out, ctype, extra or None)
        except ServeError as e:
            # rejections that never reached the scheduler (404/400/warming)
            # still get a fresh id for the error body/header
            self._reply_error(e, trace_id=getattr(e, "trace_id", None)
                              or trace_id or new_trace_id())
        except Exception as e:              # noqa: BLE001 — last-resort 500
            self._reply_error(ServeError(f"{type(e).__name__}: {e}"),
                              trace_id=trace_id or new_trace_id())


def make_server(session, host: str = "127.0.0.1",
                port: int = 0, verbose: bool = False) -> ThreadingHTTPServer:
    """Build (not start) the HTTP server; ``port=0`` picks an ephemeral
    port — read it back from ``server.server_address``.  The server owns no
    session lifecycle: close the session yourself after ``shutdown()``."""
    srv = ThreadingHTTPServer((host, port), ServeHandler)
    srv.daemon_threads = True
    srv.client = ServeClient(session)
    srv.verbose = verbose
    return srv


def serve_forever(session, host: str = "127.0.0.1", port: int = 8000,
                  verbose: bool = True,
                  ready: Optional[threading.Event] = None,
                  warmup: bool = False,
                  trace_dir: Optional[str] = None) -> None:
    """Blocking serve loop (the ``python -m repro.serve`` entry point).

    With ``warmup=True`` the socket opens immediately but inference returns
    503 (``/healthz`` reports ``"warming"``) until every resident net's
    bucket ladder is precompiled — no first request ever compile-stalls.
    ``trace_dir`` dumps the tracer's ring buffer as Chrome trace-event JSON
    (``<trace_dir>/trace.json``) on shutdown.
    """
    srv = make_server(session, host, port, verbose=verbose)
    bound = srv.server_address
    print(f"[repro.serve] listening on http://{bound[0]}:{bound[1]} "
          f"nets={','.join(session.networks)}")
    if warmup:
        srv.client.begin_warmup()
    if ready is not None:
        ready.set()
    try:
        if warmup:
            thread = threading.Thread(target=srv.serve_forever,
                                      name="repro-serve-http", daemon=True)
            thread.start()
            for name, ms in session.warmup().items():
                print(f"[repro.serve] warmed {name}: {ms:.0f}ms, "
                      f"buckets={list(session.scheduler.config.buckets)}")
            srv.client.finish_warmup()
            thread.join()
        else:
            srv.serve_forever()
    except KeyboardInterrupt:               # pragma: no cover - interactive
        print("[repro.serve] draining...")
    finally:
        srv.shutdown()
        srv.server_close()
        session.close(drain=True)
        if trace_dir is not None:
            import pathlib
            out = pathlib.Path(trace_dir) / "trace.json"
            session.tracer.to_file(out)
            print(f"[repro.serve] trace -> {out}")
