"""``repro.serve`` — multi-tenant serving front-end over ``repro.runtime``.

The traffic-facing layer between clients and the runtime: a stdlib-only
HTTP server plus an in-process client that drive the *same* code path —
benchmarks and tests exercise real serving semantics without sockets, and
the socket path adds only transport.

    repro.serve.http  — ThreadingHTTPServer: POST /v1/infer/<net>,
                        GET /v1/nets, GET /healthz, GET /metrics,
                        GET /v1/trace, GET /v1/slo
    repro.serve.client — ServeClient: validation, priority/deadline
                        plumbing, typed errors with HTTP status codes;
                        HttpServeClient: the same surface over HTTP with
                        keep-alive connection reuse
    repro.serve.payload — npy / JSON tensor codecs
    repro.serve.metrics — Prometheus text rendering from NetStats.snapshot()

    PYTHONPATH=src python -m repro.serve --artifacts bundle_dir --port 8000

Every resident network is served by its own dispatcher thread
(``repro.runtime.scheduler``), so one tenant's slow model never
head-of-line blocks another's; requests carry ``priority`` and
``deadline_us`` and the queue bound rejects overload with 429.
"""

from repro.serve.client import (BackendError, BadRequestError,
                                ClientTimeoutError, DeadlineError,
                                HttpServeClient, NotFoundError,
                                OverloadedError, ServeClient, ServeError,
                                UnavailableError, WarmingUpError)
from repro.serve.config import ServeConfig
from repro.serve.http import make_server, serve_forever

__all__ = ["ServeClient", "HttpServeClient", "ServeError", "BadRequestError",
           "NotFoundError", "OverloadedError", "DeadlineError",
           "BackendError", "ClientTimeoutError", "UnavailableError",
           "WarmingUpError", "ServeConfig", "make_server", "serve_forever"]
