"""Serving-policy configuration shared by the CLI and embedders.

``SchedulerConfig`` owns the *runtime* knobs (batching, queues, retries,
watchdog, breaker); ``ServeConfig`` owns the *front-end* policy layered on
top — what to do when a net's circuit opens, and whether the socket admits
traffic before warmup finishes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Front-end serving policy.

    ``fallback_backend`` — registered backend name (e.g. ``"ref"``) every
                       loaded net falls back to when its circuit breaker
                       opens; responses served this way carry
                       ``degraded: true``.  ``None`` (default): no fallback,
                       an open circuit sheds with 503 + ``Retry-After``.
    ``warmup``         — hold traffic (503 ``warming``) until every net's
                       bucket ladder is precompiled.
    """
    fallback_backend: Optional[str] = None
    warmup: bool = True
