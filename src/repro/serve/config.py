"""Serving-policy configuration shared by the CLI and embedders.

``SchedulerConfig`` owns the *runtime* knobs (batching, queues, retries,
watchdog, breaker); ``ServeConfig`` owns the *front-end* policy layered on
top — what to do when a net's circuit opens, and whether the socket admits
traffic before warmup finishes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Front-end serving policy.

    ``fallback_backend`` — registered backend name (e.g. ``"ref"``) every
                       loaded net falls back to when its circuit breaker
                       opens; responses served this way carry
                       ``degraded: true``.  ``None`` (default): no fallback,
                       an open circuit sheds with 503 + ``Retry-After``.
    ``warmup``         — hold traffic (503 ``warming``) until every net's
                       bucket ladder is precompiled.

    Observability knobs (``repro.obs``):

    ``trace``          — record request lifecycle traces (the trace-id
                       header contract holds either way).
    ``trace_sample``   — trace every Nth request per net (1 = all, 0 = only
                       requests arriving with an ``X-Repro-Trace-Id``).
    ``trace_profile``  — run sampled requests through the executors'
                       per-layer profiled path (slower; calibration runs).
    ``trace_dir``      — dump the trace ring buffer as Chrome trace-event
                       JSON (``<dir>/trace.json``) on shutdown.
    ``slo_path``       — JSON file of ``SloPolicy`` declarations
                       (``repro.obs.slo.load_policies``); when set, the
                       burn-rate engine evaluates them continuously and
                       surfaces state on ``/metrics`` / ``/healthz`` /
                       ``/v1/slo``.
    ``slo_period_s``   — background evaluation cadence for the engine.
    """
    fallback_backend: Optional[str] = None
    warmup: bool = True
    trace: bool = True
    trace_sample: int = 1
    trace_profile: bool = False
    trace_dir: Optional[str] = None
    slo_path: Optional[str] = None
    slo_period_s: float = 5.0

    def trace_config(self):
        """The ``repro.obs.TraceConfig`` these knobs describe."""
        from repro.obs.trace import TraceConfig
        return TraceConfig(enabled=self.trace,
                           sample_rate=self.trace_sample,
                           profile=self.trace_profile)
