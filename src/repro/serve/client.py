"""In-process serving client: the front door both HTTP and benchmarks use.

``ServeClient`` wraps a ``repro.runtime.Session`` with the request-level
semantics of the serving API — network resolution, input validation,
priority/deadline plumbing, admission control — and converts runtime
exceptions into typed :class:`ServeError` subclasses that carry an HTTP
status code.  The HTTP handler (``repro.serve.http``) is a thin transport
over this class, so the load generator and the socket tests exercise the
exact same code path.

:class:`HttpServeClient` is the remote counterpart: the same surface over
HTTP/1.1 with per-thread keep-alive connection reuse (and graceful
reconnect when the server closes a connection), so network load tests
measure the server rather than TCP connect overhead.
"""

from __future__ import annotations

import http.client
import json
import math
import threading
import time
from concurrent.futures import CancelledError, Future
from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import Dict, List, Optional
from urllib.parse import quote

import numpy as np

from repro.core.executor import ExecResult
from repro.obs.trace import TRACE_HEADER
from repro.runtime.scheduler import (BackendFaultError, CircuitOpenError,
                                     DeadlineExceededError, QueueFullError)

# client-side timeout derived from deadline_us: the deadline bounds *launch*,
# not completion, so allow the full budget plus a generous execution grace —
# the point is that a wedged server can never hold the caller forever
_TIMEOUT_GRACE_S = 30.0


class ServeError(Exception):
    """Base serving error; ``status``/``code`` map straight onto HTTP.
    ``retry_after_s`` (when set) rides 429/503 replies as ``Retry-After``;
    ``trace_id`` (when known) rides the error body and the
    ``X-Repro-Trace-Id`` response header, so rejected/shed requests stay
    correlatable with their server-side trace."""
    status = 500
    code = "internal"
    retry_after_s: Optional[float] = None
    trace_id: Optional[str] = None


class BadRequestError(ServeError):
    status = 400
    code = "bad_request"


class NotFoundError(ServeError):
    status = 404
    code = "not_found"


class OverloadedError(ServeError):
    """Admission control rejected the request (queue at ``max_queue``)."""
    status = 429
    code = "overloaded"
    retry_after_s = 1.0


class WarmingUpError(ServeError):
    """The server is still precompiling its bucket ladder; retry shortly.
    ``/healthz`` reports ``"warming"`` for the duration."""
    status = 503
    code = "warming"
    retry_after_s = 1.0


class UnavailableError(ServeError):
    """The net's circuit breaker is open and no fallback backend is
    configured; ``Retry-After`` carries the time to the half-open probe."""
    status = 503
    code = "circuit_open"

    def __init__(self, message: str = "", retry_after_s: Optional[float] = None):
        super().__init__(message)
        if retry_after_s is not None:
            self.retry_after_s = retry_after_s


class BackendError(ServeError):
    """The backend exhausted its retry budget for this request's batch."""
    status = 500
    code = "backend_fault"


class DeadlineError(ServeError):
    """The request's ``deadline_us`` elapsed before launch; it was shed."""
    status = 504
    code = "deadline_exceeded"


class ClientTimeoutError(ServeError):
    """The client-side ``timeout_s`` elapsed waiting for the result; the
    request may still complete server-side, but the caller has moved on."""
    status = 504
    code = "client_timeout"


class ServeClient:
    """Session front door with serving semantics and typed errors.

    Rejected requests (unknown net, malformed input, saturated queue) fail
    *fast and synchronously*; admitted requests always resolve — with a
    result, a backend error, or :class:`DeadlineError` when shed.
    """

    def __init__(self, session, timeout_s: Optional[float] = None):
        self.session = session
        self.timeout_s = timeout_s       # default client-side result timeout
        self._warming = False

    # -- warmup gate ---------------------------------------------------------
    def begin_warmup(self) -> None:
        """Refuse inference (503 ``warming``) until ``finish_warmup``;
        ``/healthz``, ``/metrics`` and ``/v1/nets`` keep answering."""
        self._warming = True

    def finish_warmup(self) -> None:
        self._warming = False

    # -- inference -----------------------------------------------------------
    def infer_async(self, net: Optional[str], x, priority: int = 0,
                    deadline_us: Optional[float] = None,
                    trace_id: Optional[str] = None) -> Future:
        """Admit one request; returns the runtime Future (which carries
        ``fut.trace_id``).  ``trace_id`` — a client-supplied
        ``X-Repro-Trace-Id`` — forces the request into the sampled set.

        Raises ``NotFoundError`` / ``BadRequestError`` / ``OverloadedError``
        / ``WarmingUpError`` synchronously — an exception here means the
        request never entered the queue."""
        if self._warming:
            raise WarmingUpError(
                "server is warming up (precompiling bucket shapes); "
                "retry shortly")
        try:
            return self.session.submit(x, net=net, priority=priority,
                                       deadline_us=deadline_us,
                                       trace_id=trace_id)
        except KeyError as e:
            raise NotFoundError(str(e.args[0]) if e.args else str(e)) from None
        except QueueFullError as e:
            err = OverloadedError(str(e))
            err.trace_id = getattr(e, "trace_id", None)
            raise err from None
        except CircuitOpenError as e:
            err = UnavailableError(str(e), retry_after_s=e.retry_after_s)
            err.trace_id = getattr(e, "trace_id", None)
            raise err from None
        except (ValueError, TypeError) as e:
            raise BadRequestError(str(e)) from None

    @staticmethod
    def resolve_future(fut: Future, timeout: Optional[float] = None):
        """Block on a runtime future, translating shed/fault/cancel/timeout
        exceptions into their typed ``ServeError`` (each carrying the
        future's ``trace_id``)."""
        tid = getattr(fut, "trace_id", None)

        def _fail(err: ServeError):
            err.trace_id = tid
            raise err from None

        try:
            return fut.result(timeout=timeout)
        except DeadlineExceededError as e:
            _fail(DeadlineError(str(e)))
        except BackendFaultError as e:
            _fail(BackendError(str(e)))
        except FuturesTimeoutError:
            _fail(ClientTimeoutError(
                f"no result within the client-side timeout ({timeout}s); "
                f"the server may be wedged"))
        except CancelledError:
            _fail(ServeError("request cancelled: server shutting down"))

    def timeout_for(self, deadline_us: Optional[float]) -> Optional[float]:
        """Default client-side result timeout: the constructor's
        ``timeout_s``, or a finite ``deadline_us`` plus execution grace."""
        if self.timeout_s is not None:
            return self.timeout_s
        if deadline_us is not None and math.isfinite(deadline_us):
            return deadline_us * 1e-6 + _TIMEOUT_GRACE_S
        return None

    def infer(self, net: Optional[str], x, priority: int = 0,
              deadline_us: Optional[float] = None,
              timeout: Optional[float] = None,
              trace_id: Optional[str] = None):
        """Synchronous inference -> ``ExecResult`` (or a ``ServeError``).

        ``timeout`` (seconds) bounds the client-side wait; it defaults to
        the constructor's ``timeout_s``, or — when the request carries a
        finite ``deadline_us`` — to the deadline plus an execution grace,
        so a stuck server can never block the caller indefinitely."""
        if timeout is None:
            timeout = self.timeout_for(deadline_us)
        return self.resolve_future(
            self.infer_async(net, x, priority=priority,
                             deadline_us=deadline_us, trace_id=trace_id),
            timeout=timeout)

    # -- introspection -------------------------------------------------------
    def nets(self) -> List[Dict]:
        """One descriptor per resident network (the ``/v1/nets`` body).

        Includes the engine metadata a client needs to discover precision
        *before* submitting: ``config`` (``nv_small`` / ``nv_full``) and
        ``dtype`` (``int8`` / ``bf16``) alongside the input shape."""
        out = []
        for name in self.session.networks:
            art = self.session.artifacts(name)
            ex = self.session.executor(name)
            dims = getattr(ex, "input_dims", None)
            cfg = getattr(art, "cfg", None)
            out.append({
                "name": name,
                "backend": self.session._resolve(name).backend,
                "config": getattr(cfg, "name", None),
                "dtype": getattr(cfg, "dtype", None),
                "input_shape": list(dims[1:]) if dims is not None else None,
                "output_elems": getattr(art, "output_elems", None),
                "queue_depth": self.session.queue_depth(name),
            })
        return out

    def healthz(self) -> Dict:
        """Liveness + per-net health.  ``status`` is ``ok`` only when every
        resident net is ``healthy``; the HTTP layer returns 503 otherwise.
        Per-net states: ``warming`` / ``healthy`` / ``degraded`` (circuit
        open, fallback serving) / ``circuit_open`` (shedding)."""
        ses = self.session
        if self._warming:
            states = {n: "warming" for n in ses.networks}
            status = "warming"
        else:
            states = {n: h["state"] for n, h in ses.health().items()}
            status = ("ok" if all(s == "healthy" for s in states.values())
                      else "degraded")
        doc = {"status": status, "nets": len(ses.networks),
               "net_states": states, "time": time.time()}
        slo = getattr(ses, "slo", None)
        if slo is not None:
            slo_states = slo.evaluate()
            doc["slo_states"] = slo_states
            if status == "ok" and "breach" in slo_states.values():
                # breaching the declared objectives is unhealthy even while
                # every circuit is closed — surface it as 503 so load
                # balancers stop favouring this replica
                doc["status"] = "slo_breach"
        return doc

    def metrics_text(self) -> str:
        from repro.serve import metrics
        return metrics.render(self.session)

    def trace_doc(self, limit: Optional[int] = None) -> Dict:
        """Chrome trace-event JSON of the most recent completed traces
        (the ``GET /v1/trace`` body) — load into chrome://tracing or
        ui.perfetto.dev."""
        return self.session.tracer.chrome_trace(limit)

    def slo_doc(self) -> Dict:
        """The ``GET /v1/slo`` body: declared policies, burn-rate pairs and
        the per-net evaluation detail (fresh — evaluates on call)."""
        slo = getattr(self.session, "slo", None)
        if slo is None:
            return {"enabled": False, "policies": [], "nets": {}}
        slo.evaluate()
        return {"enabled": True, **slo.snapshot()}

    @classmethod
    def connect(cls, base_url: str, timeout_s: Optional[float] = None,
                workers: int = 32) -> "HttpServeClient":
        """A remote counterpart: same ``infer`` / ``infer_async`` /
        ``healthz`` surface over HTTP with keep-alive connection reuse."""
        return HttpServeClient(base_url, timeout_s=timeout_s, workers=workers)


class HttpServeClient:
    """``ServeClient``-shaped front door over a remote ``repro.serve``
    server, with HTTP/1.1 keep-alive connection reuse.

    One persistent connection per calling thread (``http.client`` sockets
    are not thread-safe), so the table-6 load generator measures the server
    rather than per-request TCP connect overhead.  When the server closes a
    connection (``Connection: close`` on error replies, restarts, idle
    timeouts) the next request on that thread transparently reconnects and
    retries once — inference is stateless, so a possibly-duplicated send is
    benign.  ``connects`` counts sockets opened; a keep-alive workload of N
    requests from one thread keeps it at 1.

    Errors arrive as the same typed :class:`ServeError` subclasses the
    in-process client raises, reconstructed from the error body's ``code``.
    ``infer_async`` runs ``infer`` on an internal thread pool and returns a
    ``Future`` — drive it exactly like the in-process client's
    (``client.resolve_future(fut)`` is an identity adapter here).
    """

    def __init__(self, base_url: str, timeout_s: Optional[float] = None,
                 workers: int = 32):
        from urllib.parse import urlsplit
        parts = urlsplit(base_url if "//" in base_url
                         else "http://" + base_url)
        if parts.scheme not in ("", "http"):
            raise ValueError(f"unsupported scheme {parts.scheme!r} "
                             f"(plain http only)")
        self.host = parts.hostname or "localhost"
        self.port = parts.port or 80
        self.timeout_s = timeout_s if timeout_s is not None else 60.0
        self.connects = 0                 # sockets opened (keep-alive gauge)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._workers = workers
        self._pool = None                 # lazy: only infer_async needs it

    # -- connection management ----------------------------------------------
    def _conn(self):
        c = getattr(self._local, "conn", None)
        if c is None:
            c = http.client.HTTPConnection(self.host, self.port,
                                           timeout=self.timeout_s)
            self._local.conn = c
            with self._lock:
                self.connects += 1
        return c

    def _drop_conn(self) -> None:
        c = getattr(self._local, "conn", None)
        if c is not None:
            try:
                c.close()
            except Exception:
                pass
            self._local.conn = None

    def _request(self, method: str, path: str, body: Optional[bytes] = None,
                 headers: Optional[Dict[str, str]] = None):
        """One request over this thread's persistent connection; on a dead
        socket (server closed the keep-alive side), reconnect and retry
        once.  Returns ``(status, response_headers, body_bytes)``."""
        last_exc = None
        for attempt in (0, 1):
            conn = self._conn()
            try:
                conn.request(method, path, body=body, headers=headers or {})
                resp = conn.getresponse()
                data = resp.read()
            except (http.client.HTTPException, ConnectionError, OSError) as e:
                self._drop_conn()
                last_exc = e
                continue
            if resp.will_close:
                # server asked to close (error replies do): honour it so the
                # next request reconnects instead of hitting a dead socket
                self._drop_conn()
            return resp.status, resp.headers, data
        raise ServeError(f"server unreachable at "
                         f"{self.host}:{self.port}: {last_exc}")

    # -- inference -----------------------------------------------------------
    def infer(self, net: Optional[str], x, priority: int = 0,
              deadline_us: Optional[float] = None,
              timeout: Optional[float] = None,
              trace_id: Optional[str] = None):
        """Synchronous remote inference -> ``ExecResult`` (or a typed
        ``ServeError``).  Matches ``ServeClient.infer``; ``timeout`` is
        accepted for signature parity (the connection timeout governs)."""
        x = np.asarray(x)
        doc = {"input": x.tolist()}
        if x.dtype == np.int8:
            doc["dtype"] = "int8"
        if priority:
            doc["priority"] = int(priority)
        if deadline_us is not None:
            doc["deadline_us"] = float(deadline_us)
        headers = {"Content-Type": "application/json"}
        if trace_id is not None:
            headers[TRACE_HEADER] = trace_id
        path = "/v1/infer" if net is None else f"/v1/infer/{quote(net)}"
        status, rh, data = self._request(
            "POST", path, body=json.dumps(doc).encode("utf-8"),
            headers=headers)
        if status != 200:
            raise self._error(status, rh, data)
        out = json.loads(data.decode("utf-8"))
        i8 = np.asarray(out["output_int8"])
        res = ExecResult(
            # bf16 nets ship the raw byte stream (0..255) here; int8 nets
            # always fit the signed range
            output_int8=i8.astype(np.int8 if i8.size == 0 or
                                  (i8.min() >= -128 and i8.max() <= 127)
                                  else np.uint8),
            output=np.asarray(out["output"], dtype=np.float64),
            degraded=bool(out.get("degraded", False)))
        return res

    def infer_async(self, net: Optional[str], x, priority: int = 0,
                    deadline_us: Optional[float] = None,
                    trace_id: Optional[str] = None) -> Future:
        """``infer`` on an internal thread pool -> ``Future[ExecResult]``.
        Unlike the in-process client, admission errors surface through the
        future rather than synchronously (the request must travel first)."""
        with self._lock:
            if self._pool is None:
                from concurrent.futures import ThreadPoolExecutor
                self._pool = ThreadPoolExecutor(
                    max_workers=self._workers,
                    thread_name_prefix="repro-http-client")
            pool = self._pool
        return pool.submit(self.infer, net, x, priority=priority,
                           deadline_us=deadline_us, trace_id=trace_id)

    @staticmethod
    def resolve_future(fut: Future, timeout: Optional[float] = None):
        """Adapter for ``ServeClient.resolve_future`` call sites: the typed
        errors were already raised inside ``infer`` and propagate from
        ``result()`` as-is."""
        try:
            return fut.result(timeout=timeout)
        except FuturesTimeoutError:
            raise ClientTimeoutError(
                f"no result within the client-side timeout "
                f"({timeout}s)") from None

    @staticmethod
    def _error(status: int, headers, data: bytes) -> ServeError:
        try:
            err = json.loads(data.decode("utf-8"))["error"]
        except Exception:
            err = {"code": "internal", "message": data[:200].decode(
                "utf-8", "replace")}
        cls = _ERROR_BY_CODE.get(err.get("code"), ServeError)
        e = cls(err.get("message", f"HTTP {status}"))
        if err.get("retry_after_s") is not None:
            e.retry_after_s = float(err["retry_after_s"])
        e.trace_id = err.get("trace_id") or headers.get(TRACE_HEADER)
        return e

    # -- introspection --------------------------------------------------------
    def _get_json(self, path: str, ok_statuses=(200,)) -> Dict:
        status, _, data = self._request("GET", path)
        if status not in ok_statuses:
            raise self._error(status, {}, data)
        return json.loads(data.decode("utf-8"))

    def nets(self) -> List[Dict]:
        return self._get_json("/v1/nets")["nets"]

    def healthz(self) -> Dict:
        # health is meaningful at any status (503 while warming/degraded)
        return self._get_json("/healthz", ok_statuses=(200, 503))

    def slo_doc(self) -> Dict:
        return self._get_json("/v1/slo")

    def metrics_text(self) -> str:
        status, _, data = self._request("GET", "/metrics")
        if status != 200:
            raise self._error(status, {}, data)
        return data.decode("utf-8")

    def trace_doc(self, limit: Optional[int] = None) -> Dict:
        return self._get_json("/v1/trace"
                              + (f"?limit={int(limit)}" if limit else ""))

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Release this thread's connection and the async pool (other
        threads' sockets die with their threads)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)
        self._drop_conn()

    def __enter__(self) -> "HttpServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# error-body ``code`` -> typed exception, inverse of the server's encoding
_ERROR_BY_CODE = {
    cls.code: cls for cls in (BadRequestError, NotFoundError,
                              OverloadedError, WarmingUpError,
                              UnavailableError, BackendError, DeadlineError,
                              ClientTimeoutError)
}
