"""In-process serving client: the front door both HTTP and benchmarks use.

``ServeClient`` wraps a ``repro.runtime.Session`` with the request-level
semantics of the serving API — network resolution, input validation,
priority/deadline plumbing, admission control — and converts runtime
exceptions into typed :class:`ServeError` subclasses that carry an HTTP
status code.  The HTTP handler (``repro.serve.http``) is a thin transport
over this class, so the load generator and the socket tests exercise the
exact same code path.
"""

from __future__ import annotations

import time
from concurrent.futures import CancelledError, Future
from typing import Dict, List, Optional

import numpy as np

from repro.runtime.scheduler import DeadlineExceededError, QueueFullError


class ServeError(Exception):
    """Base serving error; ``status``/``code`` map straight onto HTTP."""
    status = 500
    code = "internal"


class BadRequestError(ServeError):
    status = 400
    code = "bad_request"


class NotFoundError(ServeError):
    status = 404
    code = "not_found"


class OverloadedError(ServeError):
    """Admission control rejected the request (queue at ``max_queue``)."""
    status = 429
    code = "overloaded"


class WarmingUpError(ServeError):
    """The server is still precompiling its bucket ladder; retry shortly.
    ``/healthz`` reports ``"warming"`` for the duration."""
    status = 503
    code = "warming"


class DeadlineError(ServeError):
    """The request's ``deadline_us`` elapsed before launch; it was shed."""
    status = 504
    code = "deadline_exceeded"


class ServeClient:
    """Session front door with serving semantics and typed errors.

    Rejected requests (unknown net, malformed input, saturated queue) fail
    *fast and synchronously*; admitted requests always resolve — with a
    result, a backend error, or :class:`DeadlineError` when shed.
    """

    def __init__(self, session):
        self.session = session
        self._warming = False

    # -- warmup gate ---------------------------------------------------------
    def begin_warmup(self) -> None:
        """Refuse inference (503 ``warming``) until ``finish_warmup``;
        ``/healthz``, ``/metrics`` and ``/v1/nets`` keep answering."""
        self._warming = True

    def finish_warmup(self) -> None:
        self._warming = False

    # -- inference -----------------------------------------------------------
    def infer_async(self, net: Optional[str], x, priority: int = 0,
                    deadline_us: Optional[float] = None) -> Future:
        """Admit one request; returns the runtime Future.

        Raises ``NotFoundError`` / ``BadRequestError`` / ``OverloadedError``
        / ``WarmingUpError`` synchronously — an exception here means the
        request never entered the queue."""
        if self._warming:
            raise WarmingUpError(
                "server is warming up (precompiling bucket shapes); "
                "retry shortly")
        try:
            return self.session.submit(x, net=net, priority=priority,
                                       deadline_us=deadline_us)
        except KeyError as e:
            raise NotFoundError(str(e.args[0]) if e.args else str(e)) from None
        except QueueFullError as e:
            raise OverloadedError(str(e)) from None
        except (ValueError, TypeError) as e:
            raise BadRequestError(str(e)) from None

    @staticmethod
    def resolve_future(fut: Future, timeout: Optional[float] = None):
        """Block on a runtime future, translating shed/cancel exceptions."""
        try:
            return fut.result(timeout=timeout)
        except DeadlineExceededError as e:
            raise DeadlineError(str(e)) from None
        except CancelledError:
            raise ServeError("request cancelled: server shutting down") from None

    def infer(self, net: Optional[str], x, priority: int = 0,
              deadline_us: Optional[float] = None,
              timeout: Optional[float] = None):
        """Synchronous inference -> ``ExecResult`` (or a ``ServeError``)."""
        return self.resolve_future(
            self.infer_async(net, x, priority=priority,
                             deadline_us=deadline_us), timeout=timeout)

    # -- introspection -------------------------------------------------------
    def nets(self) -> List[Dict]:
        """One descriptor per resident network (the ``/v1/nets`` body).

        Includes the engine metadata a client needs to discover precision
        *before* submitting: ``config`` (``nv_small`` / ``nv_full``) and
        ``dtype`` (``int8`` / ``bf16``) alongside the input shape."""
        out = []
        for name in self.session.networks:
            art = self.session.artifacts(name)
            ex = self.session.executor(name)
            dims = getattr(ex, "input_dims", None)
            cfg = getattr(art, "cfg", None)
            out.append({
                "name": name,
                "backend": self.session._resolve(name).backend,
                "config": getattr(cfg, "name", None),
                "dtype": getattr(cfg, "dtype", None),
                "input_shape": list(dims[1:]) if dims is not None else None,
                "output_elems": getattr(art, "output_elems", None),
                "queue_depth": self.session.queue_depth(name),
            })
        return out

    def healthz(self) -> Dict:
        return {"status": "warming" if self._warming else "ok",
                "nets": len(self.session.networks), "time": time.time()}

    def metrics_text(self) -> str:
        from repro.serve import metrics
        return metrics.render(self.session)
