"""In-process serving client: the front door both HTTP and benchmarks use.

``ServeClient`` wraps a ``repro.runtime.Session`` with the request-level
semantics of the serving API — network resolution, input validation,
priority/deadline plumbing, admission control — and converts runtime
exceptions into typed :class:`ServeError` subclasses that carry an HTTP
status code.  The HTTP handler (``repro.serve.http``) is a thin transport
over this class, so the load generator and the socket tests exercise the
exact same code path.
"""

from __future__ import annotations

import math
import time
from concurrent.futures import CancelledError, Future
from concurrent.futures import TimeoutError as FuturesTimeoutError
from typing import Dict, List, Optional

import numpy as np

from repro.runtime.scheduler import (BackendFaultError, CircuitOpenError,
                                     DeadlineExceededError, QueueFullError)

# client-side timeout derived from deadline_us: the deadline bounds *launch*,
# not completion, so allow the full budget plus a generous execution grace —
# the point is that a wedged server can never hold the caller forever
_TIMEOUT_GRACE_S = 30.0


class ServeError(Exception):
    """Base serving error; ``status``/``code`` map straight onto HTTP.
    ``retry_after_s`` (when set) rides 429/503 replies as ``Retry-After``;
    ``trace_id`` (when known) rides the error body and the
    ``X-Repro-Trace-Id`` response header, so rejected/shed requests stay
    correlatable with their server-side trace."""
    status = 500
    code = "internal"
    retry_after_s: Optional[float] = None
    trace_id: Optional[str] = None


class BadRequestError(ServeError):
    status = 400
    code = "bad_request"


class NotFoundError(ServeError):
    status = 404
    code = "not_found"


class OverloadedError(ServeError):
    """Admission control rejected the request (queue at ``max_queue``)."""
    status = 429
    code = "overloaded"
    retry_after_s = 1.0


class WarmingUpError(ServeError):
    """The server is still precompiling its bucket ladder; retry shortly.
    ``/healthz`` reports ``"warming"`` for the duration."""
    status = 503
    code = "warming"
    retry_after_s = 1.0


class UnavailableError(ServeError):
    """The net's circuit breaker is open and no fallback backend is
    configured; ``Retry-After`` carries the time to the half-open probe."""
    status = 503
    code = "circuit_open"

    def __init__(self, message: str = "", retry_after_s: Optional[float] = None):
        super().__init__(message)
        if retry_after_s is not None:
            self.retry_after_s = retry_after_s


class BackendError(ServeError):
    """The backend exhausted its retry budget for this request's batch."""
    status = 500
    code = "backend_fault"


class DeadlineError(ServeError):
    """The request's ``deadline_us`` elapsed before launch; it was shed."""
    status = 504
    code = "deadline_exceeded"


class ClientTimeoutError(ServeError):
    """The client-side ``timeout_s`` elapsed waiting for the result; the
    request may still complete server-side, but the caller has moved on."""
    status = 504
    code = "client_timeout"


class ServeClient:
    """Session front door with serving semantics and typed errors.

    Rejected requests (unknown net, malformed input, saturated queue) fail
    *fast and synchronously*; admitted requests always resolve — with a
    result, a backend error, or :class:`DeadlineError` when shed.
    """

    def __init__(self, session, timeout_s: Optional[float] = None):
        self.session = session
        self.timeout_s = timeout_s       # default client-side result timeout
        self._warming = False

    # -- warmup gate ---------------------------------------------------------
    def begin_warmup(self) -> None:
        """Refuse inference (503 ``warming``) until ``finish_warmup``;
        ``/healthz``, ``/metrics`` and ``/v1/nets`` keep answering."""
        self._warming = True

    def finish_warmup(self) -> None:
        self._warming = False

    # -- inference -----------------------------------------------------------
    def infer_async(self, net: Optional[str], x, priority: int = 0,
                    deadline_us: Optional[float] = None,
                    trace_id: Optional[str] = None) -> Future:
        """Admit one request; returns the runtime Future (which carries
        ``fut.trace_id``).  ``trace_id`` — a client-supplied
        ``X-Repro-Trace-Id`` — forces the request into the sampled set.

        Raises ``NotFoundError`` / ``BadRequestError`` / ``OverloadedError``
        / ``WarmingUpError`` synchronously — an exception here means the
        request never entered the queue."""
        if self._warming:
            raise WarmingUpError(
                "server is warming up (precompiling bucket shapes); "
                "retry shortly")
        try:
            return self.session.submit(x, net=net, priority=priority,
                                       deadline_us=deadline_us,
                                       trace_id=trace_id)
        except KeyError as e:
            raise NotFoundError(str(e.args[0]) if e.args else str(e)) from None
        except QueueFullError as e:
            err = OverloadedError(str(e))
            err.trace_id = getattr(e, "trace_id", None)
            raise err from None
        except CircuitOpenError as e:
            err = UnavailableError(str(e), retry_after_s=e.retry_after_s)
            err.trace_id = getattr(e, "trace_id", None)
            raise err from None
        except (ValueError, TypeError) as e:
            raise BadRequestError(str(e)) from None

    @staticmethod
    def resolve_future(fut: Future, timeout: Optional[float] = None):
        """Block on a runtime future, translating shed/fault/cancel/timeout
        exceptions into their typed ``ServeError`` (each carrying the
        future's ``trace_id``)."""
        tid = getattr(fut, "trace_id", None)

        def _fail(err: ServeError):
            err.trace_id = tid
            raise err from None

        try:
            return fut.result(timeout=timeout)
        except DeadlineExceededError as e:
            _fail(DeadlineError(str(e)))
        except BackendFaultError as e:
            _fail(BackendError(str(e)))
        except FuturesTimeoutError:
            _fail(ClientTimeoutError(
                f"no result within the client-side timeout ({timeout}s); "
                f"the server may be wedged"))
        except CancelledError:
            _fail(ServeError("request cancelled: server shutting down"))

    def timeout_for(self, deadline_us: Optional[float]) -> Optional[float]:
        """Default client-side result timeout: the constructor's
        ``timeout_s``, or a finite ``deadline_us`` plus execution grace."""
        if self.timeout_s is not None:
            return self.timeout_s
        if deadline_us is not None and math.isfinite(deadline_us):
            return deadline_us * 1e-6 + _TIMEOUT_GRACE_S
        return None

    def infer(self, net: Optional[str], x, priority: int = 0,
              deadline_us: Optional[float] = None,
              timeout: Optional[float] = None,
              trace_id: Optional[str] = None):
        """Synchronous inference -> ``ExecResult`` (or a ``ServeError``).

        ``timeout`` (seconds) bounds the client-side wait; it defaults to
        the constructor's ``timeout_s``, or — when the request carries a
        finite ``deadline_us`` — to the deadline plus an execution grace,
        so a stuck server can never block the caller indefinitely."""
        if timeout is None:
            timeout = self.timeout_for(deadline_us)
        return self.resolve_future(
            self.infer_async(net, x, priority=priority,
                             deadline_us=deadline_us, trace_id=trace_id),
            timeout=timeout)

    # -- introspection -------------------------------------------------------
    def nets(self) -> List[Dict]:
        """One descriptor per resident network (the ``/v1/nets`` body).

        Includes the engine metadata a client needs to discover precision
        *before* submitting: ``config`` (``nv_small`` / ``nv_full``) and
        ``dtype`` (``int8`` / ``bf16``) alongside the input shape."""
        out = []
        for name in self.session.networks:
            art = self.session.artifacts(name)
            ex = self.session.executor(name)
            dims = getattr(ex, "input_dims", None)
            cfg = getattr(art, "cfg", None)
            out.append({
                "name": name,
                "backend": self.session._resolve(name).backend,
                "config": getattr(cfg, "name", None),
                "dtype": getattr(cfg, "dtype", None),
                "input_shape": list(dims[1:]) if dims is not None else None,
                "output_elems": getattr(art, "output_elems", None),
                "queue_depth": self.session.queue_depth(name),
            })
        return out

    def healthz(self) -> Dict:
        """Liveness + per-net health.  ``status`` is ``ok`` only when every
        resident net is ``healthy``; the HTTP layer returns 503 otherwise.
        Per-net states: ``warming`` / ``healthy`` / ``degraded`` (circuit
        open, fallback serving) / ``circuit_open`` (shedding)."""
        ses = self.session
        if self._warming:
            states = {n: "warming" for n in ses.networks}
            status = "warming"
        else:
            states = {n: h["state"] for n, h in ses.health().items()}
            status = ("ok" if all(s == "healthy" for s in states.values())
                      else "degraded")
        return {"status": status, "nets": len(ses.networks),
                "net_states": states, "time": time.time()}

    def metrics_text(self) -> str:
        from repro.serve import metrics
        return metrics.render(self.session)

    def trace_doc(self, limit: Optional[int] = None) -> Dict:
        """Chrome trace-event JSON of the most recent completed traces
        (the ``GET /v1/trace`` body) — load into chrome://tracing or
        ui.perfetto.dev."""
        return self.session.tracer.chrome_trace(limit)
