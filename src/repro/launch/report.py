"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun JSONs.

    PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def _fmt_bytes(b):
    if b is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b/div:.1f}{unit}"
    return f"{b:.0f}B"


def _fmt_s(s):
    if s is None:
        return "-"
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}us"


def load(dir_: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | status | compile | GB/device | collectives (per-chip bytes) |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r.get("shape", ""), r.get("mesh", ""))):
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r.get('shape','-')} | {r.get('mesh','-')} "
                       f"| **{r.get('status')}** | - | - | - |")
            continue
        mem = r["memory_analysis"].get("peak_per_device_gb")
        coll = r["hlo"]["collective_breakdown"]
        coll_s = ", ".join(f"{k}:{_fmt_bytes(v)}" for k, v in sorted(coll.items())) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']}s "
            f"| {mem:.1f} | {coll_s} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16") -> str:
    out = ["| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful | mem-floor | roofline-frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r.get("shape", ""))):
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rl['compute_s'])} "
            f"| {_fmt_s(rl['memory_s'])} | {_fmt_s(rl['collective_s'])} "
            f"| **{rl['dominant']}** | {rl['model_flops_total']:.2e} "
            f"| {rl['useful_ratio']:.2f} | {rl['mem_floor_ratio']:.3f} "
            f"| {rl['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    rows = load(args.dir)
    if args.section in ("dryrun", "both"):
        print("## Dry-run\n")
        print(dryrun_table(rows))
        print()
    if args.section in ("roofline", "both"):
        print("## Roofline (single-pod 16x16)\n")
        print(roofline_table(rows, "16x16"))


if __name__ == "__main__":
    main()
