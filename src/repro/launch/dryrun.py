import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove it fits, and extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above must execute before
any jax import anywhere): ``PYTHONPATH=src python -m repro.launch.dryrun ...``

    --arch <id> --shape <name> [--multipod] [--out DIR]   one cell
    --all [--multipod] [--out DIR]                        sweep (subprocess per
                                                          cell for isolation)

Per cell it records: compiled memory_analysis (bytes/device — proves it fits),
raw cost_analysis, trip-count-corrected HLO FLOPs/bytes, per-collective
traffic, and the three roofline terms (launch/roofline.py).
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import numpy as np


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str,
             opt_flags=()) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.data.pipeline import BatchSpec
    from repro.launch import hlo_analysis, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (batch_sharding, build_decode_step,
                                    build_prefill, build_train_step)
    from repro.models import registry
    from repro.optim import adamw

    cfg = configs.get_config(arch_id)
    sdef0 = configs.SHAPES[shape_name]
    if sdef0["kind"] != "train":
        # serving path stores weights in bf16 (the paper's engine stores int8):
        # halves param reads and avoids per-layer f32->bf16 converts
        cfg = cfg.__class__(**{**cfg.__dict__, "param_dtype": jnp.bfloat16})
    for flag in opt_flags:                     # perf-iteration overrides
        k, v = flag.split("=", 1)
        cfg = cfg.__class__(**{**cfg.__dict__, k: type(getattr(cfg, k))(eval(v))})
    sdef = configs.SHAPES[shape_name]
    spec = BatchSpec(seq_len=sdef["seq_len"], global_batch=sdef["global_batch"],
                     kind=sdef["kind"])
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    model = registry.get(cfg.family)
    shapes = model.param_shapes(cfg)
    t0 = time.time()

    with mesh:
        if spec.kind == "train":
            ocfg = adamw.AdamWConfig(
                state_dtype=jnp.bfloat16 if cfg.opt_state_bf16 else None)
            step_fn, sh = build_train_step(cfg, mesh, ocfg)
            psds = jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
                shapes, sh["params"])
            sd = jnp.bfloat16 if cfg.opt_state_bf16 else None
            msds = jax.tree.map(
                lambda s_: jax.ShapeDtypeStruct(s_.shape, sd or s_.dtype,
                                                sharding=s_.sharding), psds)
            osds = adamw.AdamWState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                mu=msds, nu=msds)
            bsh, bshapes = batch_sharding(cfg, mesh, spec)
            bsds = jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
                bshapes, bsh)
            lowered = step_fn.lower(psds, osds, bsds)
        elif spec.kind == "prefill":
            fn, psh = build_prefill(cfg, mesh, spec)
            psds = jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
                shapes, psh)
            bsh, bshapes = batch_sharding(cfg, mesh, spec)
            bsds = jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
                bshapes, bsh)
            lowered = fn.lower(psds, bsds)
        else:  # decode
            fn, sh = build_decode_step(cfg, mesh, spec)
            psds = jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
                shapes, sh["params"])
            csds = jax.tree.map(
                lambda s, n: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=n),
                sh["cache_shapes"],
                jax.tree.map(lambda x: x, sh["cache"]))
            tsds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                    for k, v in sh["tok_shapes"].items()}
            lowered = fn.lower(psds, csds, tsds, jnp.int32(0))
        compiled = lowered.compile()

    t_compile = time.time() - t0
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_gb": ma.argument_size_in_bytes / 1e9,
            "output_gb": ma.output_size_in_bytes / 1e9,
            "temp_gb": ma.temp_size_in_bytes / 1e9,
            "alias_gb": ma.alias_size_in_bytes / 1e9,
            "peak_per_device_gb": (ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes
                                   - ma.alias_size_in_bytes) / 1e9,
        }
    except Exception as e:                                # pragma: no cover
        mem = {"error": str(e)}
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else (ca or {})
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))

    hlo_text = compiled.as_text()
    hlo = hlo_analysis.analyze(hlo_text, default_trip=cfg.n_layers)
    mf = roofline.model_flops(cfg, spec)
    cache_bytes = 0.0
    if spec.kind == "decode":
        cache_bytes = sum(
            int(np.prod(s.shape)) * s.dtype.itemsize
            for s in jax.tree.leaves(sh["cache_shapes"]))
    rl = roofline.terms(arch_id, shape_name, mesh_name, chips, hlo.flops,
                        hlo.bytes_accessed, hlo.collective_bytes, mf,
                        min_bytes_total=roofline.min_bytes(cfg, spec, cache_bytes))
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "status": "ok", "compile_s": round(t_compile, 1),
        "params_b": cfg.num_params() / 1e9,
        "active_params_b": cfg.active_params() / 1e9,
        "memory_analysis": mem,
        "cost_analysis_raw": {"flops": raw_flops, "bytes": raw_bytes},
        "hlo": {
            "flops_per_chip": hlo.flops,
            "bytes_per_chip": hlo.bytes_accessed,
            "collective_bytes_per_chip": hlo.collective_bytes,
            "collective_breakdown": hlo.collective_breakdown,
            "collective_counts": hlo.collective_counts,
            "n_while": hlo.n_while_loops,
        },
        "roofline": rl.to_dict(),
        "opt_flags": list(opt_flags),
        "hlo_size_bytes": len(hlo_text),
    }
    print(f"[dryrun] {arch_id} x {shape_name} @ {mesh_name}: OK "
          f"(compile {t_compile:.0f}s, {mem.get('peak_per_device_gb', float('nan')):.2f} "
          f"GB/dev, dominant={rl.dominant}, frac={rl.roofline_fraction:.3f})")
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", action="append", default=[],
                    help="cfg override key=value (perf iterations)")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        from repro import configs      # safe: subprocesses do the compiling
        failures = []
        for arch_id, shape_name in configs.cells():
            tag = f"{arch_id}__{shape_name}__{'2x16x16' if args.multipod else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] skip cached {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch_id,
                   "--shape", shape_name, "--out", args.out]
            if args.multipod:
                cmd.append("--multipod")
            for o in args.opt:
                cmd += ["--opt", o]
            print(f"[dryrun] launching {tag}", flush=True)
            r = subprocess.run(cmd, timeout=args.timeout)
            if r.returncode != 0:
                failures.append(tag)
                with open(path, "w") as f:
                    json.dump({"arch": arch_id, "shape": shape_name,
                               "status": "failed", "rc": r.returncode}, f)
        print(f"[dryrun] sweep done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    tag = f"{args.arch}__{args.shape}__{'2x16x16' if args.multipod else '16x16'}"
    suffix = "".join(f"__{o}" for o in args.opt).replace("=", "-")
    path = os.path.join(args.out, tag + suffix + ".json")
    try:
        result = run_cell(args.arch, args.shape, args.multipod, args.out,
                          tuple(args.opt))
    except Exception:
        traceback.print_exc()
        with open(path, "w") as f:
            json.dump({"arch": args.arch, "shape": args.shape, "status": "error",
                       "trace": traceback.format_exc()}, f, indent=1)
        return 1
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
