"""Roofline-term computation from a compiled dry-run artifact.

TPU v5e-class hardware constants (the TARGET platform; this container is a
CPU host used only to lower/compile):

    peak bf16 compute : 197 TFLOP/s per chip (394 TOP/s int8)
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link

Terms (per the assignment):
    compute    = HLO_FLOPs   / (chips * peak)
    memory     = HLO_bytes   / (chips * HBM_bw)
    collective = coll_bytes  / (chips * link_bw)

The post-SPMD module is a per-device program, so FLOPs/bytes parsed from it are
already per-chip (chips divide out); we report both conventions explicitly.

MODEL_FLOPS (usefulness reference): 6·N·D (train), 2·N·D (prefill),
2·N per decode token (N = active params for MoE).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (post-SPMD program) quantities
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    roofline_fraction: float     # useful-time / dominant-term time
    min_bytes_per_chip: float = 0.0   # unavoidable traffic (params+cache)/chips
    mem_floor_ratio: float = 0.0      # min_bytes / modeled bytes (1.0 = optimal)
    note: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def terms(arch: str, shape: str, mesh_name: str, chips: int, hlo_flops: float,
          hlo_bytes: float, coll_bytes: float, model_flops: float,
          peak: float = PEAK_FLOPS_BF16, min_bytes_total: float = 0.0) -> Roofline:
    compute_s = hlo_flops / peak
    memory_s = hlo_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", collective_s), key=lambda t: t[1])[0]
    dom_s = max(compute_s, memory_s, collective_s)
    # compute-side roofline fraction: useful model FLOPs at peak vs the
    # dominant-term time.  For inherently memory-bound steps (decode) the
    # mem_floor_ratio is the honest score: how close the modeled traffic is
    # to the unavoidable params+cache movement.
    useful_s = model_flops / (chips * peak)
    frac = useful_s / dom_s if dom_s > 0 else 0.0
    min_b = min_bytes_total / chips if min_bytes_total else 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=hlo_flops, bytes_per_chip=hlo_bytes,
        coll_bytes_per_chip=coll_bytes, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dom,
        model_flops_total=model_flops,
        useful_ratio=model_flops / max(hlo_flops * chips, 1.0),
        roofline_fraction=min(frac, 1.0),
        min_bytes_per_chip=min_b,
        mem_floor_ratio=min(min_b / hlo_bytes, 1.0) if hlo_bytes else 0.0)


def min_bytes(cfg, spec, cache_bytes: float = 0.0) -> float:
    """Unavoidable per-step HBM traffic: read every active param once
    (bf16) + read/update the KV cache (decode) or write it (prefill)."""
    pbytes = 2.0 * cfg.active_params()
    if spec.kind == "train":
        # fwd + bwd param reads + grad/opt writes ~ 3x params in + 2x out (f32)
        return 3 * pbytes + 2 * 4.0 * cfg.num_params()
    return pbytes + cache_bytes


def model_flops(cfg, spec) -> float:
    """Analytic useful-FLOPs reference for one step of this cell."""
    n_active = cfg.active_params()
    b, s = spec.global_batch, spec.seq_len
    if spec.kind == "train":
        if cfg.family == "encdec":
            dec = max(s // cfg.dec_len_ratio, 64)
            return 6.0 * n_active * b * (s + dec) / 2   # enc fwd-only approx
        return 6.0 * n_active * b * s
    if spec.kind == "prefill":
        if cfg.family == "encdec":
            dec = max(s // cfg.dec_len_ratio, 64)
            return 2.0 * n_active * b * (s + dec)
        return 2.0 * n_active * b * s
    # decode: one token per sequence
    return 2.0 * n_active * b
