"""Post-SPMD HLO text analysis: FLOPs / bytes / collective traffic with
while-loop trip-count correction.

Why this exists: XLA's ``compiled.cost_analysis()`` visits a ``while`` body
ONCE (verified empirically in this container — a 4-layer scan reports exactly
1/4 the FLOPs of its unrolled twin), so any scan-over-layers model is
undercounted by ~L.  And collective bytes are not in cost_analysis at all.
This module parses ``compiled.as_text()`` into a computation call graph,
extracts trip counts from while-condition compare constants, and walks every
op with its true execution multiplicity.

Accounting rules:
  * FLOPs: dot = 2 * |result| * K_contracted (from the contracting-dims attr);
    elementwise arith = |result|; reduce = |operand|.  x multiplicity.
  * bytes: counted at the fusion boundary — operands + results of top-level
    (non-fused-subcomputation) ops that touch buffers; fusion-internal ops are
    register traffic on a real TPU and are excluded.
  * collective bytes: operand bytes of all-reduce / all-gather / reduce-scatter
    / all-to-all / collective-permute, x multiplicity.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
# callee attrs: bare form (body=%x) and braces form (branch_computations={%a, %b})
_CALLED_BARE_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CALLED_BRACE_RE = re.compile(r"(?:calls|branch_computations)=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMMENT_RE = re.compile(r"/\*.*?\*/")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "power", "select", "and",
    "or", "xor", "compare", "sign", "floor", "ceil", "cosine", "sine",
    "shift-right-arithmetic", "shift-right-logical", "shift-left", "clamp",
    "exponential-minus-one", "logistic",
}


def _split_operands(s: str) -> List[str]:
    """Split an HLO operand list on top-level commas only.

    Shape strings like ``f32[64,64]{1,0}`` contain commas, so a naive
    ``s.split(",")`` shreds every operand into garbage tokens — this was
    exactly the scan-flops undercount: dot operands failed to resolve, the
    contracted-K lookup missed, and every matmul fell back to 2*|result|.
    """
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def _operand_names(line: str) -> List[str]:
    """Operand names of an op line: ``dot(f32[8,8]{1,0} %a, ... %b)`` -> [a, b]."""
    m = re.search(r"\(([^)]*)\)", line)
    if not m:
        return []
    names = []
    for tok in _split_operands(m.group(1)):
        if not tok:
            continue
        # strip an inline type prefix ("f32[64,64]{1,0} %name" -> "%name")
        names.append(tok.split()[-1].lstrip("%"))
    return names


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    dims = m.group(2)
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    line: str
    callees: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_fusion_body: bool = False


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    fusion_bodies = set()
    for line in text.splitlines():
        stripped = _COMMENT_RE.sub("", line).strip()   # drop /*index=N*/ comments
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$", stripped)
        if header and ("=" not in stripped.split("->")[0]):
            current = Computation(name=header.group(1), ops=[])
            comps[current.name] = current
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(stripped)
        if not m:
            continue
        name, rtype, kind = m.group(1), m.group(2), m.group(3)
        callees = []
        for cm in _CALLED_BARE_RE.finditer(stripped):
            callees.append(cm.group(1))
        for cm in _CALLED_BRACE_RE.finditer(stripped):
            for c in cm.group(1).split(","):
                c = c.strip().lstrip("%")
                if c:
                    callees.append(c)
        if kind == "fusion":
            fusion_bodies.update(callees)
        current.ops.append(Op(name=name, kind=kind, result_type=rtype,
                              line=stripped, callees=callees))
    for fb in fusion_bodies:
        if fb in comps:
            comps[fb].is_fusion_body = True
    return comps


def _entry_name(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        return m.group(1)
    return next(iter(comps))


def _trip_count(comps: Dict[str, Computation], cond_name: str,
                default: int) -> int:
    """Largest integer constant in the while condition (compare bound)."""
    cond = comps.get(cond_name)
    if cond is None:
        return default
    best = default
    for op in cond.ops:
        for c in _CONST_RE.finditer(op.line):
            best = max(best, int(c.group(1)))
    return best


def compute_multiplicities(comps: Dict[str, Computation], entry: str,
                           default_trip: int = 1) -> Dict[str, float]:
    """Execution count per computation, composing nested while trip counts."""
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            if op.kind == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.line)
                bm = re.search(r"body=%?([\w.\-]+)", op.line)
                # XLA usually annotates the exact trip count; fall back to the
                # condition's compare constant, then to the caller's default.
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps, cm.group(1) if cm else "", default_trip)
                for target, factor in ((bm.group(1) if bm else None, trip),
                                       (cm.group(1) if cm else None, trip + 1)):
                    if target:
                        mult[target] = mult.get(target, 0.0) + mult[cname] * factor
                        if target not in seen:
                            seen.add(target)
                            order.append(target)
            else:
                for callee in op.callees:
                    mult[callee] = mult.get(callee, 0.0) + mult[cname]
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)
    return mult


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    """2 * |result| * K from contracting dims."""
    result_elems = shape_elems(op.result_type)
    operands = _operand_names(op.line)
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not cm or not operands:
        return 2.0 * result_elems
    lhs_type = shapes.get(operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * result_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for ci in cm.group(1).split(","):
        if ci:
            idx = int(ci)
            if idx < len(dims):
                k *= dims[idx]
    return 2.0 * result_elems * k


@dataclasses.dataclass
class HloCosts:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    collective_counts: Dict[str, int]
    n_while_loops: int
    trip_corrected: bool


_PASSTHRU = {"convert", "bitcast", "copy", "reshape", "transpose"}


def _fusion_bytes(body: "Computation", operand_types: List[str]) -> int:
    """Bytes a fusion actually touches: parameters consumed only through
    dynamic-slice count their slices; in-place dynamic-update-slice targets
    count the update; everything else counts fully (XLA-style).

    Consumer chains are resolved THROUGH convert/bitcast/copy ops because the
    CPU backend's FloatNormalization pass (no native bf16) wraps loop-carried
    bf16 buffers in f32 converts that a TPU build would not emit — a naive
    count would charge the whole buffer per iteration (verified: ~880 GB of
    phantom traffic on a 32k-decode cell).  Slice bytes are charged at the
    PARAMETER's dtype (the dtype the target hardware would stream)."""
    params: Dict[str, int] = {}      # param op name -> operand index
    consumers: Dict[str, List[Op]] = {}
    for op in body.ops:
        if op.kind == "parameter":
            pm = re.search(r"parameter\((\d+)\)", op.line)
            if pm:
                params[op.name] = int(pm.group(1))
        if op.kind != "parameter":
            for o in _operand_names(op.line):
                consumers.setdefault(o, []).append(op)
    total = 0
    body_shapes = {op.name: op.result_type for op in body.ops}

    def _is_dus_target(c: Op, name: str) -> bool:
        if c.kind != "dynamic-update-slice":
            return False
        ops_ = _operand_names(c.line)
        return bool(ops_) and ops_[0] == name

    def _effective(name: str, depth: int = 0) -> Optional[List[Tuple[str, Op]]]:
        """Resolve consumers through pass-through ops; None = opaque use."""
        out: List[Tuple[str, Op]] = []
        for c in consumers.get(name, []):
            if c.kind in ("dynamic-slice",) or _is_dus_target(c, name):
                out.append((name, c))
            elif c.kind in _PASSTHRU and depth < 4:
                nested = _effective(c.name, depth + 1)
                if nested is None:
                    return None
                out.extend(nested)
            else:
                return None
        return out

    for pname, idx in params.items():
        ptype = operand_types[idx] if idx < len(operand_types) else \
            body_shapes.get(pname, "")
        eff = _effective(pname)
        if eff is not None:
            # charge dynamic-slice reads at the param's dtype width
            pm_bytes = shape_bytes(ptype)
            pm_elems = shape_elems(ptype)
            width = pm_bytes / max(pm_elems, 1)
            total += int(sum(shape_elems(c.result_type) * width
                             for _, c in eff if c.kind == "dynamic-slice"))
        else:
            total += shape_bytes(ptype)
    # Root (the fusion's write): walk back through pass-through ops (the CPU
    # backend wraps loop buffers in converts) to the real producer; a
    # dynamic-update-slice root writes only its update slice.
    by_name = {op.name: op for op in body.ops}
    root = body.ops[-1] if body.ops else None
    for _ in range(4):
        if root is not None and root.kind in _PASSTHRU:
            ops_ = _operand_names(root.line)
            prod = ops_[0] if ops_ else ""
            if prod in by_name:
                root = by_name[prod]
                continue
        break
    if root is not None and root.kind == "dynamic-update-slice":
        ops_ = _operand_names(root.line)
        upd = ops_[1] if len(ops_) >= 2 else ""
        ut = body_shapes.get(upd, "")
        width = shape_bytes(body.ops[-1].result_type) / \
            max(shape_elems(body.ops[-1].result_type), 1)
        total += int(shape_elems(ut) * width) if ut else \
            shape_bytes(body.ops[-1].result_type)
    elif root is not None:
        total += shape_bytes(body.ops[-1].result_type)
    return total


def analyze(text: str, default_trip: int = 1) -> HloCosts:
    comps = parse_hlo(text)
    entry = _entry_name(comps, text)
    mult = compute_multiplicities(comps, entry, default_trip)

    # symbol table: op name -> result type (for operand lookups)
    shapes: Dict[str, str] = {}
    for comp in comps.values():
        for op in comp.ops:
            shapes[op.name] = op.result_type

    flops = 0.0
    mem_bytes = 0.0
    coll_bytes = 0.0
    coll_break: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    coll_counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    n_while = 0

    skip_mem = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
                "while", "call", "conditional", "after-all", "partition-id",
                "iota", "broadcast", "reshape", "transpose"}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            kind = op.kind
            # ---- FLOPs (fusion-internal ops included) -----------------------
            if kind == "dot":
                flops += m * _dot_flops(op, shapes)
            elif kind == "convolution":
                flops += m * 2.0 * shape_elems(op.result_type)   # lower bound
            elif kind in _ELEMENTWISE:
                flops += m * shape_elems(op.result_type)
            elif kind == "reduce":
                # operand elems (first operand)
                ops_ = _operand_names(op.line)
                if ops_:
                    flops += m * shape_elems(shapes.get(ops_[0], ""))
            # ---- collective traffic -----------------------------------------
            base_kind = kind.replace("-start", "").replace("-done", "")
            if base_kind in COLLECTIVES and not kind.endswith("-done"):
                b = 0
                for o in _operand_names(op.line):
                    if o in shapes:
                        b += shape_bytes(shapes[o])
                if b == 0:                       # fall back to result size
                    b = shape_bytes(op.result_type)
                coll_bytes += m * b
                coll_break[base_kind] += m * b
                coll_counts[base_kind] += 1
            # ---- memory traffic at fusion boundary --------------------------
            if not comp.is_fusion_body and kind not in skip_mem:
                if kind == "dynamic-update-slice":
                    # in-place update: read+write the UPDATE slice only
                    # (XLA HloCostAnalysis special-cases DUS the same way)
                    ops_ = _operand_names(op.line)
                    b = 0
                    if len(ops_) >= 2 and ops_[1] in shapes:
                        b = 2 * shape_bytes(shapes[ops_[1]])
                    mem_bytes += m * b
                elif kind == "dynamic-slice":
                    mem_bytes += m * 2 * shape_bytes(op.result_type)
                elif kind == "fusion" and op.callees and op.callees[0] in comps:
                    operand_types = [shapes.get(o, "")
                                     for o in _operand_names(op.line)]
                    mem_bytes += m * _fusion_bytes(comps[op.callees[0]],
                                                   operand_types)
                else:
                    b = shape_bytes(op.result_type)
                    for o in _operand_names(op.line):
                        if o in shapes:
                            b += shape_bytes(shapes[o])
                    mem_bytes += m * b
            if kind == "while":
                n_while += 1

    return HloCosts(flops=flops, bytes_accessed=mem_bytes,
                    collective_bytes=coll_bytes,
                    collective_breakdown={k: v for k, v in coll_break.items() if v},
                    collective_counts={k: v for k, v in coll_counts.items() if v},
                    n_while_loops=n_while, trip_corrected=True)
