"""Serving driver: batched request loop over AOT prefill/decode binaries.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --requests 8 --prompt-len 64 --gen 32

Production posture (bare-metal replay at pod scale, DESIGN.md §2):
  * prefill and decode are each ONE compiled executable (per shape bucket),
  * the KV arena is statically planned and donated across steps,
  * request admission batches to the compiled batch size (padding slots),
  * per-request positions support ragged prompts within a batch.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import registry


class Server:
    """Minimal continuous-batching server over the compiled step binaries."""

    def __init__(self, cfg, mesh, batch_size: int, max_len: int, seed: int = 0):
        self.cfg = cfg
        self.model = registry.get(cfg.family)
        self.mesh = mesh
        self.b = batch_size
        self.max_len = max_len
        self.params = self.model.init_params(cfg, jax.random.key(seed))
        self.prefill_fn = jax.jit(
            lambda p, t: self.model.prefill(cfg, p, {"tokens": t}))
        self.decode_fn = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(cfg, p, c,
                                                        {"tokens": t}, pos),
            donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, n_gen: int):
        """prompts: (B, S) int32, right-aligned equal length (bucketed)."""
        b, s = prompts.shape
        assert b == self.b and s + n_gen <= self.max_len
        logits, pre_cache = self.prefill_fn(self.params, jnp.asarray(prompts))
        cache = self.model.init_cache(self.cfg, b, self.max_len)
        if self.cfg.family == "ssm":
            cache = pre_cache
        else:
            def blit(dst, src):
                if dst.ndim >= 2 and src.shape != dst.shape:
                    idx = tuple([slice(None)] * (dst.ndim - 2)
                                + [slice(0, src.shape[-2]), slice(None)])
                    return dst.at[idx].set(src.astype(dst.dtype))
                return src.astype(dst.dtype)
            cache = jax.tree.map(blit, cache, pre_cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out = [np.asarray(tok)]
        for i in range(n_gen - 1):
            logits, cache = self.decode_fn(self.params, cache, tok,
                                           jnp.asarray(s + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
        return np.concatenate(out, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)
    with mesh:
        srv = Server(cfg, mesh, args.requests, args.prompt_len + args.gen)
        prompts = rng.integers(1, cfg.vocab, (args.requests, args.prompt_len),
                               dtype=np.int32)
        t0 = time.perf_counter()
        gen = srv.generate(prompts, args.gen)
        dt = time.perf_counter() - t0
    total_tok = args.requests * args.gen
    print(f"[serve] arch={cfg.name} b={args.requests} prompt={args.prompt_len} "
          f"gen={args.gen}: {dt*1e3:.1f} ms total, {total_tok/dt:.0f} tok/s")
    for r in range(min(args.requests, 3)):
        print(f"  req{r}: {gen[r][:10].tolist()}")


if __name__ == "__main__":
    main()
