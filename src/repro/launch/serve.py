"""DEPRECATED — retired in favour of the ``repro.serve`` subsystem.

This module predates the ``Session``/``Scheduler`` runtime: its LLM-era
``Server`` re-implemented continuous batching privately, on top of model
code rather than the compiled NVDLA artifact path.  The serving stack now
lives in :mod:`repro.serve` (stdlib HTTP front-end + in-process
``ServeClient``) over :mod:`repro.runtime` (per-net dispatcher threads,
SLA-aware micro-batching, admission control):

    PYTHONPATH=src python -m repro.serve --artifacts <bundle_dir> --port 8000

Importing this shim warns; instantiating the old ``Server`` or invoking
``main()`` raises with the migration pointer.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.launch.serve is deprecated and its LLM-era Server has been "
    "retired; serve compiled bundles with `python -m repro.serve` "
    "(repro.serve.ServeClient / make_server over repro.runtime.Session)",
    DeprecationWarning, stacklevel=2)

_MIGRATION = (
    "repro.launch.serve.Server was retired: the serving stack is now "
    "repro.serve (HTTP front-end, per-net dispatchers, priority/deadline "
    "scheduling, admission control) over repro.runtime.Session.  Compile a "
    "network with repro.core.pipeline.CompilerPipeline, Artifacts.save() "
    "the bundle, then run `python -m repro.serve --artifacts <dir>`.")


class Server:
    """Placeholder for the retired LLM-era continuous-batching server."""

    def __init__(self, *args, **kwargs):
        raise NotImplementedError(_MIGRATION)


def main() -> None:
    raise SystemExit(_MIGRATION)


if __name__ == "__main__":
    main()
