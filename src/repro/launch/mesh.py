"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod" axis
carries pure data parallelism (one gradient all-reduce per step crosses the
inter-pod links; no per-layer collective ever does).

Defined as functions (never module-level constants) so importing this module
touches no jax device state — smoke tests must keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def _axis_types_kw(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh`` where supported.

    ``jax.sharding.AxisType`` only exists from jax 0.5; on 0.4.x meshes are
    implicitly Auto, so omitting the kwarg is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax-version-portable ``jax.make_mesh`` with Auto axis types."""
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (smoke tests / examples: 1 CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh):
    """The data-parallel axes of a mesh (('pod','data') when multi-pod)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
