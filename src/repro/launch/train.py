"""Training driver: bare-metal-style AOT step replay with full fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --batch 2 --seq 128 --ckpt-dir /tmp/ckpt

Implements the production loop structure:
  * AOT-compile ONE train-step executable, then replay it (no retracing) —
    the trace-replay philosophy of the paper applied to training,
  * checkpoint/restart: atomic, keep-last-k, optional async; exact data-stream
    resume; restores onto a DIFFERENT mesh/device count (elastic),
  * optional int8 error-feedback gradient compression over the 'pod' axis
    (--compress-grads; see distributed/compression.py),
  * straggler/fault story: deterministic step-indexed data (any host can
    recompute any shard), preemption-safe checkpoints.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import store
from repro.data.pipeline import BatchSpec, DataIterator
from repro.distributed import sharding
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import _named, batch_sharding, build_train_step
from repro.models import registry
from repro.optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = registry.get(cfg.family)
    mesh = make_host_mesh(args.model_parallel)
    spec = BatchSpec(seq_len=args.seq, global_batch=args.batch, kind="train")
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 20, 1))

    with mesh:
        step_fn, sh = build_train_step(cfg, mesh, opt_cfg)
        params = model.init_params(cfg, jax.random.key(args.seed))
        params = jax.device_put(params, sh["params"])
        opt_state = adamw.init(params)
        start_step = 0
        data = DataIterator(cfg, spec, seed=args.seed)

        # ---- restart path (fault tolerance / elastic rescale) --------------
        if args.ckpt_dir:
            last = store.latest_step(args.ckpt_dir)
            if last is not None:
                (params, opt_state), extras = store.restore(
                    args.ckpt_dir, last, (params, opt_state),
                    shardings=(sh["params"], sh["opt"]))
                data = DataIterator.restore(cfg, spec, extras["data"])
                start_step = extras["step"]
                print(f"[train] resumed from step {start_step} "
                      f"onto {mesh.devices.size} device(s)")

        bsh, _ = batch_sharding(cfg, mesh, spec)
        t_last, tok_per_step = time.time(), args.batch * args.seq
        for step in range(start_step, args.steps):
            host_batch = next(data)
            batch = jax.tree.map(
                lambda v, s: jax.device_put(jnp.asarray(v), s), host_batch, bsh)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                m = jax.device_get(metrics)
                dt = time.time() - t_last
                t_last = time.time()
                print(f"[train] step {step} loss={float(m['loss']):.4f} "
                      f"ce={float(m['ce']):.4f} gnorm={float(m['grad_norm']):.3f} "
                      f"({tok_per_step * min(10, step + 1) / max(dt, 1e-9):.0f} tok/s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                store.save(args.ckpt_dir, step + 1, (params, opt_state),
                           extras={"step": step + 1, "data": data.state()},
                           async_write=args.async_ckpt)
        if args.ckpt_dir:
            store.save(args.ckpt_dir, args.steps, (params, opt_state),
                       extras={"step": args.steps, "data": data.state()})
        print("[train] done")


if __name__ == "__main__":
    main()
