"""Jitted step builders: train / prefill / decode with full sharding specs.

These are the AOT-compiled "binaries" of the framework (DESIGN.md §2): one XLA
executable per (arch x shape x mesh), bound once, replayed by the run loops
with zero retracing — params, optimizer state and KV arenas are donated so
steady-state steps allocate nothing.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data import pipeline
from repro.distributed import sharding
from repro.models import registry
from repro.models.common import ArchConfig
from repro.optim import adamw


def _named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def build_train_step(cfg: ArchConfig, mesh, opt_cfg: adamw.AdamWConfig):
    """Returns (jitted step, shardings dict).

    Gradient accumulation: the global batch is split into ``cfg.grad_accum``
    microbatches scanned sequentially with summed grads — bounds live
    activation memory (saved scan carries scale with the microbatch, not the
    global batch) at zero extra collective traffic.
    """
    model = registry.get(cfg.family)
    pspec = sharding.param_specs(cfg, mesh)
    psh = _named(mesh, pspec)
    osh = adamw.AdamWState(step=NamedSharding(mesh, P()), mu=psh, nu=psh)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_axes = dp if len(dp) > 1 else dp[0]

    def _split_micro(batch, accum):
        def split(k, v):
            ax = 1 if k == "pos3" else 0               # pos3 is (3, B, S)
            b = v.shape[ax]
            new = v.shape[:ax] + (accum, b // accum) + v.shape[ax + 1:]
            out = v.reshape(new)
            if ax == 1:
                out = jnp.moveaxis(out, 1, 0)          # (accum, 3, B/a, S)
            spec = [None] * out.ndim
            spec[ax + 1] = dp_axes
            return jax.lax.with_sharding_constraint(
                out, P(*spec)) if dp else out
        return {k: split(k, v) for k, v in batch.items()}

    def step(params, opt_state, batch):
        accum = max(cfg.grad_accum, 1)
        some = next(iter(batch.values()))
        if accum > 1 and some.shape[0] % accum == 0:
            micro = _split_micro(batch, accum)

            def body(carry, mb):
                gsum, lsum = carry
                (l, metrics), g = jax.value_and_grad(
                    lambda p: model.loss(cfg, p, mb), has_aux=True)(params)
                gsum = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)),
                                            micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda m: m[-1], ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(cfg, p, batch), has_aux=True)(params)
        new_p, new_o, om = adamw.update(opt_cfg, grads, opt_state, params)
        return new_p, new_o, {"loss": loss, **metrics, **om}

    fn = jax.jit(step, donate_argnums=(0, 1),
                 in_shardings=(psh, osh, None),
                 out_shardings=(psh, osh, None))
    return fn, {"params": psh, "opt": osh}


def batch_sharding(cfg, mesh, spec: pipeline.BatchSpec, long_context=False):
    shapes = pipeline.batch_shapes(cfg, spec)
    bspec = sharding.batch_specs(cfg, mesh, shapes, long_context)
    return _named(mesh, bspec), shapes


def build_prefill(cfg: ArchConfig, mesh, spec: pipeline.BatchSpec):
    model = registry.get(cfg.family)
    psh = _named(mesh, sharding.param_specs(cfg, mesh))
    bsh, _ = batch_sharding(cfg, mesh, spec)

    def fn(params, batch):
        return model.prefill(cfg, params, batch)

    return jax.jit(fn, in_shardings=(psh, bsh)), psh


def build_decode_step(cfg: ArchConfig, mesh, spec: pipeline.BatchSpec):
    """serve_step: one new token against a seq_len KV cache (donated)."""
    model = registry.get(cfg.family)
    long_ctx = spec.global_batch == 1 and spec.seq_len > 65536
    psh = _named(mesh, sharding.param_specs(cfg, mesh))
    if cfg.family == "encdec":
        cache_shapes = model.init_cache(cfg, spec.global_batch, spec.seq_len,
                                        as_shapes=True, cross_len=spec.seq_len)
    else:
        cache_shapes = model.init_cache(cfg, spec.global_batch, spec.seq_len,
                                        as_shapes=True)
    csh = _named(mesh, sharding.cache_specs(cfg, mesh, cache_shapes, long_ctx))
    tok_shapes = pipeline.decode_batch_shapes(cfg, spec)
    tsh = _named(mesh, sharding.batch_specs(cfg, mesh, tok_shapes))

    def fn(params, cache, batch, pos):
        return model.decode_step(cfg, params, cache, batch, pos)

    jitted = jax.jit(fn, donate_argnums=(1,),
                     in_shardings=(psh, csh, tsh, None),
                     out_shardings=(None, csh))
    return jitted, {"params": psh, "cache": csh, "cache_shapes": cache_shapes,
                    "tok_shapes": tok_shapes}
