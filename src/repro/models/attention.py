"""Attention primitives used by the LM zoo.

``flash_mha`` is a pure-jnp chunked online-softmax attention (FlashAttention
schedule expressed with lax.scan) — it lowers through pjit/GSPMD for the
multi-pod dry-run and bounds live memory to O(q_chunk x kv_chunk) per head.
The Pallas kernels in kernels/ implement the same math as the TPU-target
hot-path; tests pin them against each other.

``decode_attn`` is the single-new-token path against a static-shape KV cache
(cache length = the cell's seq_len), masked by the current position.  When the
cache's sequence axis is sharded (long-context SP cells), the max/sum
reductions lower to cross-device partial-softmax combines under GSPMD —
the same (m, l, acc) merge the distributed flash-decode kernel tier uses.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              q_chunk: int = 512, kv_chunk: int = 1024,
              sm_scale: Optional[float] = None) -> jax.Array:
    """q (B,H,Sq,D); k/v (B,Hkv,Skv,D); GQA via head grouping. -> (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                       # may differ from d (e.g. MLA)
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    q = q.reshape(b, hkv, g, sq, d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    qs = q.reshape(b, hkv, g, nq, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)
    ks = k.reshape(b, hkv, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hkv, nk, kv_chunk, dv).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_idx):
        qi, iq = qi_idx                                    # (b,hkv,g,qc,d)
        qi32 = qi.astype(jnp.float32) * sm_scale

        def kv_step(carry, kv_idx):
            m, l, acc = carry
            ki, vi, ik = kv_idx
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qi32, ki.astype(jnp.float32))
            if causal:
                qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None]
                kpos = ik * kv_chunk + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((qpos >= kpos)[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                               vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (ks, vs, jnp.arange(nk)))
        return None, (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # out: (nq, b, hkv, g, qc, dv) -> (b, h, sq, dv)
    return out.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, dv)


def _context_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:                                  # pragma: no cover
        return None


def use_sp_decode(b: int, hkv: int, smax: int) -> Optional[object]:
    """Return the mesh when the sequence-parallel decode path applies (mirrors
    the cache-layout predicate in distributed/sharding.py)."""
    mesh = _context_mesh()
    if mesh is None or "model" not in mesh.axis_names or b <= 1:
        return None
    ms = mesh.shape["model"]
    if hkv % ms != 0 and smax % ms == 0 and smax // ms >= 512:
        return mesh
    return None


def decode_attn_sp(q, k_cache, v_cache, pos, mesh, *, sm_scale=None,
                   k_new=None, v_new=None):
    """Two-tier distributed flash-decode over a SEQUENCE-sharded cache, with
    the cache update fused INSIDE the shard (each rank owns its range).

    Each 'model' rank (a) writes the new K/V token iff ``pos`` falls in its
    slice (masked local write — no cross-shard dynamic-update-slice, which
    GSPMD would otherwise lower as a whole-cache select), then (b) runs flash
    attention over its slice; partial (m, l, acc) merge with pmax/psum — the
    same combine as the Pallas split-K kernel's intra-chip tier.

    q (B,H,1,D); caches (B,Hkv,S,D); k_new/v_new optional (B,Hkv,1,D).
    Returns out, or (out, k_cache', v_cache') when k_new is given.
    """
    import numpy as np
    from repro.distributed.shmap import shard_map_norep as shard_map
    from jax.sharding import PartitionSpec as P

    b, h, _, d = q.shape
    hkv = k_cache.shape[1]
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dpsize = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    bax = (dp if len(dp) > 1 else dp[0]) if (dp and b % dpsize == 0 and b > 1) \
        else None
    qspec = P(bax, None, None, None)
    cspec = P(bax, None, "model", None)
    with_update = k_new is not None

    def local(qv, kcv, vcv, knv, vnv, posv):
        s_loc = kcv.shape[2]
        start = jax.lax.axis_index("model") * s_loc
        if with_update:
            lpos = posv - start
            in_range = (lpos >= 0) & (lpos < s_loc)
            safe = jnp.clip(lpos, 0, s_loc - 1)
            kc_u = jax.lax.dynamic_update_slice(
                kcv, knv.astype(kcv.dtype), (0, 0, safe, 0))
            vc_u = jax.lax.dynamic_update_slice(
                vcv, vnv.astype(vcv.dtype), (0, 0, safe, 0))
            kcv = jnp.where(in_range, kc_u, kcv)
            vcv = jnp.where(in_range, vc_u, vcv)
        qg = (qv.reshape(-1, hkv, g, d) * sm_scale).astype(kcv.dtype)
        # bf16 x bf16 -> f32 accumulate: no materialised f32 cache copy
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, kcv,
                       preferred_element_type=jnp.float32)
        idx = start + jnp.arange(s_loc)[None, None, None, :]
        s = jnp.where(idx <= posv, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhgk,bhkd->bhgd", p.astype(vcv.dtype), vcv,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_g)                         # (b,hkv,g,1), broadcasts
        l_g = jax.lax.psum(l * w, "model")
        acc_g = jax.lax.psum(acc * w, "model")
        out = (acc_g / jnp.maximum(l_g, 1e-30)).reshape(-1, h, 1, d)
        out = out.astype(qv.dtype)
        return (out, kcv, vcv) if with_update else (out,)

    zero = jnp.zeros((b, hkv, 1, d), k_cache.dtype)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
                   out_specs=(qspec, cspec, cspec) if with_update else (qspec,))
    res = fn(q, k_cache, v_cache,
             k_new if with_update else zero,
             v_new if with_update else zero, pos)
    return res if with_update else res[0]


def decode_attn(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                pos: jax.Array, *, sm_scale: Optional[float] = None,
                kv_chunk: int = 4096) -> jax.Array:
    """One-token attention: q (B,H,1,D); caches (B,Hkv,Smax,D); pos scalar int32.

    Entries at index > pos are masked (cache is valid on [0, pos]).

    Three tiers, chosen to match how sharding.py lays the cache out:
      * sequence-sharded cache (kv-heads don't divide the model axis):
        two-tier distributed flash-decode via shard_map (_decode_attn_sp),
      * long unsharded caches: local flash-decode scan (online-softmax carry
        keeps HLO traffic ~= cache bytes instead of full-length f32 scores),
      * short caches: single fused pass.
    """
    b, h, _, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    mesh = use_sp_decode(b, hkv, smax)
    if mesh is not None:
        return decode_attn_sp(q, k_cache, v_cache, pos, mesh, sm_scale=sm_scale)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * sm_scale
    if smax <= kv_chunk or smax % kv_chunk:
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache.astype(jnp.float32))
        idx = jnp.arange(smax)[None, None, None, :]
        s = jnp.where(idx <= pos, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32)) / \
            jnp.maximum(l, 1e-30)
        return out.reshape(b, h, 1, d).astype(q.dtype)

    nc = smax // kv_chunk
    ks = k_cache.reshape(b, hkv, nc, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = v_cache.reshape(b, hkv, nc, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    def step(carry, inp):
        m, l, acc = carry
        ki, vi, ic = inp
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, ki.astype(jnp.float32))
        idx = ic * kv_chunk + jnp.arange(kv_chunk)[None, None, None, :]
        s = jnp.where(idx <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhgk,bhkd->bhgd", p,
                                       vi.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (ks, vs, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, 1, d).astype(q.dtype)


def update_cache(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Insert new (B,Hkv,T,D) at position ``pos`` along the cache's seq axis."""
    return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        (0, 0, pos, 0))
