"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (B, T, d) directly (in smoke tests they are random;
in a real deployment the two stride-2 convs + log-mel stage produce them).

Encoder: bidirectional attention over frames (sinusoidal positions).
Decoder: causal self-attention + cross-attention to encoder output.
Shapes: ``train_4k``/``prefill_32k`` use seq_len frames and seq_len //
``cfg.dec_len_ratio`` decoder tokens; ``decode_32k`` decodes one token against a
self-KV cache and a 32k-frame cross-KV cache.  (``long_500k`` is skipped: full
attention, see DESIGN.md §4.)
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models.common import (ArchConfig, act_shard, init_from_shapes,
                                 rms_norm, sds, xent_loss)


def _mha_shapes(cfg, L):
    d, H, Dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    pd = cfg.param_dtype
    return {"wq": sds((L, d, H * Dh), pd), "wk": sds((L, d, H * Dh), pd),
            "wv": sds((L, d, H * Dh), pd), "wo": sds((L, H * Dh, d), pd)}


def _mlp_shapes(cfg, L):
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    return {"w1": sds((L, d, f), pd), "w2": sds((L, f, d), pd)}


def param_shapes(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab
    Le, Ld = cfg.n_enc_layers, cfg.n_dec_layers
    pd = cfg.param_dtype
    return {
        "embed": sds((V, d), pd),            # decoder token embedding
        "enc": {"ln1": sds((Le, d), pd), "ln2": sds((Le, d), pd),
                "attn": _mha_shapes(cfg, Le), "mlp": _mlp_shapes(cfg, Le)},
        "dec": {"ln1": sds((Ld, d), pd), "ln2": sds((Ld, d), pd),
                "ln3": sds((Ld, d), pd),
                "self_attn": _mha_shapes(cfg, Ld),
                "cross_attn": _mha_shapes(cfg, Ld),
                "mlp": _mlp_shapes(cfg, Ld)},
        "ln_enc": sds((d,), pd),
        "ln_f": sds((d,), pd),
        "head": sds((V, d), pd),
    }


def init_params(cfg: ArchConfig, key: jax.Array):
    p = init_from_shapes(param_shapes(cfg), key)
    for part in ("enc", "dec"):
        for k in ("ln1", "ln2", "ln3"):
            if k in p[part]:
                p[part][k] = jnp.ones_like(p[part][k])
    p["ln_enc"] = jnp.ones_like(p["ln_enc"])
    p["ln_f"] = jnp.ones_like(p["ln_f"])
    return p


def _sinusoid(s: int, d: int) -> jax.Array:
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1), jnp.float32)


def _proj_heads(cfg, w, x):
    b, s, _ = x.shape
    return jnp.einsum("bsd,dx->bsx", x, w.astype(x.dtype)).reshape(
        b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _mha(cfg, p, xq, xkv, causal):
    q = _proj_heads(cfg, p["wq"], xq)
    k = _proj_heads(cfg, p["wk"], xkv)
    v = _proj_heads(cfg, p["wv"], xkv)
    o = attn_lib.flash_mha(q, k, v, causal=causal)
    b, s = xq.shape[0], xq.shape[1]
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bsx,xd->bsd", o, p["wo"].astype(xq.dtype)), (k, v)


def _mlp(p, x):
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(
        jnp.einsum("bsd,df->bsf", x, p["w1"].astype(x.dtype))),
        p["w2"].astype(x.dtype))


def encode(cfg: ArchConfig, params, frames: jax.Array) -> jax.Array:
    """frames (B, T, d) stub embeddings -> encoder output (B, T, d)."""
    x = frames.astype(cfg.compute_dtype) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(cfg.compute_dtype)[None]

    def body(xc, p_l):
        xc = act_shard(xc, enabled=cfg.seq_parallel)
        h, _ = _mha(cfg, p_l["attn"], rms_norm(xc, p_l["ln1"], cfg.norm_eps),
                    rms_norm(xc, p_l["ln1"], cfg.norm_eps), causal=False)
        xc = xc + h
        xc = xc + _mlp(p_l["mlp"], rms_norm(xc, p_l["ln2"], cfg.norm_eps))
        return xc, 0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _decoder(cfg, params, tokens, enc_out, collect_cache=False):
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = x + _sinusoid(s, cfg.d_model).astype(x.dtype)[None]

    def body(xc, p_l):
        xc = act_shard(xc, enabled=cfg.seq_parallel)
        h, self_kv = _mha(cfg, p_l["self_attn"],
                          rms_norm(xc, p_l["ln1"], cfg.norm_eps),
                          rms_norm(xc, p_l["ln1"], cfg.norm_eps), causal=True)
        xc = xc + h
        h, cross_kv = _mha(cfg, p_l["cross_attn"],
                           rms_norm(xc, p_l["ln2"], cfg.norm_eps), enc_out,
                           causal=False)
        xc = xc + h
        xc = xc + _mlp(p_l["mlp"], rms_norm(xc, p_l["ln3"], cfg.norm_eps))
        return xc, (self_kv, cross_kv) if collect_cache else 0

    body_fn = jax.checkpoint(body) if (cfg.remat and not collect_cache) else body
    x, caches = jax.lax.scan(body_fn, x, params["dec"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps), caches


def loss(cfg: ArchConfig, params, batch):
    """batch: frames (B,T,d), tokens (B,Sd), labels (B,Sd)."""
    enc_out = encode(cfg, params, batch["frames"])
    x, _ = _decoder(cfg, params, batch["tokens"], enc_out)
    ce = xent_loss(x, params["head"], batch["labels"], cfg.loss_chunk)
    return ce, {"ce": ce}


def init_cache(cfg: ArchConfig, b: int, max_len: int, as_shapes: bool = False,
               cross_len: int | None = None):
    Ld, H, Dh = cfg.n_dec_layers, cfg.n_heads, cfg.head_dim
    cross_len = cross_len or max_len
    dec_len = max(max_len // cfg.dec_len_ratio, 64)
    ct = cfg.compute_dtype
    shapes = {"self_k": sds((Ld, b, H, dec_len, Dh), ct),
              "self_v": sds((Ld, b, H, dec_len, Dh), ct),
              "cross_k": sds((Ld, b, H, cross_len, Dh), ct),
              "cross_v": sds((Ld, b, H, cross_len, Dh), ct)}
    if as_shapes:
        return shapes
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)


def prefill(cfg: ArchConfig, params, batch):
    """Encode frames + run decoder prompt; returns last logits + caches."""
    enc_out = encode(cfg, params, batch["frames"])
    x, caches = _decoder(cfg, params, batch["tokens"], enc_out,
                         collect_cache=True)
    (self_k, self_v), (cross_k, cross_v) = caches
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    cache = {"self_k": self_k, "self_v": self_v,
             "cross_k": cross_k, "cross_v": cross_v}
    return logits.astype(jnp.float32), cache


def decode_step(cfg: ArchConfig, params, cache, batch, pos):
    """One decoder token; cross-KV is static (encoder ran at prefill)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    spos = _sinusoid(cache["self_k"].shape[3], cfg.d_model)
    x = x + jax.lax.dynamic_slice(spos, (pos, 0), (1, cfg.d_model)).astype(x.dtype)[None]

    def body(xc, inp):
        p_l, sk, sv, ck, cv = inp
        h = rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        q = _proj_heads(cfg, p_l["self_attn"]["wq"], h)
        kn = _proj_heads(cfg, p_l["self_attn"]["wk"], h)
        vn = _proj_heads(cfg, p_l["self_attn"]["wv"], h)
        mesh = attn_lib.use_sp_decode(b, sk.shape[1], sk.shape[2])
        if mesh is not None:
            o, sk, sv = attn_lib.decode_attn_sp(q, sk, sv, pos, mesh,
                                                k_new=kn, v_new=vn)
        else:
            sk = attn_lib.update_cache(sk, kn, pos)
            sv = attn_lib.update_cache(sv, vn, pos)
            o = attn_lib.decode_attn(q, sk, sv, pos)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        xc = xc + jnp.einsum("bsx,xd->bsd", o,
                             p_l["self_attn"]["wo"].astype(xc.dtype))
        h = rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        q = _proj_heads(cfg, p_l["cross_attn"]["wq"], h)
        o = attn_lib.decode_attn(q, ck, cv, jnp.asarray(ck.shape[2] - 1))
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        xc = xc + jnp.einsum("bsx,xd->bsd", o,
                             p_l["cross_attn"]["wo"].astype(xc.dtype))
        xc = xc + _mlp(p_l["mlp"], rms_norm(xc, p_l["ln3"], cfg.norm_eps))
        return xc, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    new_cache = dict(cache, self_k=sk, self_v=sv)
    return logits.astype(jnp.float32), new_cache
