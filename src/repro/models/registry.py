"""Family registry: maps ArchConfig.family -> model module.

Every module implements the same functional protocol (see transformer.py):
param_shapes / init_params / loss / prefill / init_cache / decode_step.

This registry covers the *LM substrate* only.  Engine-side CNNs resolve
elsewhere: hand-written builders in ``repro.core.graph.BUILDERS``, imported
models (ONNX / declarative JSON) via ``repro.frontend`` — with
``repro.frontend.resolve.resolve_net`` as the one lookup that accepts both.
"""

from __future__ import annotations

from repro.models import rwkv6, transformer, whisper, zamba

_REGISTRY = {
    "dense": transformer,
    "moe": transformer,
    "mla": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": zamba,
    "encdec": whisper,
}


def get(family: str):
    try:
        return _REGISTRY[family]
    except KeyError:
        raise ValueError(f"unknown model family {family!r}; "
                         f"known: {sorted(_REGISTRY)}") from None
