"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent decay.

Per head h with head dim D, per step t:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (state: D x D)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(decay_t)) produced by a LoRA MLP of the token-shifted input
(the data-dependent decay that distinguishes v6), plus token-shift mixing on
every projection and a squared-ReLU channel-mix FFN.

Training/prefill uses a chunked formulation: within a chunk the contribution is
a masked quadratic form; the D x D state is carried across chunks with a scan —
the same structure as Mamba-2's SSD (chunk = cfg.ssm_chunk).  Decode is O(1) in
context length: the whole ``long_500k`` story for this arch.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (ArchConfig, act_shard, init_from_shapes,
                                 rms_norm, sds, xent_loss)


def param_shapes(cfg: ArchConfig) -> Dict[str, Any]:
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    r = cfg.rwkv_lora
    pd = cfg.param_dtype
    return {
        "embed": sds((V, d), pd),
        "blocks": {
            "ln1": sds((L, d), pd), "ln2": sds((L, d), pd),
            # time-mix: token-shift mixing coefficients per stream (r,k,v,w,g)
            "mix": sds((L, 5, d), pd),
            "wr": sds((L, d, d), pd), "wk": sds((L, d, d), pd),
            "wv": sds((L, d, d), pd), "wg": sds((L, d, d), pd),
            "wo": sds((L, d, d), pd),
            # data-dependent decay LoRA: d -> r -> d
            "w_a": sds((L, d, r), pd), "w_b": sds((L, r, d), pd),
            "w_bias": sds((L, d), pd),
            "u": sds((L, d), pd),                      # per-channel bonus
            "ln_x": sds((L, d), pd),                   # group-norm surrogate
            # channel mix
            "cmix": sds((L, 2, d), pd),
            "ck": sds((L, d, cfg.d_ff), pd), "cv": sds((L, cfg.d_ff, d), pd),
            "cr": sds((L, d, d), pd),
        },
        "ln_f": sds((d,), pd),
        "head": sds((V, d), pd),
    }


def init_params(cfg: ArchConfig, key: jax.Array):
    p = init_from_shapes(param_shapes(cfg), key)
    b = p["blocks"]
    for k in ("ln1", "ln2", "ln_x"):
        b[k] = jnp.ones_like(b[k])
    p["ln_f"] = jnp.ones_like(p["ln_f"])
    b["mix"] = jnp.full_like(b["mix"], 0.5)
    b["cmix"] = jnp.full_like(b["cmix"], 0.5)
    b["w_bias"] = jnp.full_like(b["w_bias"], -4.0)   # slow decay at init
    return p


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Token shift: x (B,S,d) -> previous token (zero/state for t=0)."""
    prev = (jnp.zeros_like(x[:, :1]) if last is None
            else last[:, None].astype(x.dtype))
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, w_log, u, chunk: int, s0=None):
    """Chunked WKV: r,k,v (B,S,H,D); w_log (B,S,H,D) (log decay, negative).

    Returns (o (B,S,H,D), final state (B,H,D,D))."""
    b, s, H, D = r.shape
    Q = min(chunk, s)
    nc = s // Q
    rb, kb, vb = (t.reshape(b, nc, Q, H, D) for t in (r, k, v))
    wb = w_log.reshape(b, nc, Q, H, D)
    cs = jnp.cumsum(wb, axis=2)                               # (b,nc,Q,H,D)

    # intra-chunk: o_t += sum_{j<t} r_t ⊙ exp(cs_{t-1}-cs_j) k_j v_j + diag(u) term
    r_dec = rb * jnp.exp(cs - wb)                             # r_t exp(cs_{t-1})
    k_dec = kb * jnp.exp(-cs)                                 # k_j exp(-cs_j)
    att = jnp.einsum("bcqhd,bckhd->bchqk", r_dec, k_dec)      # (b,nc,H,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    att = jnp.where(mask[None, None, None], att, 0.0)
    o = jnp.einsum("bchqk,bckhe->bcqhe", att, vb)
    # bonus diagonal term: r_t ⊙ u ⊙ k_t v_t
    bonus = jnp.einsum("bcqhd,bcqhd->bcqh", rb, kb * u[None, None, None])
    o = o + bonus[..., None] * vb

    # chunk-final states and cross-chunk scan
    dec_to_end = jnp.exp(cs[:, :, -1:] - cs)                  # (b,nc,Q,H,D)
    st = jnp.einsum("bcqhd,bcqhe->bchde", kb * dec_to_end, vb)  # (b,nc,H,D,D)
    chunk_dec = jnp.exp(cs[:, :, -1])                         # (b,nc,H,D)

    def scan_fn(Sc, inp):
        sti, deci = inp
        return Sc * deci[..., None] + sti, Sc

    S_init = jnp.zeros((b, H, D, D), jnp.float32) if s0 is None else s0.astype(jnp.float32)
    S_last, S_prev = jax.lax.scan(
        scan_fn, S_init,
        (st.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_dec.transpose(1, 0, 2, 3).astype(jnp.float32)))
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)                  # (b,nc,H,D,D)

    o = o + jnp.einsum("bcqhd,bchde->bcqhe", r_dec.astype(jnp.float32),
                       S_prev).astype(o.dtype)
    return o.reshape(b, s, H, D), S_last


def _time_mix_forward(cfg, p, x, chunk, state=None):
    """Full-sequence time-mix. state: (last_x (B,d), S (B,H,D,D)) or None."""
    b, s, d = x.shape
    H = cfg.n_heads
    D = d // H
    last_x = None if state is None else state[0]
    xs = _shift(x, last_x)

    def mixed(i):
        m = p["mix"][i][None, None].astype(x.dtype)
        return x * m + xs * (1 - m)

    r = jnp.einsum("bsd,de->bse", mixed(0), p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", mixed(1), p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", mixed(2), p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,de->bse", mixed(3), p["wg"].astype(x.dtype))
    dd = jnp.einsum("bsd,dr->bsr", mixed(4), p["w_a"].astype(x.dtype))
    dd = jnp.einsum("bsr,rd->bsd", jnp.tanh(dd), p["w_b"].astype(x.dtype))
    w_log = -jnp.exp((p["w_bias"][None, None] + dd).astype(jnp.float32))  # < 0

    rh, kh, vh = (t.reshape(b, s, H, D) for t in (r, k, v))
    u = p["u"].reshape(H, D).astype(jnp.float32)
    o, S_last = _wkv_chunked(rh.astype(jnp.float32), kh.astype(jnp.float32),
                             vh.astype(jnp.float32),
                             w_log.reshape(b, s, H, D), u, chunk,
                             None if state is None else state[1])
    o = o.reshape(b, s, d).astype(x.dtype)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * jax.nn.silu(g)
    out = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return out, (x[:, -1], S_last)


def _channel_mix(cfg, p, x, last_x=None):
    xs = _shift(x, last_x)
    m0 = p["cmix"][0][None, None].astype(x.dtype)
    m1 = p["cmix"][1][None, None].astype(x.dtype)
    xk = x * m0 + xs * (1 - m0)
    xr = x * m1 + xs * (1 - m1)
    kk = jnp.einsum("bsd,df->bsf", xk, p["ck"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cr"].astype(x.dtype)))
    return rr * vv, x[:, -1]


def _block(cfg, p, x, chunk, state=None):
    tm_state = None if state is None else (state["tm_x"], state["S"])
    cm_last = None if state is None else state["cm_x"]
    a, tm_new = _time_mix_forward(cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps),
                                  chunk, tm_state)
    x = x + a
    c, cm_new = _channel_mix(cfg, p, rms_norm(x, p["ln2"], cfg.norm_eps), cm_last)
    x = x + c
    return x, {"tm_x": tm_new[0].astype(jnp.float32), "S": tm_new[1],
               "cm_x": cm_new.astype(jnp.float32)}


def _scan_blocks(cfg, params, x, collect_state=False, states=None):
    def body(xc, inp):
        xc = act_shard(xc, enabled=cfg.seq_parallel)
        if states is None:
            p_l = inp
            xo, st = _block(cfg, p_l, xc, cfg.ssm_chunk)
        else:
            p_l, st_l = inp
            xo, st = _block(cfg, p_l, xc, cfg.ssm_chunk, st_l)
        return xo, st if collect_state else 0

    body_fn = jax.checkpoint(body) if cfg.remat else body
    xs = params["blocks"] if states is None else (params["blocks"], states)
    return jax.lax.scan(body_fn, x, xs)


def loss(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x, _ = _scan_blocks(cfg, params, x)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    ce = xent_loss(x, params["head"], batch["labels"], cfg.loss_chunk)
    return ce, {"ce": ce}


def init_cache(cfg: ArchConfig, b: int, max_len: int, as_shapes: bool = False):
    """RWKV cache is O(1) in context: per-layer state only."""
    L, d, H = cfg.n_layers, cfg.d_model, cfg.n_heads
    D = d // H
    ct = jnp.float32
    shapes = {"tm_x": sds((L, b, d), ct), "S": sds((L, b, H, D, D), ct),
              "cm_x": sds((L, b, d), ct)}
    if as_shapes:
        return shapes
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def prefill(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x, states = _scan_blocks(cfg, params, x, collect_state=True)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    return logits.astype(jnp.float32), states


def decode_step(cfg: ArchConfig, params, cache, batch, pos):
    tokens = batch["tokens"]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]

    def body(xc, inp):
        p_l, st_l = inp
        xo, st = _block(cfg, p_l, xc, 1, st_l)
        return xo, st

    x, new_states = jax.lax.scan(body, x, (params["blocks"], cache))
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    return logits.astype(jnp.float32), new_states
