"""Shared model machinery: configs, norms, RoPE/M-RoPE, losses, init helpers.

Everything is pure-functional JAX (params as pytrees of stacked per-layer
arrays, ``lax.scan`` over the layer dimension) so the same code path lowers for
1-device smoke tests and 512-device pjit dry-runs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | mla | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    moe_chunk: int = 512         # seq chunk for dispatch (bounds dispatch tensor)
    moe_every: int = 1           # one MoE layer per N layers (Llama-4 style)
    n_shared_experts: int = 0    # always-active shared experts per MoE layer
    # MLA (DeepSeek-V2 style; MiniCPM3 values by default when family == "mla")
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # SSM (Mamba2 SSD)
    ssm_state: int = 64
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (Zamba2-style): one shared attention block every N ssm layers
    attn_every: int = 6
    # RWKV6
    rwkv_lora: int = 64
    # enc-dec (Whisper-style)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    dec_len_ratio: int = 8       # decoder length = seq_len // ratio for train/prefill
    # VLM (Qwen2-VL M-RoPE)
    mrope_sections: Tuple[int, ...] = ()
    # generic
    seq_parallel: bool = True   # Megatron-SP activation sharding between blocks
    opt_state_bf16: bool = False # bf16 Adam moments (halves optimizer memory)
    grad_accum: int = 8          # microbatch count for gradient accumulation
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512        # seq chunk for the vocab-sharded cross entropy
    use_scan: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def num_params(self) -> int:
        from repro.models import registry
        shapes = registry.get(self.family).param_shapes(self)
        return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))

    def active_params(self) -> int:
        """Params touched per token (MoE: non-expert + shared + top_k experts)."""
        if self.n_experts == 0:
            return self.num_params()
        full = self.num_params()
        n_moe_layers = self.n_layers // max(self.moe_every, 1)
        expert_block = 3 * self.d_model * self.d_ff * n_moe_layers
        all_experts = expert_block * self.n_experts
        return full - all_experts + expert_block * self.top_k


# ---------------------------------------------------------------------------
# Elementary layers
# ---------------------------------------------------------------------------
def act_shard(x: jax.Array, batch_axis: int = 0, seq_axis: int = 1,
              enabled: bool = True) -> jax.Array:
    """Megatron-style sequence-parallel activation constraint between blocks.

    When lowering under a mesh context, constrain a (B, S, d) activation to
    P(dp, 'model', None): batch over the data axes, SEQUENCE over the model
    axis.  GSPMD then turns each block's TP all-reduce into reduce-scatter +
    all-gather and the saved scan carries shrink by the TP degree — the fix
    that makes train_4k fit HBM (see EXPERIMENTS.md §Perf).  No-op without a
    mesh context (single-device smoke tests) or when dims don't divide.
    """
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            return x
    except Exception:                                  # pragma: no cover
        return x
    from jax.sharding import PartitionSpec as P
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    spec = [None] * x.ndim
    dpsize = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if dp and x.shape[batch_axis] % dpsize == 0 and x.shape[batch_axis] > 1:
        spec[batch_axis] = dp if len(dp) > 1 else dp[0]
    if ("model" in names and x.shape[seq_axis] > 1
            and x.shape[seq_axis] % mesh.shape["model"] == 0):
        spec[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def rope_freqs(d: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x (..., S, D); pos (..., S) int32. Rotates pairs (even,odd)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs          # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_mrope(x: jax.Array, pos3: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL M-RoPE: x (B, H, S, D); pos3 (3, B, S) (temporal, h, w).

    ``sections`` partitions D/2 rotary frequencies among the three position
    streams (e.g. (16, 24, 24) for D=128).
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    ang = pos3[..., None].astype(jnp.float32) * freqs         # (3, B, S, D/2)
    sec_id = np.repeat(np.arange(len(sections)), np.array(sections))  # (D/2,)
    onehot = jnp.asarray(sec_id[None, :] == np.arange(len(sections))[:, None],
                         jnp.float32)                         # (3, D/2)
    ang = jnp.einsum("kbsf,kf->bsf", ang, onehot)             # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]   # (B, 1, S, D/2)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def swiglu(x: jax.Array, wg: jax.Array, wu: jax.Array, wd: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, wu.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, wd.astype(x.dtype))


# ---------------------------------------------------------------------------
# Vocab-sharded, sequence-chunked cross entropy
# ---------------------------------------------------------------------------
def xent_loss(x: jax.Array, head: jax.Array, labels: jax.Array,
              chunk: int = 512) -> jax.Array:
    """Mean CE over (B, S) without materialising full (B, S, V) logits.

    ``head`` (V, d) is vocab-sharded over 'model'; the max/logsumexp reductions
    over V lower to partial reductions + all-reduce under GSPMD.  The sequence
    is processed in chunks via scan so the live logits tensor is (B, chunk, V).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    n = s // chunk
    xc = x[:, :n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels[:, :n * chunk].reshape(b, n, chunk).swapaxes(0, 1)

    v = head.shape[0]

    def step(tot, xl):
        xi, li = xl
        logits = jnp.einsum("bcd,vd->bcv", xi, head.astype(xi.dtype))
        logits = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
        # one-hot contraction instead of take_along_axis: stays vocab-sharded
        # under GSPMD (Megatron-style vocab-parallel CE)
        onehot = jax.nn.one_hot(li, v, dtype=logits.dtype)
        gold = jnp.einsum("bcv,bcv->bc", logits, onehot)
        return tot + jnp.sum(lse - gold), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc))
    return tot / (b * n * chunk)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------
def init_from_shapes(shapes, key: jax.Array, scale: float = 0.02):
    """Normal(0, scale) init for every leaf (fan-in scaling applied by callers
    that need it); deterministic per-leaf fold-in by flattened index."""
    leaves, treedef = jax.tree.flatten(shapes)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(jax.random.normal(k, leaf.shape, leaf.dtype) * scale)
        else:
            out.append(jnp.zeros(leaf.shape, leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)
