"""Unified decoder-only transformer: dense / MoE / MLA / VLM(M-RoPE) families.

Pure-functional, scan-over-layers, remat-able; one code path lowers for the
1-device smoke tests, the 512-device dry-run, and the serving executor.

MoE archs support *interleaved* expert layers (``cfg.moe_every``: one MoE layer
per ``moe_every`` layers, rest dense — Llama-4 style) plus optional
always-active shared experts.  Layers are scanned in SUPERBLOCKS of
``moe_every`` layers so the stacked-params scan stays uniform.

Interface (shared by every family module in models/):
    param_shapes(cfg)                  -> pytree of ShapeDtypeStruct
    init_params(cfg, key)              -> pytree of arrays
    loss(cfg, params, batch)           -> (scalar loss, metrics dict)
    prefill(cfg, params, batch)        -> (last-token logits, cache)
    init_cache(cfg, batch_size, max_len) -> cache pytree
    decode_step(cfg, params, cache, batch, pos) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models.common import (ArchConfig, act_shard, apply_mrope,
                                 apply_rope, init_from_shapes, rms_norm, sds,
                                 swiglu, xent_loss)


# ---------------------------------------------------------------------------
# Parameter shapes
# ---------------------------------------------------------------------------
def _attn_shapes(cfg: ArchConfig, lead: Tuple[int, ...]) -> Dict[str, Any]:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    pd = cfg.param_dtype
    if cfg.family == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return {
            "wq_a": sds(lead + (d, cfg.q_lora_rank), pd),
            "q_ln": sds(lead + (cfg.q_lora_rank,), pd),
            "wq_b": sds(lead + (cfg.q_lora_rank, H * qk), pd),
            "wkv_a": sds(lead + (d, cfg.kv_lora_rank + cfg.qk_rope_dim), pd),
            "kv_ln": sds(lead + (cfg.kv_lora_rank,), pd),
            "wkv_b": sds(lead + (cfg.kv_lora_rank,
                                 H * (cfg.qk_nope_dim + cfg.v_head_dim)), pd),
            "wo": sds(lead + (H * cfg.v_head_dim, d), pd),
        }
    return {
        "wq": sds(lead + (d, H * Dh), pd),
        "wk": sds(lead + (d, Hkv * Dh), pd),
        "wv": sds(lead + (d, Hkv * Dh), pd),
        "wo": sds(lead + (H * Dh, d), pd),
    }


def _dense_mlp_shapes(cfg: ArchConfig, lead) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    return {"wg": sds(lead + (d, f), pd), "wu": sds(lead + (d, f), pd),
            "wd": sds(lead + (f, d), pd)}


def _moe_mlp_shapes(cfg: ArchConfig, lead) -> Dict[str, Any]:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pd = cfg.param_dtype
    out = {
        "router": sds(lead + (d, E), pd),
        "wg": sds(lead + (E, d, f), pd),
        "wu": sds(lead + (E, d, f), pd),
        "wd": sds(lead + (E, f, d), pd),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        out["wg_s"] = sds(lead + (d, fs), pd)
        out["wu_s"] = sds(lead + (d, fs), pd)
        out["wd_s"] = sds(lead + (fs, d), pd)
    return out


def _block_shapes(cfg: ArchConfig, lead, moe: bool) -> Dict[str, Any]:
    d = cfg.d_model
    pd = cfg.param_dtype
    return {
        "ln1": sds(lead + (d,), pd), "ln2": sds(lead + (d,), pd),
        "attn": _attn_shapes(cfg, lead),
        "mlp": _moe_mlp_shapes(cfg, lead) if moe else _dense_mlp_shapes(cfg, lead),
    }


def _n_groups(cfg: ArchConfig) -> int:
    return cfg.n_layers // max(cfg.moe_every, 1)


def param_shapes(cfg: ArchConfig) -> Dict[str, Any]:
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    pd = cfg.param_dtype
    if cfg.n_experts and cfg.moe_every > 1:
        G = _n_groups(cfg)
        blocks = {"moe": _block_shapes(cfg, (G,), moe=True),
                  "dense": _block_shapes(cfg, (G, cfg.moe_every - 1), moe=False)}
    else:
        blocks = _block_shapes(cfg, (L,), moe=bool(cfg.n_experts))
    return {
        "embed": sds((V, d), pd),
        "blocks": blocks,
        "ln_f": sds((d,), pd),
        "head": sds((V, d), pd),
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    params = init_from_shapes(param_shapes(cfg), key, scale=0.02)

    def fix_norms(b):
        b["ln1"] = jnp.ones_like(b["ln1"])
        b["ln2"] = jnp.ones_like(b["ln2"])
        if cfg.family == "mla":
            b["attn"]["q_ln"] = jnp.ones_like(b["attn"]["q_ln"])
            b["attn"]["kv_ln"] = jnp.ones_like(b["attn"]["kv_ln"])

    if cfg.n_experts and cfg.moe_every > 1:
        fix_norms(params["blocks"]["moe"])
        fix_norms(params["blocks"]["dense"])
    else:
        fix_norms(params["blocks"])
    params["ln_f"] = jnp.ones_like(params["ln_f"])
    return params


# ---------------------------------------------------------------------------
# MoE FFN (token-choice top-k, capacity dispatch, seq-chunked)
# ---------------------------------------------------------------------------
def moe_capacity(cfg: ArchConfig, chunk: int) -> int:
    return max(4, int(np.ceil(chunk * cfg.top_k * cfg.capacity_factor / cfg.n_experts)))


def moe_chunk_size(cfg: ArchConfig, s: int) -> int:
    """Bound the dispatch tensor: chunk*k slots <= 1024."""
    c = min(cfg.moe_chunk, max(1, 1024 // max(cfg.top_k, 1)))
    c = min(c, s)
    while s % c:
        c -= 1
    return c


def moe_ffn(cfg: ArchConfig, p: Dict[str, Any], x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux load-balance loss). Experts sharded on 'model'."""
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    chunk = moe_chunk_size(cfg, s)
    C = moe_capacity(cfg, chunk)
    n = s // chunk
    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)              # (n,b,c,d)

    def step(aux, xi):
        logits = jnp.einsum("bcd,de->bce", xi, p["router"].astype(xi.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gates, idx = jax.lax.top_k(probs, k)                    # (b,c,k)
        gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)).astype(xi.dtype)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # (b,c,k,E)
        flat = onehot.reshape(b, chunk * k, E)
        pos_in_e = jnp.cumsum(flat, axis=1) - flat              # (b,t,E)
        slot = jnp.sum(pos_in_e * flat, axis=-1)                # (b,t)
        keep = (slot < C) & (flat.sum(-1) > 0)
        disp = (jax.nn.one_hot(slot, C, dtype=xi.dtype)
                * keep[..., None].astype(xi.dtype))             # (b,t,C)
        disp = jnp.einsum("btc,bte->btec", disp, flat.astype(xi.dtype))  # (b,t,E,C)
        xslots = jnp.repeat(xi, k, axis=1)                      # (b,t,d)
        x_e = jnp.einsum("btec,btd->becd", disp, xslots)        # (b,E,C,d)
        g = jnp.einsum("becd,edf->becf", x_e, p["wg"].astype(xi.dtype))
        u = jnp.einsum("becd,edf->becf", x_e, p["wu"].astype(xi.dtype))
        y_e = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                         p["wd"].astype(xi.dtype))              # (b,E,C,d)
        gate_slot = gates.reshape(b, chunk * k)
        comb = disp * gate_slot[..., None, None]
        y = jnp.einsum("btec,becd->btd", comb, y_e)             # (b,t,d)
        y = y.reshape(b, chunk, k, d).sum(2)
        # Switch-style load-balance aux
        f_e = jnp.mean(onehot.sum(2).astype(jnp.float32), axis=(0, 1))   # (E,)
        p_e = jnp.mean(probs, axis=(0, 1))
        aux = aux + E * jnp.sum(f_e * p_e)
        return aux, y

    aux, ys = jax.lax.scan(step, jnp.zeros((), jnp.float32), xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d)
    if "wg_s" in p:                                             # shared expert(s)
        y = y + swiglu(x, p["wg_s"], p["wu_s"], p["wd_s"])
    return y, aux / n


def _mlp(cfg: ArchConfig, p_mlp, x):
    if "router" in p_mlp:
        return moe_ffn(cfg, p_mlp, x)
    return swiglu(x, p_mlp["wg"], p_mlp["wu"], p_mlp["wd"]), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------
def _gqa_qkv(cfg: ArchConfig, p, h, pos, pos3):
    b, s, d = h.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = jnp.einsum("bsd,dx->bsx", h, p["wq"].astype(h.dtype)).reshape(b, s, H, Dh)
    kk = jnp.einsum("bsd,dx->bsx", h, p["wk"].astype(h.dtype)).reshape(b, s, Hkv, Dh)
    vv = jnp.einsum("bsd,dx->bsx", h, p["wv"].astype(h.dtype)).reshape(b, s, Hkv, Dh)
    q, kk, vv = (t.transpose(0, 2, 1, 3) for t in (q, kk, vv))   # (B,H,S,D)
    if cfg.family == "vlm" and pos3 is not None:
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        kk = apply_mrope(kk, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        kk = apply_rope(kk, pos[:, None], cfg.rope_theta)
    return q, kk, vv


def _mla_q(cfg, p, h, pos):
    b, s, _ = h.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", h, p["wq_a"].astype(h.dtype)),
                  p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rx->bsx", cq, p["wq_b"].astype(h.dtype))
    q = q.reshape(b, s, H, nope + rope).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg, p, h, pos):
    ckv_r = jnp.einsum("bsd,dr->bsr", h, p["wkv_a"].astype(h.dtype))
    ckv = rms_norm(ckv_r[..., :cfg.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_r[..., cfg.kv_lora_rank:]                       # (B,S,rope)
    k_rope = apply_rope(k_rope[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    return ckv, k_rope


def block_forward(cfg: ArchConfig, p, x, pos, pos3=None, causal=True):
    """Full-sequence block (train/prefill). Returns (x, aux, cache_kv)."""
    b, s, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.family == "mla":
        nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        H = cfg.n_heads
        q_nope, q_rope = _mla_q(cfg, p["attn"], h, pos)
        ckv, k_rope = _mla_latent(cfg, p["attn"], h, pos)
        kv = jnp.einsum("bsr,rx->bsx", ckv, p["attn"]["wkv_b"].astype(h.dtype))
        kv = kv.reshape(b, s, H, nope + vdim).transpose(0, 2, 1, 3)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        q = jnp.concatenate([q_nope, q_rope], -1)
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope[:, None], (b, H, s, rope))], -1)
        o = attn.flash_mha(q, k, v, causal=causal)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, H * vdim)
        cache = (ckv, k_rope)
    else:
        q, k, v = _gqa_qkv(cfg, p["attn"], h, pos, pos3)
        o = attn.flash_mha(q, k, v, causal=causal)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
        cache = (k.transpose(0, 2, 1, 3).reshape(b, s, -1),
                 v.transpose(0, 2, 1, 3).reshape(b, s, -1))
    x = x + jnp.einsum("bsx,xd->bsd", o, p["attn"]["wo"].astype(x.dtype))
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, aux = _mlp(cfg, p["mlp"], h2)
    return x + y, jnp.asarray(aux, jnp.float32), cache


def block_decode(cfg: ArchConfig, p, x, pos, cache, pos3=None):
    """One-token block. cache: family-specific per-layer tensors."""
    b, _, d = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    posv = jnp.full((b, 1), pos, jnp.int32)
    if cfg.family == "mla":
        nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        H, rank = cfg.n_heads, cfg.kv_lora_rank
        q_nope, q_rope = _mla_q(cfg, p["attn"], h, posv)         # (B,H,1,*)
        ckv_new, kr_new = _mla_latent(cfg, p["attn"], h, posv)   # (B,1,rank),(B,1,rope)
        ckv_c, kr_c = cache
        ckv_c = jax.lax.dynamic_update_slice(ckv_c, ckv_new.astype(ckv_c.dtype),
                                             (0, pos, 0))
        kr_c = jax.lax.dynamic_update_slice(kr_c, kr_new.astype(kr_c.dtype),
                                            (0, pos, 0))
        wkv_b = p["attn"]["wkv_b"].reshape(rank, H, nope + vdim)
        w_k, w_v = wkv_b[..., :nope], wkv_b[..., nope:]
        # absorbed decode: score via latent space
        q_abs = jnp.einsum("bhod,rhd->bhor", q_nope, w_k.astype(h.dtype))
        s_lat = jnp.einsum("bhor,bsr->bhos", q_abs, ckv_c.astype(h.dtype))
        s_rope = jnp.einsum("bhod,bsd->bhos", q_rope, kr_c.astype(h.dtype))
        s = (s_lat + s_rope).astype(jnp.float32) * ((nope + rope) ** -0.5)
        idx = jnp.arange(ckv_c.shape[1])[None, None, None, :]
        s = jnp.where(idx <= pos, s, -1e30)
        pattn = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhos,bsr->bhor", pattn.astype(h.dtype), ckv_c.astype(h.dtype))
        o = jnp.einsum("bhor,rhv->bhov", o_lat, w_v.astype(h.dtype))
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, H * vdim)
        new_cache = (ckv_c, kr_c)
    else:
        H, Hkv, Dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
        if cfg.family == "vlm" and pos3 is not None:
            q, k_new, v_new = _gqa_qkv(cfg, p["attn"], h, posv, pos3)
        else:
            q, k_new, v_new = _gqa_qkv(cfg, p["attn"], h, posv, None)
        k_c, v_c = cache                                         # (B,Hkv,Smax,Dh)
        mesh = attn.use_sp_decode(b, Hkv, k_c.shape[2])
        if mesh is not None:
            # sequence-sharded cache: fused local write + distributed
            # flash-decode (see attention.decode_attn_sp)
            o, k_c, v_c = attn.decode_attn_sp(q, k_c, v_c, pos, mesh,
                                              k_new=k_new, v_new=v_new)
        else:
            k_c = attn.update_cache(k_c, k_new, pos)
            v_c = attn.update_cache(v_c, v_new, pos)
            o = attn.decode_attn(q, k_c, v_c, pos)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, H * Dh)
        new_cache = (k_c, v_c)
    x = x + jnp.einsum("bsx,xd->bsd", o, p["attn"]["wo"].astype(x.dtype))
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    y, _ = _mlp(cfg, p["mlp"], h2)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Model-level: forward / loss / prefill / decode
# ---------------------------------------------------------------------------
def _interleaved(cfg: ArchConfig) -> bool:
    return bool(cfg.n_experts) and cfg.moe_every > 1


def _scan_blocks(cfg: ArchConfig, params, x, pos, pos3, causal=True,
                 collect_cache=False):
    blocks = params["blocks"]

    if _interleaved(cfg):
        def body(carry, p_g):
            xc, aux = carry
            xc = act_shard(xc, enabled=cfg.seq_parallel)
            d_caches = []
            for j in range(cfg.moe_every - 1):
                p_l = jax.tree.map(lambda a: a[j], p_g["dense"])
                xc, a, cache = block_forward(cfg, p_l, xc, pos, pos3, causal)
                aux = aux + a
                d_caches.append(cache)
            xc, a, m_cache = block_forward(cfg, p_g["moe"], xc, pos, pos3, causal)
            aux = aux + a
            ys = 0
            if collect_cache:
                dk = jnp.stack([c[0] for c in d_caches])
                dv = jnp.stack([c[1] for c in d_caches])
                ys = (dk, dv, m_cache[0], m_cache[1])
            return (xc, aux), ys
    else:
        def body(carry, p_l):
            xc, aux = carry
            xc = act_shard(xc, enabled=cfg.seq_parallel)
            xo, a, cache = block_forward(cfg, p_l, xc, pos, pos3, causal)
            return (xo, aux + a), (cache if collect_cache else 0)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                                    blocks)
    return x, aux, caches


def forward(cfg: ArchConfig, params, batch, causal=True):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    pos = batch.get("positions", jnp.broadcast_to(jnp.arange(s)[None], (b, s)))
    pos3 = batch.get("pos3")
    x, aux, _ = _scan_blocks(cfg, params, x, pos, pos3, causal)
    return rms_norm(x, params["ln_f"], cfg.norm_eps), aux


def loss(cfg: ArchConfig, params, batch):
    x, aux = forward(cfg, params, batch)
    ce = xent_loss(x, params["head"], batch["labels"], cfg.loss_chunk)
    metrics = {"ce": ce, "aux": aux}
    return ce + 0.01 * aux, metrics


def _split_heads(cfg, kv_flat, b, s):
    Hkv, Dh = cfg.n_kv, cfg.head_dim
    lead = kv_flat.shape[:-3]
    return kv_flat.reshape(lead + (b, s, Hkv, Dh)).swapaxes(-2, -3)


def prefill(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    pos = batch.get("positions", jnp.broadcast_to(jnp.arange(s)[None], (b, s)))
    pos3 = batch.get("pos3")
    x, _, caches = _scan_blocks(cfg, params, x, pos, pos3, causal=True,
                                collect_cache=True)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    if _interleaved(cfg):
        dk, dv, mk, mv = caches
        cache = {"dk": _split_heads(cfg, dk, b, s), "dv": _split_heads(cfg, dv, b, s),
                 "mk": _split_heads(cfg, mk, b, s), "mv": _split_heads(cfg, mv, b, s)}
    elif cfg.family == "mla":
        ckv, kr = caches                      # (L,B,S,rank), (L,B,S,rope)
        cache = {"ckv": ckv, "kr": kr}
    else:
        k, v = caches                         # (L,B,S,Hkv*Dh)
        cache = {"k": _split_heads(cfg, k, b, s), "v": _split_heads(cfg, v, b, s)}
    return logits.astype(jnp.float32), cache


def init_cache(cfg: ArchConfig, b: int, max_len: int, as_shapes: bool = False):
    L = cfg.n_layers
    ct = cfg.compute_dtype
    Hkv, Dh = cfg.n_kv, cfg.head_dim
    if _interleaved(cfg):
        G, me = _n_groups(cfg), cfg.moe_every
        shapes = {"dk": sds((G, me - 1, b, Hkv, max_len, Dh), ct),
                  "dv": sds((G, me - 1, b, Hkv, max_len, Dh), ct),
                  "mk": sds((G, b, Hkv, max_len, Dh), ct),
                  "mv": sds((G, b, Hkv, max_len, Dh), ct)}
    elif cfg.family == "mla":
        shapes = {"ckv": sds((L, b, max_len, cfg.kv_lora_rank), ct),
                  "kr": sds((L, b, max_len, cfg.qk_rope_dim), ct)}
    else:
        shapes = {"k": sds((L, b, Hkv, max_len, Dh), ct),
                  "v": sds((L, b, Hkv, max_len, Dh), ct)}
    if as_shapes:
        return shapes
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def decode_step(cfg: ArchConfig, params, cache, batch, pos):
    """batch["tokens"]: (B,1); pos: scalar int32. Returns (logits (B,V), cache)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    pos3 = batch.get("pos3")
    blocks = params["blocks"]
    if _interleaved(cfg):
        xs = (blocks, cache["dk"], cache["dv"], cache["mk"], cache["mv"])

        def body(xc, inp):
            p_g, dk, dv, mk, mv = inp
            dk2, dv2 = [], []
            for j in range(cfg.moe_every - 1):
                p_l = jax.tree.map(lambda a: a[j], p_g["dense"])
                xc, (k2, v2) = block_decode(cfg, p_l, xc, pos, (dk[j], dv[j]), pos3)
                dk2.append(k2)
                dv2.append(v2)
            xc, (mk2, mv2) = block_decode(cfg, p_g["moe"], xc, pos, (mk, mv), pos3)
            return xc, (jnp.stack(dk2), jnp.stack(dv2), mk2, mv2)

        x, (dk, dv, mk, mv) = jax.lax.scan(body, x, xs)
        new_cache = {"dk": dk, "dv": dv, "mk": mk, "mv": mv}
    elif cfg.family == "mla":
        xs = (blocks, cache["ckv"], cache["kr"])

        def body(xc, p_c):
            p_l, ckv_l, kr_l = p_c
            xo, (ckv2, kr2) = block_decode(cfg, p_l, xc, pos, (ckv_l, kr_l), pos3)
            return xo, (ckv2, kr2)

        x, (ckv, kr) = jax.lax.scan(body, x, xs)
        new_cache = {"ckv": ckv, "kr": kr}
    else:
        xs = (blocks, cache["k"], cache["v"])

        def body(xc, p_c):
            p_l, k_l, v_l = p_c
            xo, (k2, v2) = block_decode(cfg, p_l, xc, pos, (k_l, v_l), pos3)
            return xo, (k2, v2)

        x, (k, v) = jax.lax.scan(body, x, xs)
        new_cache = {"k": k, "v": v}
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    return logits.astype(jnp.float32), new_cache
