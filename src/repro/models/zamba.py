"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block (arXiv:2411.15242).

``cfg.n_layers`` Mamba2 layers; after every ``cfg.attn_every`` of them, a single
*shared* transformer block (same weights each invocation — Zamba's parameter-
efficiency trick, and a natural fit for a command-stream engine that re-invokes
one CONV unit across layers, cf. DESIGN.md §4).  Each invocation has its own KV
cache segment (same weights, different activations).

Simplifications vs the released model (documented in DESIGN.md): plain residual
instead of input-concat re-projection, no per-invocation LoRA on the shared
block.  Shapes and compute/memory scaling match the assigned config.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ssm
from repro.models.common import (ArchConfig, act_shard, apply_rope,
                                 init_from_shapes, rms_norm, sds, swiglu,
                                 xent_loss)


def n_attn_calls(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def param_shapes(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    pd = cfg.param_dtype
    return {
        "embed": sds((V, d), pd),
        "mamba": ssm.ssm_param_shapes(cfg, cfg.n_layers),
        "shared_attn": {
            "ln1": sds((d,), pd), "ln2": sds((d,), pd),
            "wq": sds((d, H * Dh), pd), "wk": sds((d, Hkv * Dh), pd),
            "wv": sds((d, Hkv * Dh), pd), "wo": sds((H * Dh, d), pd),
            "wg": sds((d, cfg.d_ff), pd), "wu": sds((d, cfg.d_ff), pd),
            "wd": sds((cfg.d_ff, d), pd),
        },
        "ln_f": sds((d,), pd),
        "head": sds((V, d), pd),
    }


def init_params(cfg: ArchConfig, key: jax.Array):
    p = init_from_shapes(param_shapes(cfg), key)
    p["mamba"]["ln"] = jnp.ones_like(p["mamba"]["ln"])
    p["mamba"]["norm"] = jnp.ones_like(p["mamba"]["norm"])
    p["mamba"]["dt_bias"] = jnp.full_like(p["mamba"]["dt_bias"], 0.5)
    p["mamba"]["A_log"] = jnp.zeros_like(p["mamba"]["A_log"])
    p["mamba"]["D"] = jnp.ones_like(p["mamba"]["D"])
    p["shared_attn"]["ln1"] = jnp.ones_like(p["shared_attn"]["ln1"])
    p["shared_attn"]["ln2"] = jnp.ones_like(p["shared_attn"]["ln2"])
    p["ln_f"] = jnp.ones_like(p["ln_f"])
    return p


def _shared_attn_forward(cfg, p, x, pos, cache=None, pos_scalar=None):
    """Shared transformer block; full-seq (cache=None) or decode (cache given)."""
    b = x.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    s = h.shape[1]
    q = jnp.einsum("bsd,dx->bsx", h, p["wq"].astype(h.dtype)).reshape(b, s, H, Dh)
    k = jnp.einsum("bsd,dx->bsx", h, p["wk"].astype(h.dtype)).reshape(b, s, Hkv, Dh)
    v = jnp.einsum("bsd,dx->bsx", h, p["wv"].astype(h.dtype)).reshape(b, s, Hkv, Dh)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    if cache is None:
        o = attn_lib.flash_mha(q, k, v, causal=True)
        new_cache = (k, v)
    else:
        k_c, v_c = cache
        k_c = attn_lib.update_cache(k_c, k, pos_scalar)
        v_c = attn_lib.update_cache(v_c, v, pos_scalar)
        o = attn_lib.decode_attn(q, k_c, v_c, pos_scalar)
        new_cache = (k_c, v_c)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, H * Dh)
    x = x + jnp.einsum("bsx,xd->bsd", o, p["wo"].astype(x.dtype))
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(h2, p["wg"], p["wu"], p["wd"])
    return x, new_cache


def _layer_param(params, i):
    return jax.tree.map(lambda a: a[i], params["mamba"])


def _backbone(cfg, params, x, pos, caches=None, pos_scalar=None,
              collect_cache=False):
    """Interleave mamba layers with shared-attn invocations (python loop: the
    layer pattern is heterogeneous; n_layers is small enough to unroll)."""
    kv_out = []
    states_out = []
    a_idx = 0
    for i in range(cfg.n_layers):
        x = act_shard(x, enabled=cfg.seq_parallel)
        if caches is None:
            if collect_cache:
                x, st = ssm.mamba_block_forward(cfg, _layer_param(params, i), x,
                                                return_state=True)
                states_out.append(st)
            else:
                x = ssm.mamba_block_forward(cfg, _layer_param(params, i), x)
        else:
            conv_st = caches["conv"][i]
            ssm_st = caches["ssm"][i]
            x, (conv2, ssm2) = ssm.mamba_block_decode(
                cfg, _layer_param(params, i), x, conv_st, ssm_st)
            states_out.append((conv2, ssm2))
        if (i + 1) % cfg.attn_every == 0:
            if caches is None:
                x, kv = _shared_attn_forward(cfg, params["shared_attn"], x, pos)
                kv_out.append(kv)
            else:
                kv = (caches["k"][a_idx], caches["v"][a_idx])
                x, kv2 = _shared_attn_forward(cfg, params["shared_attn"], x, pos,
                                              kv, pos_scalar)
                kv_out.append(kv2)
            a_idx += 1
    return x, kv_out, states_out


def loss(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, _, _ = _backbone(cfg, params, x, pos)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    ce = xent_loss(x, params["head"], batch["labels"], cfg.loss_chunk)
    return ce, {"ce": ce}


def init_cache(cfg: ArchConfig, b: int, max_len: int, as_shapes: bool = False):
    Hkv, Dh = cfg.n_kv, cfg.head_dim
    A = n_attn_calls(cfg)
    L = cfg.n_layers
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    ct = cfg.compute_dtype
    shapes = {
        "k": sds((A, b, Hkv, max_len, Dh), ct),
        "v": sds((A, b, Hkv, max_len, Dh), ct),
        "conv": sds((L, b, cfg.ssm_conv - 1, conv_dim), ct),
        "ssm": sds((L, b, cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
    }
    if as_shapes:
        return shapes
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)


def prefill(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, kvs, states = _backbone(cfg, params, x, pos, collect_cache=True)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    cache = {
        "k": jnp.stack([kv[0] for kv in kvs]),
        "v": jnp.stack([kv[1] for kv in kvs]),
        "conv": jnp.stack([st[0] for st in states]).astype(cfg.compute_dtype),
        "ssm": jnp.stack([st[1] for st in states]).astype(jnp.float32),
    }
    return logits.astype(jnp.float32), cache


def decode_step(cfg: ArchConfig, params, cache, batch, pos):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    posv = jnp.full((b, 1), pos, jnp.int32)
    x, kvs, states = _backbone(cfg, params, x, posv, caches=cache, pos_scalar=pos)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["head"].astype(x.dtype))
    new_cache = {
        "k": jnp.stack([kv[0] for kv in kvs]),
        "v": jnp.stack([kv[1] for kv in kvs]),
        "conv": jnp.stack([st[0] for st in states]),
        "ssm": jnp.stack([st[1] for st in states]),
    }
    return logits.astype(jnp.float32), new_cache
