"""Mamba2 (SSD) blocks — the state-space substrate for zamba2-1.2b.

Chunked SSD algorithm (Mamba-2, arXiv:2405.21060 §6): the sequence is split
into chunks; within a chunk the recurrence is computed as a masked quadratic
form (MXU-friendly), and chunk-final states are propagated with a short scan —
O(S·Q) work with static shapes, so it lowers cleanly through pjit and has an
O(1)-in-context decode step (the whole point of the ``long_500k`` cells).

Single-group B/C (n_groups=1), scalar-per-head A (Mamba-2 simplification).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, rms_norm, sds


def ssm_param_shapes(cfg: ArchConfig, n_layers: int) -> Dict[str, Any]:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.n_ssm_heads
    L = n_layers
    pd = cfg.param_dtype
    # in_proj emits [z (di), x (di), B (N), C (N), dt (H)]
    return {
        "ln": sds((L, d), pd),
        "in_proj": sds((L, d, 2 * di + 2 * N + H), pd),
        "conv_w": sds((L, di + 2 * N, cfg.ssm_conv), pd),   # depthwise causal conv
        "conv_b": sds((L, di + 2 * N), pd),
        "A_log": sds((L, H), pd),
        "D": sds((L, H), pd),
        "dt_bias": sds((L, H), pd),
        "norm": sds((L, di), pd),                           # gated RMSNorm
        "out_proj": sds((L, di, d), pd),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (C,K) -> (B,S,C)."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]] * w[None, None, :, i]
    return out + b[None, None, :]


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: returns (..., Q, Q) with out[..,i,j] = sum_{j<k<=i} x[..,k]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_forward(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int, h0: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x (b,S,H,P); dt (b,S,H) (softplus'ed); A (H,) negative; B,C (b,S,N).
    Returns (y (b,S,H,P), final state (b,H,P,N)).
    """
    b, s, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, s)
    nc = s // Q
    xb = x.reshape(b, nc, Q, H, P)
    dtb = dt.reshape(b, nc, Q, H)
    Bb = B.reshape(b, nc, Q, N)
    Cb = C.reshape(b, nc, Q, N)
    dA = dtb * A[None, None, None, :]                       # (b,nc,Q,H) log-decay
    dA_cs = jnp.cumsum(dA, axis=2)                          # within-chunk cumsum

    # 1) intra-chunk (diagonal block): y = (L ∘ (C B^T)) (dt x)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (b,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cb, Bb)          # (b,nc,Q,Q)
    dtx = xb * dtb[..., None]                               # (b,nc,Q,H,P)
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp",
                        Lmat, scores, dtx)

    # 2) chunk-final states: S_c = sum_k exp(dA_cs[end]-dA_cs[k]) B_k (dt x)_k
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (b,nc,Q,H)
    states = jnp.einsum("bckn,bckh,bckhp->bchnp", Bb, decay_to_end, dtx)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (b,nc,H)

    def scan_fn(h, inp):
        st, dec = inp                                       # (b,H,N,P), (b,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h

    h_init = (jnp.zeros((b, H, N, P), jnp.float32) if h0 is None
              else h0.astype(jnp.float32).transpose(0, 1, 3, 2))
    _, h_prev = jax.lax.scan(scan_fn,
                             h_init,
                             (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
                              chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    h_last, _ = scan_fn(h_prev[-1],
                        (states.transpose(1, 0, 2, 3, 4)[-1].astype(jnp.float32),
                         chunk_decay.transpose(1, 0, 2)[-1].astype(jnp.float32)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                # (b,nc,H,N,P)

    # 4) chunk-start contribution: y += C_t exp(dA_cs[t]) h_prev
    in_decay = jnp.exp(dA_cs)                               # (b,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cb, in_decay,
                       h_prev.astype(Cb.dtype))
    y = (y_diag + y_off).reshape(b, s, H, P)
    return y.astype(x.dtype), h_last.transpose(0, 1, 3, 2).astype(x.dtype)


def mamba_block_forward(cfg: ArchConfig, p, x, conv_state=None, ssm_state=None,
                        return_state: bool = False):
    """Full-sequence Mamba2 block. x (B,S,d) -> (B,S,d) [+ states]."""
    b, s, d = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_headdim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xi, B_, C_, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    xbc = jnp.concatenate([xi, B_, C_], -1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(h.dtype),
                                   p["conv_b"].astype(h.dtype)))
    xi, B_, C_ = jnp.split(xbc, [di, di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_forward(xi.reshape(b, s, H, P), dt, A, B_, C_, cfg.ssm_chunk)
    y = y + xi.reshape(b, s, H, P) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        # conv tail state: last (K-1) inputs of the conv stream
        conv_tail = jnp.concatenate([xi, B_, C_], -1)[:, s - (cfg.ssm_conv - 1):]
        return out, (conv_tail, state)
    return out


def mamba_block_decode(cfg: ArchConfig, p, x, conv_state, ssm_state):
    """One-token Mamba2 step. x (B,1,d); conv_state (B,K-1,conv_dim);
    ssm_state (B,H,P,N)."""
    b, _, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_headdim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xi, B_, C_, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], -1)
    xbc_new = jnp.concatenate([xi, B_, C_], -1)               # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc_new], 1)        # (B,K,conv_dim)
    w = p["conv_w"].astype(h.dtype)                           # (conv_dim,K)
    xbc = jax.nn.silu(jnp.einsum("bkc,ck->bc", window, w)
                      + p["conv_b"].astype(h.dtype))[:, None]
    xi, B_, C_ = jnp.split(xbc, [di, di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0] * A[None])                          # (B,H)
    xh = xi.reshape(b, H, P)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B_[:, 0].astype(jnp.float32),
                     xh.astype(jnp.float32))
    new_state = ssm_state.astype(jnp.float32) * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), new_state)
    y = y.astype(x.dtype) + xh * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, (window[:, 1:], new_state.astype(ssm_state.dtype))
