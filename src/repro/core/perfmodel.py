"""Cycle model for the engine (derives the paper's Tables II/III time columns)
plus the kernel cost model that picks a GEMM implementation per descriptor.

Cycle model: max(compute, memory) + configuration-overhead per descriptor:

  compute cycles = MACs / engine.macs
  memory  cycles = bytes moved over the DBB / dbb_bytes_per_cycle
  config  cycles = (#csb writes + #csb polls) * csb_cycles_per_access

The tight coupling + bare-metal claim of the paper shows up here as the config
term: a Linux driver stack pays orders of magnitude more host cycles per op
(syscalls, ioctl marshalling), which is what Table II's comparison against [8]
reflects.  We expose both the raw per-descriptor breakdown and whole-model
totals at the paper's 100 MHz system clock.

Kernel cost model (``select_kernel``): every CONV/FC contraction is lowered to
one of three kernels, chosen per descriptor by estimated cost on the serving
backend — never by a hard-coded size cliff:

  * ``gemm_f32_exact`` — single f32 GEMM; exact only while K*128*128 <= 2^24.
  * ``gemm_f32_tiled`` — K split into <=1024-element tiles, each an exact f32
    GEMM, partials accumulated in int32.  Exact for every K, so the scalar
    integer ``dot_general`` path is never needed.
  * ``pallas_fused``   — the ``kernels/int8_conv`` Pallas kernel: MXU int8
    GEMM with the NVDLA SDP epilogue fused so the int32 accumulator never
    leaves VMEM.

The bf16 (nv_full) datapath has its own candidate family, selected when the
engine config's dtype is ``bf16`` (``KERNELS_BY_DTYPE``):

  * ``gemm_bf16``        — XLA GEMM over bf16 operands, f32 accumulate (bf16
    products are exact in f32, so no K tiling is ever needed).
  * ``pallas_bf16_fused``— the ``kernels/bf16_conv`` Pallas kernel: MXU bf16
    GEMM with the nv_full SDP epilogue (f32 bias + ReLU) fused so the f32
    accumulator never leaves VMEM.

``kernel_plan`` maps a whole descriptor list; the pipeline's ``cost_model``
stage publishes the plan into the ``Artifacts`` manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import engine

# ---------------------------------------------------------------------------
# Kernel selection
# ---------------------------------------------------------------------------
KERNEL_GEMM_EXACT = "gemm_f32_exact"
KERNEL_GEMM_TILED = "gemm_f32_tiled"
KERNEL_PALLAS = "pallas_fused"
KERNEL_VPU = "vpu"                     # PDP / EW: no GEMM, pure vector ops

GEMM_KERNELS = (KERNEL_GEMM_EXACT, KERNEL_GEMM_TILED, KERNEL_PALLAS)

# bf16 (nv_full) kernel family: float accumulate, no requant, no exactness
# tiling (f32 accumulation of exact bf16 products needs no K split)
KERNEL_GEMM_BF16 = "gemm_bf16"         # XLA bf16 GEMM, f32 accumulate
KERNEL_PALLAS_BF16 = "pallas_bf16_fused"

BF16_KERNELS = (KERNEL_GEMM_BF16, KERNEL_PALLAS_BF16)

# which GEMM kernels may serve a descriptor, per engine dtype — selection and
# ``kernel_plan=`` override validation both consult this
KERNELS_BY_DTYPE = {"int8": GEMM_KERNELS, "bf16": BF16_KERNELS}

# Largest contraction K for which a single f32 GEMM is provably bit-exact:
# every int8*int8 product has |p| <= 128*128, so the worst-case partial sum
# K * 128 * 128 must stay within the 2^24 f32 integer-exact window.
EXACT_K = (1 << 24) // (128 * 128)     # = 1024


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """What the serving substrate can do, for the kernel cost model.

    Rates are relative (MACs and bytes per cycle) — only ratios matter for
    selection.  The scalar integer GEMM XLA falls back to on CPU is
    deliberately *not* a candidate: ``gemm_f32_tiled`` is exact for every K,
    wins outright whenever the GEMM is compute-bound (output positions /
    coalesced lanes widen the N dimension), and stays within a small
    constant of int8 streaming in the weight-bandwidth-bound GEMV regime.
    """
    platform: str
    f32_macs_per_cycle: float          # wide f32 units (SIMD FMA / MXU f32)
    bytes_per_cycle: float             # weight-stream bandwidth
    pallas_native: bool                # Pallas runs compiled (TPU) vs interpret
    tile_overhead_macs: float = 4096.0  # int32 partial-sum add per extra K-tile
    bf16_macs_per_cycle: float = 0.0   # native bf16 MAC rate (0 = cast to f32)

    @property
    def bf16_rate(self) -> float:
        """Effective bf16 MAC rate: native when the substrate has bf16 units
        (TPU MXU runs bf16 at 2x the f32 rate), else the f32 units after an
        upcast."""
        return self.bf16_macs_per_cycle or self.f32_macs_per_cycle


PROFILES: Dict[str, BackendProfile] = {
    "cpu": BackendProfile(platform="cpu", f32_macs_per_cycle=16.0,
                          bytes_per_cycle=32.0, pallas_native=False),
    "tpu": BackendProfile(platform="tpu", f32_macs_per_cycle=256.0,
                          bytes_per_cycle=512.0, pallas_native=True,
                          bf16_macs_per_cycle=512.0),
    "gpu": BackendProfile(platform="gpu", f32_macs_per_cycle=128.0,
                          bytes_per_cycle=256.0, pallas_native=False,
                          bf16_macs_per_cycle=256.0),
}


def default_backend() -> str:
    """The profile name for the platform jax will execute on."""
    import jax
    plat = jax.default_backend()
    return plat if plat in PROFILES else "cpu"


def resolve_profile(backend: Union[str, BackendProfile, None]) -> BackendProfile:
    if backend is None:
        return PROFILES[default_backend()]
    if isinstance(backend, BackendProfile):
        return backend
    try:
        return PROFILES[backend]
    except KeyError:
        raise ValueError(f"unknown backend profile {backend!r}; known: "
                         f"{', '.join(sorted(PROFILES))}") from None


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """One descriptor's resolved kernel: what runs, and why."""
    kernel: str
    contract_k: int = 0
    k_tiles: int = 1
    reason: str = ""

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def contract_k(d: engine.Descriptor) -> int:
    """Contraction length K of a CONV/FC descriptor (0 for PDP/EW)."""
    _, c, h, w = d.src_dims
    if d.unit == "CONV":
        r, s = d.kernel
        return (c // d.groups) * r * s
    if d.unit == "FC":
        return c * h * w
    return 0


def gemm_cols(d: engine.Descriptor) -> int:
    """N dimension of the descriptor's GEMM: output positions P*Q (1 for FC)."""
    _, _, p, q = d.dst_dims
    return p * q if d.unit == "CONV" else 1


def descriptor_macs(d: engine.Descriptor) -> int:
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    if d.unit == "CONV":
        r, s = d.kernel
        return (c // d.groups) * r * s * k * p * q
    if d.unit == "FC":
        return c * h * w * k
    return 0


def _kernel_cost(kernel: str, k: int, macs: int, n_cols: int,
                 prof: BackendProfile) -> float:
    """Estimated cost (relative cycles) of running ``kernel`` for this
    contraction on ``prof``; ``inf`` when the kernel is not applicable.

    max(compute, weight-stream) roofline: ``n_cols`` (output positions, or
    positions x coalesced lanes) decides which side binds — GEMV-shaped
    layers (n_cols ~ 1) are weight-bandwidth-bound, so the f32 kernels pay
    their 4-byte weight stream there, while wide GEMMs are compute-bound
    and the f32 units win on rate.
    """
    n_tiles = -(-k // EXACT_K) if k else 1
    weight_elems = macs // max(n_cols, 1)
    if kernel == KERNEL_GEMM_EXACT:
        if k > EXACT_K:
            return float("inf")            # would break the exactness proof
        return max(macs / prof.f32_macs_per_cycle,
                   4.0 * weight_elems / prof.bytes_per_cycle)
    if kernel == KERNEL_GEMM_TILED:
        return (max(macs / prof.f32_macs_per_cycle,
                    4.0 * weight_elems / prof.bytes_per_cycle)
                + (n_tiles - 1) * prof.tile_overhead_macs)
    if kernel == KERNEL_PALLAS:
        if not prof.pallas_native:
            return float("inf")            # interpret mode: test-only on CPU
        # int8 weight stream + fused epilogue (the int32 accumulator stays
        # in VMEM): both sides of the roofline are cheaper than f32
        return max(0.9 * macs / prof.f32_macs_per_cycle,
                   1.0 * weight_elems / prof.bytes_per_cycle)
    if kernel == KERNEL_GEMM_BF16:
        # bf16 operands stream at 2 bytes/elem; accumulate rides the bf16
        # units when they exist, the f32 units after an upcast otherwise
        return max(macs / prof.bf16_rate,
                   2.0 * weight_elems / prof.bytes_per_cycle)
    if kernel == KERNEL_PALLAS_BF16:
        if not prof.pallas_native:
            return float("inf")            # interpret mode: test-only on CPU
        # fused epilogue: the f32 accumulator never leaves VMEM
        return max(0.9 * macs / prof.bf16_rate,
                   2.0 * weight_elems / prof.bytes_per_cycle)
    raise ValueError(f"unknown kernel {kernel!r}")


def select_kernel(d: engine.Descriptor,
                  backend: Union[str, BackendProfile, None] = None,
                  override: Optional[str] = None,
                  dtype: str = "int8") -> KernelChoice:
    """Pick the cheapest applicable kernel for one descriptor.

    ``dtype`` is the engine datapath (``EngineConfig.dtype``): it decides the
    candidate set — int8 descriptors resolve to the bit-exact integer GEMMs,
    bf16 (nv_full) descriptors to the f32-accumulate family.  ``override``
    forces a specific GEMM kernel (debugging / A-B testing); forcing
    ``gemm_f32_exact`` on a contraction too large for the exactness bound, or
    a kernel from the wrong dtype family, raises rather than silently
    producing wrong bits.
    """
    if d.unit not in ("CONV", "FC"):
        return KernelChoice(kernel=KERNEL_VPU, reason="no contraction")
    try:
        candidates = KERNELS_BY_DTYPE[dtype]
    except KeyError:
        raise ValueError(f"no kernel family for engine dtype {dtype!r}; "
                         f"known: {', '.join(sorted(KERNELS_BY_DTYPE))}") \
            from None
    prof = resolve_profile(backend)
    k = contract_k(d)
    macs = descriptor_macs(d)
    n_tiles = (-(-k // EXACT_K) if k else 1) if dtype == "int8" else 1
    if override is not None:
        if override not in candidates:
            raise ValueError(
                f"unknown kernel {override!r} for dtype {dtype!r}; "
                f"{dtype} GEMM kernels: {', '.join(candidates)}")
        if override == KERNEL_GEMM_EXACT and k > EXACT_K:
            raise ValueError(
                f"kernel {override!r} forced for K={k} > {EXACT_K}: a single "
                f"f32 GEMM is not bit-exact past K*128*128 = 2^24")
        return KernelChoice(kernel=override, contract_k=k, k_tiles=n_tiles,
                            reason="forced by kernel_plan override")
    n_cols = gemm_cols(d)
    costs = {name: _kernel_cost(name, k, macs, n_cols, prof)
             for name in candidates}
    best = min(costs, key=costs.get)
    return KernelChoice(
        kernel=best, contract_k=k, k_tiles=n_tiles,
        reason=f"cost model on {prof.platform}: " + ", ".join(
            f"{n}={c:.0f}" if c != float("inf") else f"{n}=n/a"
            for n, c in costs.items()))


def kernel_plan(descs: Sequence[engine.Descriptor],
                names: Optional[Sequence[str]] = None,
                backend: Union[str, BackendProfile, None] = None,
                override: Optional[str] = None,
                dtype: str = "int8") -> List[Dict]:
    """Per-descriptor kernel plan, as JSON-ready dicts (manifest format)."""
    names = names or [f"op{i}" for i in range(len(descs))]
    prof = resolve_profile(backend)
    out = []
    for d, n in zip(descs, names):
        ch = select_kernel(d, prof, override=override, dtype=dtype)
        e = ch.to_dict()
        e.update(layer=n, unit=d.unit, backend=prof.platform, dtype=dtype)
        out.append(e)
    return out


@dataclasses.dataclass
class OpCost:
    layer: str
    unit: str
    macs: int
    bytes_moved: int
    compute_cycles: int
    memory_cycles: int
    config_cycles: int

    @property
    def cycles(self) -> int:
        return max(self.compute_cycles, self.memory_cycles) + self.config_cycles


@dataclasses.dataclass
class ModelCost:
    ops: List[OpCost]
    total_cycles: int
    ms_at_clock: float
    kernel_plan: Optional[List[Dict]] = None   # per-layer kernel choice dicts

    def layer_breakdown(self) -> List[Dict]:
        """Per-layer time share + chosen kernel, sorted by modeled cycles."""
        total = max(self.total_cycles, 1)
        plan = {e["layer"]: e for e in (self.kernel_plan or [])}
        rows = []
        for o in self.ops:
            ch = plan.get(o.layer, {})
            rows.append({
                "layer": o.layer, "unit": o.unit, "cycles": o.cycles,
                "share": o.cycles / total,
                "kernel": ch.get("kernel", ""),
                "contract_k": ch.get("contract_k", 0),
                "k_tiles": ch.get("k_tiles", 1),
            })
        rows.sort(key=lambda r: -r["cycles"])
        return rows

    def dominant(self) -> str:
        c = sum(o.compute_cycles for o in self.ops)
        m = sum(o.memory_cycles for o in self.ops)
        g = sum(o.config_cycles for o in self.ops)
        return max(("compute", c), ("memory", m), ("config", g), key=lambda t: t[1])[0]


def descriptor_cost(d: engine.Descriptor, cfg: engine.EngineConfig,
                    name: str = "") -> OpCost:
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    eb = cfg.elem_bytes
    if d.unit == "CONV":
        r, s = d.kernel
        macs = descriptor_macs(d)
        wbytes = k * (c // d.groups) * r * s * eb
        bytes_moved = c * h * w * eb + wbytes + k * 4 * 2 + k * p * q * eb
    elif d.unit == "FC":
        cin = c * h * w
        macs = descriptor_macs(d)
        bytes_moved = cin * eb + k * cin * eb + k * 4 * 2 + k * eb
    elif d.unit == "PDP":
        r, s = d.kernel
        macs = k * p * q * r * s          # adds count as MAC-equivalent work
        bytes_moved = c * h * w * eb + k * p * q * eb
    elif d.unit == "EW":
        macs = k * p * q * 2
        bytes_moved = 2 * c * h * w * eb + k * p * q * eb
    else:
        raise ValueError(d.unit)
    n_writes = len(d.to_reg_writes()) + 1     # + STATUS poll
    return OpCost(
        layer=name, unit=d.unit, macs=macs, bytes_moved=bytes_moved,
        compute_cycles=int(np.ceil(macs / (cfg.macs * cfg.mac_util))),
        memory_cycles=int(np.ceil(bytes_moved / (cfg.dbb_bytes_per_cycle * cfg.dbb_eff))),
        config_cycles=n_writes * cfg.csb_cycles_per_access + cfg.op_overhead_cycles,
    )


def model_cost(descs: List[engine.Descriptor], cfg: engine.EngineConfig,
               names: List[str] | None = None,
               backend: Union[str, BackendProfile, None] = None) -> ModelCost:
    names = names or [f"op{i}" for i in range(len(descs))]
    ops = [descriptor_cost(d, cfg, n) for d, n in zip(descs, names)]
    total = sum(o.cycles for o in ops)
    return ModelCost(ops=ops, total_cycles=total,
                     ms_at_clock=cfg.cycles_to_ms(total),
                     kernel_plan=kernel_plan(descs, names, backend,
                                             dtype=cfg.dtype))
