"""Cycle model for the engine (derives the paper's Tables II/III time columns)
plus the kernel cost model that picks a GEMM implementation per descriptor.

Cycle model: max(compute, memory) + configuration-overhead per descriptor:

  compute cycles = MACs / engine.macs
  memory  cycles = bytes moved over the DBB / dbb_bytes_per_cycle
  config  cycles = (#csb writes + #csb polls) * csb_cycles_per_access

The tight coupling + bare-metal claim of the paper shows up here as the config
term: a Linux driver stack pays orders of magnitude more host cycles per op
(syscalls, ioctl marshalling), which is what Table II's comparison against [8]
reflects.  We expose both the raw per-descriptor breakdown and whole-model
totals at the paper's 100 MHz system clock.

Kernel cost model (``select_kernel``): every CONV/FC contraction is lowered to
one of three kernels, chosen per descriptor by estimated cost on the serving
backend — never by a hard-coded size cliff:

  * ``gemm_f32_exact`` — single f32 GEMM; exact only while K*128*128 <= 2^24.
  * ``gemm_f32_tiled`` — K split into <=1024-element tiles, each an exact f32
    GEMM, partials accumulated in int32.  Exact for every K, so the scalar
    integer ``dot_general`` path is never needed.
  * ``pallas_fused``   — the ``kernels/int8_conv`` Pallas kernel: MXU int8
    GEMM with the NVDLA SDP epilogue fused so the int32 accumulator never
    leaves VMEM.

The bf16 (nv_full) datapath has its own candidate family, selected when the
engine config's dtype is ``bf16`` (``KERNELS_BY_DTYPE``):

  * ``gemm_bf16``        — XLA GEMM over bf16 operands, f32 accumulate (bf16
    products are exact in f32, so no K tiling is ever needed).
  * ``pallas_bf16_fused``— the ``kernels/bf16_conv`` Pallas kernel: MXU bf16
    GEMM with the nv_full SDP epilogue (f32 bias + ReLU) fused so the f32
    accumulator never leaves VMEM.

``kernel_plan`` maps a whole descriptor list; the pipeline's ``cost_model``
stage publishes the plan into the ``Artifacts`` manifest.

The cost model is **batch-aware**: ``select_kernel``/``kernel_plan`` take the
coalesced bucket size and compare, per kernel, executing the bucket as N
vmapped single-image launches (weights stream from HBM once *per lane*)
against one natively batched launch that folds the lanes into the GEMM's N
axis (weights stream **once**, amortised over every lane).  The winning
execution style is recorded as ``KernelChoice.batched`` and drives the
executors' batched replay — ``batched_kernel_plans`` publishes the
per-(layer, bucket) plans for the whole coalescing ladder into the manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import engine

# ---------------------------------------------------------------------------
# Kernel selection
# ---------------------------------------------------------------------------
KERNEL_GEMM_EXACT = "gemm_f32_exact"
KERNEL_GEMM_TILED = "gemm_f32_tiled"
KERNEL_PALLAS = "pallas_fused"
KERNEL_VPU = "vpu"                     # PDP / EW: no GEMM, pure vector ops

GEMM_KERNELS = (KERNEL_GEMM_EXACT, KERNEL_GEMM_TILED, KERNEL_PALLAS)

# bf16 (nv_full) kernel family: float accumulate, no requant, no exactness
# tiling (f32 accumulation of exact bf16 products needs no K split)
KERNEL_GEMM_BF16 = "gemm_bf16"         # XLA bf16 GEMM, f32 accumulate
KERNEL_PALLAS_BF16 = "pallas_bf16_fused"

BF16_KERNELS = (KERNEL_GEMM_BF16, KERNEL_PALLAS_BF16)

# which GEMM kernels may serve a descriptor, per engine dtype — selection and
# ``kernel_plan=`` override validation both consult this
KERNELS_BY_DTYPE = {"int8": GEMM_KERNELS, "bf16": BF16_KERNELS}

# Largest contraction K for which a single f32 GEMM is provably bit-exact:
# every int8*int8 product has |p| <= 128*128, so the worst-case partial sum
# K * 128 * 128 must stay within the 2^24 f32 integer-exact window.
EXACT_K = (1 << 24) // (128 * 128)     # = 1024


def bucket_ladder(max_batch: int) -> tuple:
    """The power-of-two coalescing bucket ladder for a ``max_batch`` ceiling.

    Rungs are 1, 2, 4, ... doubling below ``max_batch``, and ``max_batch``
    itself is always the top rung (a non-power-of-two ceiling still gets a
    bucket, matching the scheduler's padded-shape cap).  This is the ONE
    source of truth for which batch shapes exist: ``SchedulerConfig.buckets``
    defaults to it, ``Session.warmup`` precompiles it, and
    ``batched_kernel_plans`` publishes a plan per rung into the manifest.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    rungs = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b *= 2
    rungs.append(int(max_batch))
    return tuple(rungs)


# ladder used for manifest publication when no scheduler config is in scope
# (the serving default: scheduler buckets cap at the executor's batch ceiling)
DEFAULT_BUCKET_LADDER = bucket_ladder(32)


@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """What the serving substrate can do, for the kernel cost model.

    Rates are relative (MACs and bytes per cycle) — only ratios matter for
    selection.  The scalar integer GEMM XLA falls back to on CPU is
    deliberately *not* a candidate: ``gemm_f32_tiled`` is exact for every K,
    wins outright whenever the GEMM is compute-bound (output positions /
    coalesced lanes widen the N dimension), and stays within a small
    constant of int8 streaming in the weight-bandwidth-bound GEMV regime.
    """
    platform: str
    f32_macs_per_cycle: float          # wide f32 units (SIMD FMA / MXU f32)
    bytes_per_cycle: float             # weight-stream bandwidth
    pallas_native: bool                # Pallas runs compiled (TPU) vs interpret
    tile_overhead_macs: float = 4096.0  # int32 partial-sum add per extra K-tile
    bf16_macs_per_cycle: float = 0.0   # native bf16 MAC rate (0 = cast to f32)
    launch_overhead_macs: float = 8192.0  # fixed dispatch cost per kernel
                                       # launch (MAC-equivalents) — this is
                                       # the per-lane tax a vmapped bucket
                                       # pays N times and a native-batch
                                       # launch pays once
    vmap_folds: bool = False           # XLA's vmap batching rule already
                                       # folds a broadcast-weight dot_general
                                       # into ONE batched GEMM inside one
                                       # executable (measured parity on CPU),
                                       # so a vmapped bucket pays the weight
                                       # stream and launch once, not per
                                       # lane.  False for the Pallas TPU
                                       # path, where each lane's program
                                       # really does re-stream weights.

    @property
    def bf16_rate(self) -> float:
        """Effective bf16 MAC rate: native when the substrate has bf16 units
        (TPU MXU runs bf16 at 2x the f32 rate), else the f32 units after an
        upcast."""
        return self.bf16_macs_per_cycle or self.f32_macs_per_cycle


PROFILES: Dict[str, BackendProfile] = {
    "cpu": BackendProfile(platform="cpu", f32_macs_per_cycle=16.0,
                          bytes_per_cycle=32.0, pallas_native=False,
                          vmap_folds=True),
    "tpu": BackendProfile(platform="tpu", f32_macs_per_cycle=256.0,
                          bytes_per_cycle=512.0, pallas_native=True,
                          bf16_macs_per_cycle=512.0),
    "gpu": BackendProfile(platform="gpu", f32_macs_per_cycle=128.0,
                          bytes_per_cycle=256.0, pallas_native=False,
                          bf16_macs_per_cycle=256.0, vmap_folds=True),
}


def default_backend() -> str:
    """The profile name for the platform jax will execute on."""
    import jax
    plat = jax.default_backend()
    return plat if plat in PROFILES else "cpu"


def resolve_profile(backend: Union[str, BackendProfile, None]) -> BackendProfile:
    if backend is None:
        return PROFILES[default_backend()]
    if isinstance(backend, BackendProfile):
        return backend
    try:
        return PROFILES[backend]
    except KeyError:
        raise ValueError(f"unknown backend profile {backend!r}; known: "
                         f"{', '.join(sorted(PROFILES))}") from None


@dataclasses.dataclass(frozen=True)
class KernelChoice:
    """One descriptor's resolved kernel: what runs, and why.

    ``batch`` is the coalesced bucket size the choice was made for;
    ``batched`` says the kernel should run as ONE natively batched launch
    (lanes folded into the GEMM N axis, weights streamed once) rather than
    ``batch`` vmapped single-image launches.
    """
    kernel: str
    contract_k: int = 0
    k_tiles: int = 1
    reason: str = ""
    batch: int = 1
    batched: bool = False

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def contract_k(d: engine.Descriptor) -> int:
    """Contraction length K of a CONV/FC descriptor (0 for PDP/EW)."""
    _, c, h, w = d.src_dims
    if d.unit == "CONV":
        r, s = d.kernel
        return (c // d.groups) * r * s
    if d.unit == "FC":
        return c * h * w
    return 0


def gemm_cols(d: engine.Descriptor) -> int:
    """N dimension of the descriptor's GEMM: output positions P*Q (1 for FC)."""
    _, _, p, q = d.dst_dims
    return p * q if d.unit == "CONV" else 1


def descriptor_macs(d: engine.Descriptor) -> int:
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    if d.unit == "CONV":
        r, s = d.kernel
        return (c // d.groups) * r * s * k * p * q
    if d.unit == "FC":
        return c * h * w * k
    return 0


def _kernel_cost(kernel: str, k: int, macs: int, n_cols: int,
                 prof: BackendProfile, batch: int = 1,
                 native: bool = False) -> float:
    """Estimated cost (relative cycles) of serving a ``batch``-lane bucket
    with ``kernel`` on ``prof``; ``inf`` when the kernel is not applicable.

    max(compute, weight-stream) roofline: ``n_cols`` (output positions, or
    positions x coalesced lanes) decides which side binds — GEMV-shaped
    layers (n_cols ~ 1) are weight-bandwidth-bound, so the f32 kernels pay
    their 4-byte weight stream there, while wide GEMMs are compute-bound
    and the f32 units win on rate.

    ``native=False`` models ``batch`` vmapped single-image launches: the
    weight stream and the fixed launch overhead are paid once per lane.
    ``native=True`` models ONE batched launch with the lanes folded into the
    GEMM N axis: compute scales with the lanes but the weight stream and the
    launch overhead are paid once — the amortisation the batched kernels buy.

    On ``vmap_folds`` substrates (XLA CPU/GPU) the vmapped style pays the
    stream and launch once too: XLA's batching rule turns the broadcast-weight
    dot_general into a single batched GEMM inside one executable, so vmapping
    already IS the fold there (measured bit-exact parity on CPU) and native
    batching ties rather than wins.
    """
    lanes = max(batch, 1)
    n_tiles = -(-k // EXACT_K) if k else 1
    weight_elems = macs // max(n_cols, 1)
    folded = native or prof.vmap_folds
    streams = 1 if folded else lanes       # weight-stream trips over HBM
    launch = ((1 if folded else lanes)
              * prof.launch_overhead_macs / prof.f32_macs_per_cycle)
    cmacs = lanes * macs
    # the extra-K-tile partial-sum adds cover every output column, so they
    # scale with the lanes under either execution style
    tiles = (n_tiles - 1) * prof.tile_overhead_macs * lanes
    if kernel == KERNEL_GEMM_EXACT:
        if k > EXACT_K:
            return float("inf")            # would break the exactness proof
        return max(cmacs / prof.f32_macs_per_cycle,
                   4.0 * streams * weight_elems / prof.bytes_per_cycle) + launch
    if kernel == KERNEL_GEMM_TILED:
        return (max(cmacs / prof.f32_macs_per_cycle,
                    4.0 * streams * weight_elems / prof.bytes_per_cycle)
                + tiles + launch)
    if kernel == KERNEL_PALLAS:
        if not prof.pallas_native:
            return float("inf")            # interpret mode: test-only on CPU
        # int8 weight stream + fused epilogue (the int32 accumulator stays
        # in VMEM): both sides of the roofline are cheaper than f32
        return max(0.9 * cmacs / prof.f32_macs_per_cycle,
                   1.0 * streams * weight_elems / prof.bytes_per_cycle) + launch
    if kernel == KERNEL_GEMM_BF16:
        # bf16 operands stream at 2 bytes/elem; accumulate rides the bf16
        # units when they exist, the f32 units after an upcast otherwise
        return max(cmacs / prof.bf16_rate,
                   2.0 * streams * weight_elems / prof.bytes_per_cycle) + launch
    if kernel == KERNEL_PALLAS_BF16:
        if not prof.pallas_native:
            return float("inf")            # interpret mode: test-only on CPU
        # fused epilogue: the f32 accumulator never leaves VMEM
        return max(0.9 * cmacs / prof.bf16_rate,
                   2.0 * streams * weight_elems / prof.bytes_per_cycle) + launch
    raise ValueError(f"unknown kernel {kernel!r}")


def select_kernel(d: engine.Descriptor,
                  backend: Union[str, BackendProfile, None] = None,
                  override: Optional[str] = None,
                  dtype: str = "int8", batch: int = 1,
                  calibration: Optional["CalibrationProfile"] = None
                  ) -> KernelChoice:
    """Pick the cheapest applicable kernel for one descriptor.

    ``dtype`` is the engine datapath (``EngineConfig.dtype``): it decides the
    candidate set — int8 descriptors resolve to the bit-exact integer GEMMs,
    bf16 (nv_full) descriptors to the f32-accumulate family.  ``override``
    forces a specific GEMM kernel (debugging / A-B testing); forcing
    ``gemm_f32_exact`` on a contraction too large for the exactness bound, or
    a kernel from the wrong dtype family, raises rather than silently
    producing wrong bits.

    ``batch`` is the coalesced bucket size.  For ``batch > 1`` every
    candidate is costed under both execution styles — ``batch`` vmapped
    single-image launches vs one natively batched launch with the lanes
    folded into the GEMM N axis — and the winner's style is recorded in
    ``KernelChoice.batched``.  Native batching must *strictly* beat vmapping
    to be selected: on ``vmap_folds`` substrates (XLA CPU/GPU) the two styles
    cost the same, so the vmapped oracle keeps serving there and ``batched``
    only turns on where the amortisation is real (the Pallas TPU path).  An
    ``override`` forces the kernel but the execution style is still
    cost-chosen (every kernel family has a batched variant, so the override
    can never be silently ignored).

    ``calibration`` swaps the a-priori relative-cycle costs for measured
    microseconds: a ``CalibrationProfile`` fitted by ``calibrate()`` from
    per-layer profiling spans predicts each candidate's latency from its
    fitted per-family constants (compute rate, weight-stream bandwidth,
    launch overhead).  Applicability is still decided by the static model —
    a kernel the static model rules out (exactness bound, interpret-only
    Pallas) stays out no matter what the fit says.
    """
    lanes = max(int(batch), 1)
    if d.unit not in ("CONV", "FC"):
        return KernelChoice(kernel=KERNEL_VPU, reason="no contraction",
                            batch=lanes)
    try:
        candidates = KERNELS_BY_DTYPE[dtype]
    except KeyError:
        raise ValueError(f"no kernel family for engine dtype {dtype!r}; "
                         f"known: {', '.join(sorted(KERNELS_BY_DTYPE))}") \
            from None
    prof = resolve_profile(backend)
    k = contract_k(d)
    macs = descriptor_macs(d)
    n_cols = gemm_cols(d)
    n_tiles = (-(-k // EXACT_K) if k else 1) if dtype == "int8" else 1

    def style_cost(name: str, native: bool) -> float:
        static = _kernel_cost(name, k, macs, n_cols, prof, lanes,
                              native=native)
        if calibration is None or static == float("inf"):
            return static
        eb = 1 if dtype == "int8" else 2
        wbytes = (macs // max(n_cols, 1)) * eb
        us = calibration.predict_us(name, macs, wbytes, batch=lanes,
                                    native=native, static_cost=static)
        return us if us is not None else static

    def exec_style(name: str) -> tuple:
        """(best cost, native-batch wins) for one candidate kernel."""
        vmapped = style_cost(name, native=False)
        if lanes == 1:
            return vmapped, False
        fused = style_cost(name, native=True)
        return min(vmapped, fused), fused < vmapped

    if override is not None:
        if override not in candidates:
            raise ValueError(
                f"unknown kernel {override!r} for dtype {dtype!r}; "
                f"{dtype} GEMM kernels: {', '.join(candidates)}")
        if override == KERNEL_GEMM_EXACT and k > EXACT_K:
            raise ValueError(
                f"kernel {override!r} forced for K={k} > {EXACT_K}: a single "
                f"f32 GEMM is not bit-exact past K*128*128 = 2^24")
        _, native = exec_style(override)
        return KernelChoice(kernel=override, contract_k=k, k_tiles=n_tiles,
                            batch=lanes, batched=native,
                            reason="forced by kernel_plan override")
    styles = {name: exec_style(name) for name in candidates}
    costs = {name: c for name, (c, _) in styles.items()}
    best = min(costs, key=costs.get)
    model = "calibrated cost model" if calibration is not None else "cost model"
    return KernelChoice(
        kernel=best, contract_k=k, k_tiles=n_tiles,
        batch=lanes, batched=styles[best][1],
        reason=f"{model} on {prof.platform} (batch={lanes}): " + ", ".join(
            f"{n}={c:.0f}" if c != float("inf") else f"{n}=n/a"
            for n, c in costs.items()))


def kernel_plan(descs: Sequence[engine.Descriptor],
                names: Optional[Sequence[str]] = None,
                backend: Union[str, BackendProfile, None] = None,
                override: Optional[str] = None,
                dtype: str = "int8", batch: int = 1,
                calibration: Optional["CalibrationProfile"] = None
                ) -> List[Dict]:
    """Per-descriptor kernel plan, as JSON-ready dicts (manifest format)."""
    names = names or [f"op{i}" for i in range(len(descs))]
    prof = resolve_profile(backend)
    out = []
    for d, n in zip(descs, names):
        ch = select_kernel(d, prof, override=override, dtype=dtype,
                           batch=batch, calibration=calibration)
        e = ch.to_dict()
        e.update(layer=n, unit=d.unit, backend=prof.platform, dtype=dtype)
        out.append(e)
    return out


def batched_kernel_plans(descs: Sequence[engine.Descriptor],
                         names: Optional[Sequence[str]] = None,
                         backend: Union[str, BackendProfile, None] = None,
                         override: Optional[str] = None,
                         dtype: str = "int8",
                         buckets: Sequence[int] = DEFAULT_BUCKET_LADDER
                         ) -> Dict[int, List[Dict]]:
    """Per-(layer, bucket) kernel plans for the coalescing ladder.

    ``{bucket: kernel_plan entries}`` for every ladder rung above 1 (the
    1-lane plan is the base ``kernel_plan``); this is what the pipeline
    publishes into the manifest as ``batched_kernel_plans``.
    """
    return {int(b): kernel_plan(descs, names, backend, override=override,
                                dtype=dtype, batch=int(b))
            for b in buckets if int(b) > 1}


# ---------------------------------------------------------------------------
# Measured calibration: fit the cost model's constants from per-layer spans
# ---------------------------------------------------------------------------
def sample_features(d: engine.Descriptor, dtype: str = "int8") -> tuple:
    """(MAC-equivalents, streamed bytes) of one descriptor — the two features
    the calibration fit regresses measured microseconds against.

    CONV/FC stream their weight matrix (the roofline's bandwidth side); the
    vector units (PDP/EW) have no weights, so their "stream" is the
    activation traffic — the fitted bandwidth constant absorbs the
    difference in what the bytes actually are.
    """
    eb = 1 if dtype == "int8" else 2
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    if d.unit in ("CONV", "FC"):
        macs = descriptor_macs(d)
        return macs, (macs // max(gemm_cols(d), 1)) * eb
    if d.unit == "PDP":
        r, s = d.kernel
        return k * p * q * r * s, c * h * w * eb + k * p * q * eb
    if d.unit == "EW":
        return k * p * q * 2, 2 * c * h * w * eb + k * p * q * eb
    raise ValueError(d.unit)


def static_cost_units(d: engine.Descriptor, kernel: str,
                      backend: Union[str, BackendProfile, None] = None,
                      dtype: str = "int8", batch: int = 1,
                      native: bool = False) -> float:
    """A-priori cost (relative cycles) of one descriptor under ``kernel`` —
    the uncalibrated model the fidelity report compares measurements against.
    GEMM kernels use ``_kernel_cost``; the vector units use their own
    roofline over ``sample_features`` (they have no GEMM kernel entry)."""
    prof = resolve_profile(backend)
    if d.unit in ("CONV", "FC"):
        return _kernel_cost(kernel, contract_k(d), descriptor_macs(d),
                            gemm_cols(d), prof, batch, native)
    macs, sbytes = sample_features(d, dtype)
    lanes = max(batch, 1)
    return lanes * max(macs / prof.f32_macs_per_cycle,
                       sbytes / prof.bytes_per_cycle)


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Measured per-kernel-family cost constants, fitted by ``calibrate()``.

    ``families[kernel]`` holds the fitted additive model in microseconds:

        us = lanes*macs * us_per_mac
           + streams*bytes * us_per_byte        (streams = 1 when folded)
           + launches * launch_us               (launches = 1 when folded)

    (reciprocals of the paper-facing "compute rate" / "weight-stream
    bandwidth"; ``compute_rate``/``stream_bw`` expose those directly).
    ``us_per_cycle`` is the global scale fallback — measured microseconds per
    modeled relative cycle — used for kernel families the profiling run never
    exercised, so a calibrated ``select_kernel`` still compares every
    candidate in the same (microsecond) unit.
    """
    platform: str
    dtype: str = "int8"
    vmap_folds: bool = True
    us_per_cycle: float = 0.0
    families: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)
    samples: int = 0

    def compute_rate(self, kernel: str) -> float:
        """Fitted compute rate in MACs/us (0 when unfitted/unbounded)."""
        f = self.families.get(kernel)
        return 1.0 / f["us_per_mac"] if f and f["us_per_mac"] > 0 else 0.0

    def stream_bw(self, kernel: str) -> float:
        """Fitted stream bandwidth in bytes/us (0 when unfitted/unbounded)."""
        f = self.families.get(kernel)
        return 1.0 / f["us_per_byte"] if f and f["us_per_byte"] > 0 else 0.0

    def launch_us(self, kernel: str) -> float:
        f = self.families.get(kernel)
        return f["launch_us"] if f else 0.0

    def predict_us(self, kernel: str, macs: float, stream_bytes: float,
                   batch: int = 1, native: bool = False,
                   static_cost: Optional[float] = None) -> Optional[float]:
        """Predicted latency in microseconds, or ``None`` when the family is
        unfitted and no fallback is possible."""
        lanes = max(int(batch), 1)
        folded = native or self.vmap_folds
        f = self.families.get(kernel)
        if f is not None:
            streams = 1 if folded else lanes
            return (lanes * macs * f["us_per_mac"]
                    + streams * stream_bytes * f["us_per_byte"]
                    + streams * f["launch_us"])
        if static_cost is not None and self.us_per_cycle > 0:
            return static_cost * self.us_per_cycle
        return None

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, doc: Dict) -> "CalibrationProfile":
        return cls(platform=doc["platform"], dtype=doc.get("dtype", "int8"),
                   vmap_folds=bool(doc.get("vmap_folds", True)),
                   us_per_cycle=float(doc.get("us_per_cycle", 0.0)),
                   families={k: dict(v)
                             for k, v in doc.get("families", {}).items()},
                   samples=int(doc.get("samples", 0)))


def _fit_family(rows: List[tuple]) -> Optional[Dict[str, float]]:
    """Nonnegative least squares over (cmacs, sbytes, launches) -> us.

    Plain lstsq with iterative column dropping: a negative coefficient means
    that feature is colinear with another on this sample set (tiny nets often
    can't separate bandwidth from compute), so the offending column is
    removed and the rest refitted rather than shipping a negative "rate"."""
    A = np.array([[r[0], r[1], r[2]] for r in rows], dtype=np.float64)
    b = np.array([r[3] for r in rows], dtype=np.float64)
    cols = [0, 1, 2]
    while True:
        coef, *_ = np.linalg.lstsq(A[:, cols], b, rcond=None)
        neg = [j for j, c in enumerate(coef) if c < 0]
        if not neg or len(cols) <= 1:
            break
        cols = [c for j, c in enumerate(cols) if j not in neg]
    full = [0.0, 0.0, 0.0]
    for j, c in zip(cols, coef):
        full[j] = max(float(c), 0.0)
    if not all(np.isfinite(full)) or sum(full) <= 0:
        # degenerate fit (e.g. a single repeated layer): scale-only model
        cm = np.array([r[0] for r in rows], dtype=np.float64)
        if cm.sum() <= 0:
            return None
        full = [float(np.median(b[cm > 0] / cm[cm > 0])), 0.0, 0.0]
    return {"us_per_mac": full[0], "us_per_byte": full[1],
            "launch_us": full[2], "samples": float(len(rows))}


def calibrate(samples: Sequence[Dict],
              descs: Sequence[engine.Descriptor],
              backend: Union[str, BackendProfile, None] = None,
              dtype: str = "int8") -> CalibrationProfile:
    """Fit a ``CalibrationProfile`` from measured per-layer profiling samples.

    ``samples`` are the dicts the executors' ``run_profiled`` emits (and the
    tracer collects): ``{"index", "kernel", "us"}`` plus optional ``bucket``
    (coalesced lanes, default 1) and ``native`` (batched-launch style).
    Constants are fitted per kernel family; the global ``us_per_cycle``
    scale comes from the median measured/modeled ratio across every sample,
    so families the run never exercised still predict in microseconds.
    """
    prof = resolve_profile(backend)
    by_family: Dict[str, List[tuple]] = {}
    ratios = []
    n_used = 0
    for s in samples:
        idx = int(s["index"])
        if not 0 <= idx < len(descs):
            continue
        d = descs[idx]
        us = float(s["us"])
        if us <= 0:
            continue
        kernel = s.get("kernel") or KERNEL_VPU
        lanes = max(int(s.get("bucket", 1)), 1)
        native = bool(s.get("native", False))
        folded = native or prof.vmap_folds
        macs, sbytes = sample_features(d, dtype)
        streams = 1 if folded else lanes
        by_family.setdefault(kernel, []).append(
            (lanes * macs, streams * sbytes, streams, us))
        static = static_cost_units(d, kernel, prof, dtype, lanes, native)
        if np.isfinite(static) and static > 0:
            ratios.append(us / static)
        n_used += 1
    families = {}
    for kernel, rows in by_family.items():
        fit = _fit_family(rows)
        if fit is not None:
            families[kernel] = fit
    return CalibrationProfile(
        platform=prof.platform, dtype=dtype, vmap_folds=prof.vmap_folds,
        us_per_cycle=float(np.median(ratios)) if ratios else 0.0,
        families=families, samples=n_used)


@dataclasses.dataclass
class OpCost:
    layer: str
    unit: str
    macs: int
    bytes_moved: int
    compute_cycles: int
    memory_cycles: int
    config_cycles: int

    @property
    def cycles(self) -> int:
        return max(self.compute_cycles, self.memory_cycles) + self.config_cycles


@dataclasses.dataclass
class ModelCost:
    ops: List[OpCost]
    total_cycles: int
    ms_at_clock: float
    kernel_plan: Optional[List[Dict]] = None   # per-layer kernel choice dicts
    batched_kernel_plans: Optional[Dict[int, List[Dict]]] = None
                                               # per-(layer, bucket) choices

    def layer_breakdown(self) -> List[Dict]:
        """Per-layer time share + chosen kernel, sorted by modeled cycles."""
        total = max(self.total_cycles, 1)
        plan = {e["layer"]: e for e in (self.kernel_plan or [])}
        rows = []
        for o in self.ops:
            ch = plan.get(o.layer, {})
            rows.append({
                "layer": o.layer, "unit": o.unit, "cycles": o.cycles,
                "share": o.cycles / total,
                "kernel": ch.get("kernel", ""),
                "contract_k": ch.get("contract_k", 0),
                "k_tiles": ch.get("k_tiles", 1),
            })
        rows.sort(key=lambda r: -r["cycles"])
        return rows

    def dominant(self) -> str:
        c = sum(o.compute_cycles for o in self.ops)
        m = sum(o.memory_cycles for o in self.ops)
        g = sum(o.config_cycles for o in self.ops)
        return max(("compute", c), ("memory", m), ("config", g), key=lambda t: t[1])[0]


def descriptor_cost(d: engine.Descriptor, cfg: engine.EngineConfig,
                    name: str = "") -> OpCost:
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    eb = cfg.elem_bytes
    if d.unit == "CONV":
        r, s = d.kernel
        macs = descriptor_macs(d)
        wbytes = k * (c // d.groups) * r * s * eb
        bytes_moved = c * h * w * eb + wbytes + k * 4 * 2 + k * p * q * eb
    elif d.unit == "FC":
        cin = c * h * w
        macs = descriptor_macs(d)
        bytes_moved = cin * eb + k * cin * eb + k * 4 * 2 + k * eb
    elif d.unit == "PDP":
        r, s = d.kernel
        macs = k * p * q * r * s          # adds count as MAC-equivalent work
        bytes_moved = c * h * w * eb + k * p * q * eb
    elif d.unit == "EW":
        macs = k * p * q * 2
        bytes_moved = 2 * c * h * w * eb + k * p * q * eb
    else:
        raise ValueError(d.unit)
    n_writes = len(d.to_reg_writes()) + 1     # + STATUS poll
    return OpCost(
        layer=name, unit=d.unit, macs=macs, bytes_moved=bytes_moved,
        compute_cycles=int(np.ceil(macs / (cfg.macs * cfg.mac_util))),
        memory_cycles=int(np.ceil(bytes_moved / (cfg.dbb_bytes_per_cycle * cfg.dbb_eff))),
        config_cycles=n_writes * cfg.csb_cycles_per_access + cfg.op_overhead_cycles,
    )


def model_cost(descs: List[engine.Descriptor], cfg: engine.EngineConfig,
               names: List[str] | None = None,
               backend: Union[str, BackendProfile, None] = None) -> ModelCost:
    names = names or [f"op{i}" for i in range(len(descs))]
    ops = [descriptor_cost(d, cfg, n) for d, n in zip(descs, names)]
    total = sum(o.cycles for o in ops)
    return ModelCost(ops=ops, total_cycles=total,
                     ms_at_clock=cfg.cycles_to_ms(total),
                     kernel_plan=kernel_plan(descs, names, backend,
                                             dtype=cfg.dtype),
                     batched_kernel_plans=batched_kernel_plans(
                         descs, names, backend, dtype=cfg.dtype))
