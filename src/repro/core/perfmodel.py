"""Cycle model for the engine (derives the paper's Tables II/III time columns).

A max(compute, memory) + configuration-overhead model per descriptor:

  compute cycles = MACs / engine.macs
  memory  cycles = bytes moved over the DBB / dbb_bytes_per_cycle
  config  cycles = (#csb writes + #csb polls) * csb_cycles_per_access

The tight coupling + bare-metal claim of the paper shows up here as the config
term: a Linux driver stack pays orders of magnitude more host cycles per op
(syscalls, ioctl marshalling), which is what Table II's comparison against [8]
reflects.  We expose both the raw per-descriptor breakdown and whole-model
totals at the paper's 100 MHz system clock.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core import engine


@dataclasses.dataclass
class OpCost:
    layer: str
    unit: str
    macs: int
    bytes_moved: int
    compute_cycles: int
    memory_cycles: int
    config_cycles: int

    @property
    def cycles(self) -> int:
        return max(self.compute_cycles, self.memory_cycles) + self.config_cycles


@dataclasses.dataclass
class ModelCost:
    ops: List[OpCost]
    total_cycles: int
    ms_at_clock: float

    def dominant(self) -> str:
        c = sum(o.compute_cycles for o in self.ops)
        m = sum(o.memory_cycles for o in self.ops)
        g = sum(o.config_cycles for o in self.ops)
        return max(("compute", c), ("memory", m), ("config", g), key=lambda t: t[1])[0]


def descriptor_cost(d: engine.Descriptor, cfg: engine.EngineConfig,
                    name: str = "") -> OpCost:
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    eb = cfg.elem_bytes
    if d.unit == "CONV":
        r, s = d.kernel
        macs = (c // d.groups) * r * s * k * p * q
        wbytes = k * (c // d.groups) * r * s * eb
        bytes_moved = c * h * w * eb + wbytes + k * 4 * 2 + k * p * q * eb
    elif d.unit == "FC":
        cin = c * h * w
        macs = cin * k
        bytes_moved = cin * eb + k * cin * eb + k * 4 * 2 + k * eb
    elif d.unit == "PDP":
        r, s = d.kernel
        macs = k * p * q * r * s          # adds count as MAC-equivalent work
        bytes_moved = c * h * w * eb + k * p * q * eb
    elif d.unit == "EW":
        macs = k * p * q * 2
        bytes_moved = 2 * c * h * w * eb + k * p * q * eb
    else:
        raise ValueError(d.unit)
    n_writes = len(d.to_reg_writes()) + 1     # + STATUS poll
    return OpCost(
        layer=name, unit=d.unit, macs=macs, bytes_moved=bytes_moved,
        compute_cycles=int(np.ceil(macs / (cfg.macs * cfg.mac_util))),
        memory_cycles=int(np.ceil(bytes_moved / (cfg.dbb_bytes_per_cycle * cfg.dbb_eff))),
        config_cycles=n_writes * cfg.csb_cycles_per_access + cfg.op_overhead_cycles,
    )


def model_cost(descs: List[engine.Descriptor], cfg: engine.EngineConfig,
               names: List[str] | None = None) -> ModelCost:
    names = names or [f"op{i}" for i in range(len(descs))]
    ops = [descriptor_cost(d, cfg, n) for d, n in zip(descs, names)]
    total = sum(o.cycles for o in ops)
    return ModelCost(ops=ops, total_cycles=total, ms_at_clock=cfg.cycles_to_ms(total))
