"""INT8 quantisation + calibration tables (the paper's declared FUTURE WORK).

The paper's nv_small path is INT8-only and its stated limitation is the missing
INT8 *calibration tables* for the NVDLA compiler.  We implement that gap:

  * ``calibrate``   — run sample batches through the fp32 reference network and
    record per-layer activation ranges (percentile of |x|), producing a
    ``CalibrationTable`` (the .json the NVDLA compiler expects).
  * symmetric per-channel INT8 weight quantisation,
  * NVDLA-SDP-style *fixed-point* requantisation.  NVDLA's SDP scales with a 16-bit
    multiplier plus truncation shifts; we mirror that exactly:

        out = clip( rha( rha(acc, pre) * m , post ) )        (rha = round-half-away)

    with ``m`` int16, so every intermediate fits int32 — the whole inference is
    integer-only and bit-exact across executors (VP == bare-metal == linux-stack)
    and across numpy / jax backends.

Scale word packing (one uint32 per channel, written to the SDP scale table):
    word = (m & 0xFFFF) << 16 | (pre & 0xFF) << 8 | (post & 0xFF)
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict

import numpy as np

INT8_MIN, INT8_MAX = -128, 127
M_MAX = (1 << 15) - 1          # int16 multiplier magnitude


@dataclasses.dataclass
class CalibrationTable:
    """Per-layer activation scales: fp_value ≈ int8_value * scale."""
    scales: Dict[str, float]

    def to_json(self) -> str:
        return json.dumps({"layer": {k: {"scale": v} for k, v in self.scales.items()}},
                          indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationTable":
        d = json.loads(text)
        return cls({k: float(v["scale"]) for k, v in d["layer"].items()})


def act_scale(samples: np.ndarray, percentile: float = 99.99) -> float:
    """Symmetric activation scale from |x| percentile (à la TensorRT)."""
    amax = float(np.percentile(np.abs(samples), percentile))
    amax = max(amax, 1e-8)
    return amax / INT8_MAX


def quantize_weights(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-output-channel INT8: returns (w_int8, scales[K])."""
    k = w.shape[0]
    amax = np.abs(w.reshape(k, -1)).max(axis=1)
    amax = np.maximum(amax, 1e-8)
    scales = (amax / INT8_MAX).astype(np.float32)
    q = np.clip(np.round(w / scales.reshape((k,) + (1,) * (w.ndim - 1))),
                INT8_MIN, INT8_MAX).astype(np.int8)
    return q, scales


def quantize_act(x: np.ndarray, scale: float) -> np.ndarray:
    return np.clip(np.round(x / scale), INT8_MIN, INT8_MAX).astype(np.int8)


def quantize_bias(b: np.ndarray, in_scale: float, w_scales: np.ndarray) -> np.ndarray:
    """Bias folded to int32 at accumulator scale (in_scale * w_scale per channel)."""
    return np.round(b / (in_scale * w_scales)).astype(np.int64).clip(
        -2**31, 2**31 - 1).astype(np.int32)


# ---------------------------------------------------------------------------
# Fixed-point scale words
# ---------------------------------------------------------------------------
def fixed_point(mult: float, max_acc: int) -> tuple[int, int, int]:
    """Fold float ``mult`` into (m, pre, post): x*mult ≈ ((x>>pre)*m)>>post.

    ``max_acc`` bounds |x| so that (x>>pre) fits 15 bits and the int32 product
    (x>>pre)*m never overflows.
    """
    if mult <= 0:
        return 0, 0, 0
    pre = max(0, int(max_acc).bit_length() - 15)
    eff = mult * (1 << pre)      # multiplier applied to the pre-shifted value
    post = 0
    while eff * (1 << (post + 1)) <= M_MAX and post < 30:
        post += 1
    m = int(round(eff * (1 << post)))
    if m > M_MAX:
        m >>= 1
        post -= 1
    return m, pre, max(post, 0)


def pack_scale(m: int, pre: int, post: int) -> int:
    return ((m & 0xFFFF) << 16) | ((pre & 0xFF) << 8) | (post & 0xFF)


def unpack_scale(word: int) -> tuple[int, int, int]:
    m = (word >> 16) & 0xFFFF
    if m & 0x8000:
        m -= 0x10000
    return m, (word >> 8) & 0xFF, word & 0xFF


def requant_table(acc_scales: np.ndarray, out_scale: float, max_acc: int) -> np.ndarray:
    """Per-channel uint32 scale-word table for the SDP."""
    words = np.zeros(acc_scales.shape[0], np.uint32)
    for i, sc in enumerate(np.atleast_1d(acc_scales)):
        words[i] = pack_scale(*fixed_point(float(sc) / out_scale, max_acc))
    return words


def rha_shift(x: np.ndarray, k) -> np.ndarray:
    """Round-half-away-from-zero right shift, int32-exact (numpy reference)."""
    x = x.astype(np.int32)
    k = np.asarray(k, np.int32)
    mag = np.abs(x) + np.where(k > 0, np.int32(1) << np.maximum(k - 1, 0), 0)
    return (np.sign(x) * (mag >> k)).astype(np.int32)


def apply_scale(x: np.ndarray, m, pre, post) -> np.ndarray:
    """x*mult in fixed point (numpy reference; the jax twin lives in vp/executor)."""
    t = rha_shift(x, pre)
    return rha_shift(t * np.asarray(m, np.int32), post)


def clip8(x: np.ndarray) -> np.ndarray:
    return np.clip(x, INT8_MIN, INT8_MAX).astype(np.int8)
