"""Bare-metal RISC-V code generation (paper §IV-B2, final step).

Converts a configuration-file Trace into RV32I assembly and assembles it into a
program-memory image.  The paper compiles the equivalent assembly with the Codasip
SDK; we implement the tiny subset assembler ourselves (LUI/ADDI/LW/SW/BNE/JAL use
the real RV32I encodings) so the storage-efficiency numbers (program-memory bytes,
Table I analogue) are measured on a genuine binary.

Generated code shape, per command:

  write_reg A D:      lui/addi t0, A ; lui/addi t1, D ; sw t1, 0(t0)
  read_reg  A E M:    lui/addi t0, A ; lui/addi t1, E ; lui/addi t2, M
                 1:   lw t3, 0(t0) ; and t3, t3, t2 ; bne t3, t1, 1b   (poll)

This is exactly the paper's bare-metal execution model: the core does nothing but
replay stores into the engine's CSB window and poll status reads — no kernel, no
driver, no heap.
"""

from __future__ import annotations

import struct
from typing import List

from repro.core.tracegen import Command, Trace

# register numbers
T0, T1, T2, T3 = 5, 6, 7, 28


def _lui(rd: int, imm20: int) -> int:
    return ((imm20 & 0xFFFFF) << 12) | (rd << 7) | 0x37


def _addi(rd: int, rs1: int, imm12: int) -> int:
    return ((imm12 & 0xFFF) << 20) | (rs1 << 15) | (0 << 12) | (rd << 7) | 0x13


def _sw(rs2: int, rs1: int, imm12: int) -> int:
    imm = imm12 & 0xFFF
    return ((imm >> 5) << 25) | (rs2 << 20) | (rs1 << 15) | (0b010 << 12) | ((imm & 0x1F) << 7) | 0x23


def _lw(rd: int, rs1: int, imm12: int) -> int:
    return ((imm12 & 0xFFF) << 20) | (rs1 << 15) | (0b010 << 12) | (rd << 7) | 0x03


def _and(rd: int, rs1: int, rs2: int) -> int:
    return (rs2 << 20) | (rs1 << 15) | (0b111 << 12) | (rd << 7) | 0x33


def _bne(rs1: int, rs2: int, off: int) -> int:
    imm = off & 0x1FFF
    return (((imm >> 12) & 1) << 31) | (((imm >> 5) & 0x3F) << 25) | (rs2 << 20) \
        | (rs1 << 15) | (0b001 << 12) | (((imm >> 1) & 0xF) << 8) \
        | (((imm >> 11) & 1) << 7) | 0x63


def _jal(rd: int, off: int) -> int:
    imm = off & 0x1FFFFF
    return (((imm >> 20) & 1) << 31) | (((imm >> 1) & 0x3FF) << 21) \
        | (((imm >> 11) & 1) << 20) | (((imm >> 12) & 0xFF) << 12) | (rd << 7) | 0x6F


def _li(rd: int, value: int) -> List[tuple]:
    """Materialise a 32-bit constant: lui + addi (standard li expansion)."""
    value &= 0xFFFFFFFF
    hi = (value + 0x800) >> 12
    lo = value - (hi << 12)
    ops = []
    ops.append(("lui", f"lui x{rd}, {hi:#x}", _lui(rd, hi)))
    ops.append(("addi", f"addi x{rd}, x{rd}, {lo}", _addi(rd, rd, lo)))
    return ops


def assemble(trace: Trace) -> tuple[str, bytes]:
    """Trace -> (assembly text, program-memory binary image)."""
    asm_lines: List[str] = ["# bare-metal NVDLA replay (generated)", ".text", "_start:"]
    words: List[int] = []

    def emit(ops):
        for _, text, word in ops:
            asm_lines.append("    " + text)
            words.append(word)

    for c in trace.commands:
        if c.kind == "write_reg":
            emit(_li(T0, c.addr))
            emit(_li(T1, c.data))
            asm_lines.append(f"    sw x{T1}, 0(x{T0})        # write_reg {c.addr:#x}")
            words.append(_sw(T1, T0, 0))
        else:  # read_reg: poll until (mem[addr] & mask) == expected
            emit(_li(T0, c.addr))
            emit(_li(T1, c.data & c.mask))
            emit(_li(T2, c.mask))
            asm_lines.append(f"poll_{len(words)}:")
            asm_lines.append(f"    lw x{T3}, 0(x{T0})        # read_reg {c.addr:#x}")
            words.append(_lw(T3, T0, 0))
            asm_lines.append(f"    and x{T3}, x{T3}, x{T2}")
            words.append(_and(T3, T3, T2))
            asm_lines.append(f"    bne x{T3}, x{T1}, poll_{len(words) - 2}")
            words.append(_bne(T3, T1, -8))
    # halt: jal x0, 0 (spin)
    asm_lines.append("halt:")
    asm_lines.append("    jal x0, halt")
    words.append(_jal(0, 0))

    binary = b"".join(struct.pack("<I", w) for w in words)
    return "\n".join(asm_lines) + "\n", binary


def disassemble_writes(binary: bytes) -> List[tuple[int, int]]:
    """Recover the (addr, data) store stream from a program image (test helper).

    Walks the binary tracking li-materialised registers and records every
    ``sw t1, 0(t0)``.
    """
    regs = {}
    writes = []
    for i in range(0, len(binary), 4):
        (w,) = struct.unpack("<I", binary[i:i + 4])
        op = w & 0x7F
        if op == 0x37:                                   # lui
            rd = (w >> 7) & 0x1F
            regs[rd] = ((w >> 12) & 0xFFFFF) << 12
        elif op == 0x13 and ((w >> 12) & 7) == 0:        # addi
            rd, rs1 = (w >> 7) & 0x1F, (w >> 15) & 0x1F
            imm = w >> 20
            if imm & 0x800:
                imm -= 0x1000
            regs[rd] = (regs.get(rs1, 0) + imm) & 0xFFFFFFFF
        elif op == 0x23 and ((w >> 12) & 7) == 0b010:    # sw
            rs1, rs2 = (w >> 15) & 0x1F, (w >> 20) & 0x1F
            writes.append((regs.get(rs1, 0), regs.get(rs2, 0)))
    return writes
