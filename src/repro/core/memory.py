"""Static memory planning + weight extraction (paper §IV-B3).

Two pieces:

1. ``ArenaPlanner`` — assigns every weight / bias / scale-table / activation surface a
   static DRAM address before execution (the paper preloads DRAM with a fixed-layout
   weight + input image).  Weights are packed once; activations are placed with a
   liveness-interval first-fit so surfaces whose lifetimes do not overlap share
   memory — the static analogue of malloc that makes the runtime allocation-free.

2. ``extract_weights`` — the paper's weight-file flow: filter DBB transactions, keep
   read transactions (memory fetches == weights), and delete duplicate address
   entries by *retaining the first occurrence*.  Returns the flat weight image.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Tuple

import numpy as np

from repro.core import engine
from repro.core.graph import NetGraph

ALIGN = 64  # DBB beat alignment


def _align(x: int, a: int = ALIGN) -> int:
    return (x + a - 1) & ~(a - 1)


@dataclasses.dataclass
class Surface:
    """One named region of the DRAM arena."""
    name: str
    addr: int          # absolute DRAM address
    size: int          # bytes
    kind: str          # "weight" | "bias" | "scale" | "act" | "input" | "output"


@dataclasses.dataclass
class ArenaPlan:
    surfaces: Dict[str, Surface]
    weight_end: int    # absolute address: end of the static (preloaded) region
    total_end: int     # absolute address: end of the whole arena

    @property
    def arena_size(self) -> int:
        return self.total_end - engine.DRAM_BASE

    def offset(self, name: str) -> int:
        """Offset of a surface inside the flat arena buffer."""
        return self.surfaces[name].addr - engine.DRAM_BASE


def plan_arena(graph: NetGraph, elem_bytes: int, acc_bytes: int = 4) -> ArenaPlan:
    """Assign static addresses for all surfaces of ``graph``.

    Layout (matching the paper's DRAM map, base 0x100000):
      [weights | biases | scale tables]  -- preloaded, immutable
      [activation region]               -- liveness-planned, reused across layers

    ``concat`` layers are handled the NVDLA way: the planner lays the branch outputs
    out adjacently so concatenation is free (pure addressing).
    """
    by = graph.by_name()
    surfaces: Dict[str, Surface] = {}
    cursor = engine.DRAM_BASE

    # ---- static region: weights, biases, per-channel scale tables ----------
    params = graph.init_params(0)
    for lname in (l.name for l in graph.layers if l.name in params):
        l = by[lname]
        p = params[lname]
        wsize = _align(int(p["w"].size))            # int8: 1 byte/elem
        if elem_bytes == 2:
            wsize = _align(int(p["w"].size) * 2)    # bf16 path
        surfaces[f"{lname}.w"] = Surface(f"{lname}.w", cursor, wsize, "weight")
        cursor += wsize
        bsize = _align(int(p["b"].size) * acc_bytes)  # int32/fp32 bias
        surfaces[f"{lname}.b"] = Surface(f"{lname}.b", cursor, bsize, "bias")
        cursor += bsize
        ssize = _align(l.out_channels * 4)          # per-channel (m:int24, s:int8)
        surfaces[f"{lname}.s"] = Surface(f"{lname}.s", cursor, ssize, "scale")
        cursor += ssize
    weight_end = cursor

    # ---- activation region: liveness-interval first-fit --------------------
    # Last use index of each layer output.
    order = {l.name: i for i, l in enumerate(graph.layers)}
    last_use = {l.name: order[l.name] for l in graph.layers}
    for l in graph.layers:
        for inp in l.inputs:
            last_use[inp] = max(last_use[inp], order[l.name])

    # concat members must be placed adjacently inside their concat surface;
    # force them to share the concat's lifetime and skip separate placement.
    concat_member: Dict[str, Tuple[str, int]] = {}
    for l in graph.layers:
        if l.type == "concat":
            off = 0
            for inp in l.inputs:
                member_bytes = int(np.prod(by[inp].out_shape)) * elem_bytes
                concat_member[inp] = (l.name, off)
                off += member_bytes
                last_use[l.name] = max(last_use[l.name], last_use[inp])

    live: List[Tuple[int, int, int]] = []   # (addr, size, free_at_index)
    act_base = weight_end

    def place(size: int, born: int, dies: int) -> int:
        # free expired (strictly-dead-before-birth)
        nonlocal live
        live = [s for s in live if s[2] >= born]
        # first-fit among gaps
        taken = sorted((a, a + s) for a, s, _ in live)
        prev = act_base
        for a, b in taken:
            if a - prev >= size:
                break
            prev = max(prev, b)
        addr = prev
        live.append((addr, size, dies))
        return addr

    # Build the placement worklist: every surface gets (birth, death).  A concat
    # surface is born when its FIRST member is produced (members write straight
    # into it), so it must be placed at that point in liveness order.
    worklist: List[Tuple[int, int, str, int]] = []   # (birth, death, name, size)
    for l in graph.layers:
        if l.type == "input" or l.name in concat_member:
            continue
        size = _align(int(np.prod(l.out_shape)) * elem_bytes)
        if l.type == "concat":
            birth = min(order[i] for i in l.inputs)
        else:
            birth = order[l.name]
        worklist.append((birth, last_use[l.name], l.name, size))

    peak = act_base
    for birth, death, name, size in sorted(worklist):
        addr = place(size, birth, death)
        surfaces[name] = Surface(name, addr, size, "act")
        peak = max(peak, addr + size)

    # concat members alias into the concat surface (resolved after concat placement)
    for inp, (cat, off) in concat_member.items():
        base = surfaces[cat].addr
        size = int(np.prod(by[inp].out_shape)) * elem_bytes
        surfaces[inp] = Surface(inp, base + off, size, "act")

    # graph input gets its own pinned surface at the very end of the static region
    in_size = _align(int(np.prod(graph.input_shape)) * elem_bytes)
    surfaces["data"] = Surface("data", _align(peak), in_size, "input")
    total_end = _align(peak) + in_size

    if total_end - engine.DRAM_BASE > engine.DRAM_SIZE:
        raise MemoryError(f"arena {total_end - engine.DRAM_BASE} exceeds 512MB DRAM window")
    return ArenaPlan(surfaces=surfaces, weight_end=weight_end, total_end=total_end)


# ---------------------------------------------------------------------------
# Weight extraction from the DBB transaction log (paper §IV-B3)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DbbTxn:
    iswrite: int
    addr: int
    data: bytes


def extract_weights(txns: Iterable[DbbTxn]) -> Dict[int, bytes]:
    """Paper §IV-B3: reads (iswrite=0) are weight fetches; duplicates are deleted
    by retaining the FIRST occurrence (the original weights).

    Refinement over the paper's wording: a read of an address the engine itself
    wrote earlier in the run is an *activation* fetch, not preloaded data, so it
    is excluded (the paper's traces behave the same way because NVDLA reads
    weights before producing any output surface at the same address).
    """
    image: Dict[int, bytes] = {}
    written: set = set()
    for t in txns:
        if t.iswrite:
            written.add(t.addr)
        elif t.addr not in image and t.addr not in written:
            image[t.addr] = t.data        # first occurrence wins
    return image


def flatten_image(image: Dict[int, bytes], base: int) -> Tuple[np.ndarray, int]:
    """Pack a sparse {addr: bytes} image into a flat byte array from ``base``.

    Returns (buffer, size).  Gaps are zero-filled (uninitialised DRAM).
    """
    if not image:
        return np.zeros(0, np.uint8), 0
    end = max(a + len(b) for a, b in image.items())
    buf = np.zeros(end - base, np.uint8)
    for a, b in image.items():
        buf[a - base: a - base + len(b)] = np.frombuffer(b, np.uint8)
    return buf, end - base
