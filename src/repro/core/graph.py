"""Caffe-style network graph IR + builders for the paper's evaluated models.

The paper's toolflow consumes *Caffe* models (prototxt + caffemodel).  We model that
input stage with a small layer-graph IR: a topologically ordered list of layers, each
naming its input blobs — the same structure a prototxt describes.  Builders below
construct the six networks evaluated in the paper (Tables II & III): LeNet-5,
ResNet-18, ResNet-50, MobileNet(v1), GoogLeNet and AlexNet.

Layer types (mapping to engine units, see ``core/engine.py``):
  conv  -> CONV+SDP (bias/requant/relu fused, paper's conv pipeline)
  fc    -> FC(+SDP)
  pool  -> PDP (max / avg / global-avg)
  add   -> EW (residual add, two quantised operands rescaled to a common scale)
  concat-> pure address-planning op (no engine work: outputs are laid out adjacently)
  input -> graph input
Activation (ReLU) is a *flag* on conv/fc/add, as in NVDLA's fused SDP datapath.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Layer:
    name: str
    type: str                      # input|conv|fc|pool|add|concat
    inputs: List[str]
    # conv/fc params
    out_channels: int = 0
    kernel: int = 0
    stride: int = 1
    pad: int = 0
    groups: int = 1                # depthwise = groups == in_channels
    relu: bool = False
    # pool params
    pool_mode: str = ""            # "max" | "avg" | "gap"
    # filled by shape inference: (C, H, W) of this layer's output
    out_shape: Optional[tuple] = None


LAYER_TYPES = ("input", "conv", "fc", "pool", "add", "concat")
POOL_MODES = ("max", "avg", "gap")


@dataclasses.dataclass
class NetGraph:
    name: str
    input_shape: tuple             # (C, H, W)
    layers: List[Layer] = dataclasses.field(default_factory=list)
    # sha256 of the source file for imported nets (see ``repro.frontend``);
    # "" for hand-built graphs.  Mixed into compiler cache keys so two
    # imports that share a graph name never collide.
    source_digest: str = ""

    def layer(self, **kw) -> str:
        lyr = Layer(**kw)
        assert lyr.name not in {l.name for l in self.layers}, f"dup layer {lyr.name}"
        self.layers.append(lyr)
        return lyr.name

    def by_name(self) -> Dict[str, Layer]:
        return {l.name: l for l in self.layers}

    @property
    def output(self) -> str:
        return self.layers[-1].name

    # -- structural validation ----------------------------------------------
    def validate(self) -> "NetGraph":
        """Reject malformed graphs with a descriptive ValueError.

        Checks what the downstream stages (arena planner, loadable builder,
        tracegen) silently assume: exactly one input layer named ``data``,
        unique layer names, no dangling/forward references, known layer
        types, and per-layer shape consistency (windows that fit, matching
        ``add`` operands, concat-able spatials).  Called at
        ``CompilerPipeline`` entry so hand-built and imported graphs fail
        the same way, before any compilation work.
        """
        def err(msg: str):
            raise ValueError(f"invalid NetGraph {self.name!r}: {msg}")

        if not self.layers:
            err("graph has no layers")
        if len(self.input_shape) != 3 or any(d <= 0 for d in self.input_shape):
            err(f"input_shape must be a positive (C, H, W), "
                f"got {self.input_shape}")
        seen: Dict[str, Layer] = {}
        for l in self.layers:
            if l.name in seen:
                err(f"duplicate layer name {l.name!r}")
            if l.type not in LAYER_TYPES:
                err(f"layer {l.name!r} has unknown type {l.type!r} "
                    f"(expected one of {', '.join(LAYER_TYPES)})")
            for src in l.inputs:
                if src not in seen:
                    err(f"layer {l.name!r} reads {src!r}, which is not "
                        f"produced by any earlier layer (dangling or "
                        f"forward reference)")
            seen[l.name] = l
        inputs = [l for l in self.layers if l.type == "input"]
        if len(inputs) != 1 or inputs[0].name != "data":
            err(f"graph must have exactly one input layer named 'data' "
                f"(the loadable/arena input contract), got "
                f"{[l.name for l in inputs]}")
        if inputs[0].inputs:
            err("the input layer must not read other layers")

        # per-layer shape consistency, via a local propagation (does not
        # mutate out_shape — infer_shapes() owns that)
        shapes: Dict[str, tuple] = {}
        for l in self.layers:
            if l.type == "input":
                shapes[l.name] = self.input_shape
                continue
            if not l.inputs:
                err(f"layer {l.name!r} ({l.type}) has no inputs")
            if l.type in ("conv", "fc") and l.out_channels <= 0:
                err(f"layer {l.name!r} ({l.type}) needs out_channels > 0")
            if l.type == "conv":
                c, h, w = shapes[l.inputs[0]]
                if l.kernel <= 0 or l.stride <= 0 or l.pad < 0:
                    err(f"conv {l.name!r} has kernel={l.kernel} "
                        f"stride={l.stride} pad={l.pad}")
                if l.groups <= 0 or c % l.groups or l.out_channels % l.groups:
                    err(f"conv {l.name!r}: groups={l.groups} must divide "
                        f"in_channels={c} and out_channels={l.out_channels}")
                if h + 2 * l.pad < l.kernel or w + 2 * l.pad < l.kernel:
                    err(f"conv {l.name!r}: {l.kernel}x{l.kernel} window "
                        f"does not fit {c}x{h}x{w} input with pad={l.pad}")
                shapes[l.name] = (l.out_channels,
                                  (h + 2 * l.pad - l.kernel) // l.stride + 1,
                                  (w + 2 * l.pad - l.kernel) // l.stride + 1)
            elif l.type == "fc":
                shapes[l.name] = (l.out_channels, 1, 1)
            elif l.type == "pool":
                c, h, w = shapes[l.inputs[0]]
                if l.pool_mode not in POOL_MODES:
                    err(f"pool {l.name!r} has pool_mode={l.pool_mode!r} "
                        f"(expected one of {', '.join(POOL_MODES)})")
                if l.pool_mode == "gap":
                    shapes[l.name] = (c, 1, 1)
                else:
                    if l.kernel <= 0 or l.stride <= 0 or l.pad < 0 or \
                            h + 2 * l.pad < l.kernel or \
                            w + 2 * l.pad < l.kernel:
                        err(f"pool {l.name!r}: {l.kernel}x{l.kernel}/"
                            f"{l.stride} window (pad={l.pad}) does not fit "
                            f"{c}x{h}x{w} input")
                    shapes[l.name] = (c,
                                      (h + 2 * l.pad - l.kernel) // l.stride + 1,
                                      (w + 2 * l.pad - l.kernel) // l.stride + 1)
            elif l.type == "add":
                ops = [shapes[i] for i in l.inputs]
                if len(ops) != 2:
                    err(f"add {l.name!r} needs exactly 2 inputs, "
                        f"got {len(ops)}")
                if ops[0] != ops[1]:
                    err(f"add {l.name!r} operand shapes differ: "
                        f"{l.inputs[0]}={ops[0]} vs {l.inputs[1]}={ops[1]}")
                shapes[l.name] = ops[0]
            else:                          # concat
                ops = [shapes[i] for i in l.inputs]
                if len(ops) < 2:
                    err(f"concat {l.name!r} needs >= 2 inputs")
                if any(o[1:] != ops[0][1:] for o in ops):
                    err(f"concat {l.name!r} spatial dims differ: "
                        f"{dict(zip(l.inputs, ops))}")
                shapes[l.name] = (sum(o[0] for o in ops),) + ops[0][1:]
            if any(d <= 0 for d in shapes[l.name]):
                err(f"layer {l.name!r} ({l.type}) infers non-positive "
                    f"output shape {shapes[l.name]}")
        return self

    # -- shape inference ----------------------------------------------------
    def infer_shapes(self) -> "NetGraph":
        shapes: Dict[str, tuple] = {}
        for l in self.layers:
            if l.type == "input":
                shapes[l.name] = self.input_shape
            elif l.type == "conv":
                c, h, w = shapes[l.inputs[0]]
                p = (h + 2 * l.pad - l.kernel) // l.stride + 1
                q = (w + 2 * l.pad - l.kernel) // l.stride + 1
                shapes[l.name] = (l.out_channels, p, q)
            elif l.type == "fc":
                shapes[l.name] = (l.out_channels, 1, 1)
            elif l.type == "pool":
                c, h, w = shapes[l.inputs[0]]
                if l.pool_mode == "gap":
                    shapes[l.name] = (c, 1, 1)
                else:
                    p = (h + 2 * l.pad - l.kernel) // l.stride + 1
                    q = (w + 2 * l.pad - l.kernel) // l.stride + 1
                    shapes[l.name] = (c, p, q)
            elif l.type == "add":
                shapes[l.name] = shapes[l.inputs[0]]
            elif l.type == "concat":
                cs = [shapes[i] for i in l.inputs]
                assert all(c[1:] == cs[0][1:] for c in cs)
                shapes[l.name] = (sum(c[0] for c in cs),) + cs[0][1:]
            else:
                raise ValueError(l.type)
            l.out_shape = shapes[l.name]
        return self

    # -- parameter initialisation -------------------------------------------
    def init_params(self, seed: int = 0) -> Dict[str, Dict[str, np.ndarray]]:
        """He-init float32 weights, shaped (K, C/groups, R, S) / fc (K, C)."""
        rng = np.random.default_rng(seed)
        shapes = {l.name: l.out_shape for l in self.layers}
        params: Dict[str, Dict[str, np.ndarray]] = {}
        by = self.by_name()
        for l in self.layers:
            if l.type == "conv":
                cin = by[l.inputs[0]].out_shape[0] if by[l.inputs[0]].out_shape else self.input_shape[0]
                cin_g = cin // l.groups
                fan_in = cin_g * l.kernel * l.kernel
                w = rng.normal(0, np.sqrt(2.0 / fan_in),
                               (l.out_channels, cin_g, l.kernel, l.kernel)).astype(np.float32)
                b = rng.normal(0, 0.05, (l.out_channels,)).astype(np.float32)
                params[l.name] = {"w": w, "b": b}
            elif l.type == "fc":
                cin = int(np.prod(by[l.inputs[0]].out_shape))
                w = rng.normal(0, np.sqrt(2.0 / cin), (l.out_channels, cin)).astype(np.float32)
                b = rng.normal(0, 0.05, (l.out_channels,)).astype(np.float32)
                params[l.name] = {"w": w, "b": b}
        return params

    def num_params(self) -> int:
        return sum(int(a.size) for p in self.init_params(0).values() for a in p.values())

    def macs(self) -> int:
        """Total multiply-accumulates for one inference (for the cycle model)."""
        total = 0
        by = self.by_name()
        for l in self.layers:
            if l.type == "conv":
                cin = by[l.inputs[0]].out_shape[0]
                k, p, q = l.out_shape
                total += (cin // l.groups) * l.kernel * l.kernel * k * p * q
            elif l.type == "fc":
                total += int(np.prod(by[l.inputs[0]].out_shape)) * l.out_channels
        return total


# ===========================================================================
# Model builders (paper Tables II & III)
# ===========================================================================
def lenet5() -> NetGraph:
    """LeNet-5, 1x28x28 input (paper: 9 layers incl. input/softmax bookkeeping)."""
    g = NetGraph("lenet5", (1, 28, 28))
    g.layer(name="data", type="input", inputs=[])
    g.layer(name="conv1", type="conv", inputs=["data"], out_channels=6, kernel=5, pad=2, relu=True)
    g.layer(name="pool1", type="pool", inputs=["conv1"], kernel=2, stride=2, pool_mode="max")
    g.layer(name="conv2", type="conv", inputs=["pool1"], out_channels=16, kernel=5, relu=True)
    g.layer(name="pool2", type="pool", inputs=["conv2"], kernel=2, stride=2, pool_mode="max")
    g.layer(name="fc1", type="fc", inputs=["pool2"], out_channels=120, relu=True)
    g.layer(name="fc2", type="fc", inputs=["fc1"], out_channels=84, relu=True)
    g.layer(name="fc3", type="fc", inputs=["fc2"], out_channels=10)
    return g.infer_shapes()


def _res_basic(g: NetGraph, name: str, x: str, cin: int, cout: int, stride: int) -> str:
    c1 = g.layer(name=f"{name}_c1", type="conv", inputs=[x], out_channels=cout,
                 kernel=3, stride=stride, pad=1, relu=True)
    c2 = g.layer(name=f"{name}_c2", type="conv", inputs=[c1], out_channels=cout,
                 kernel=3, stride=1, pad=1)
    if stride != 1 or cin != cout:
        x = g.layer(name=f"{name}_sc", type="conv", inputs=[x], out_channels=cout,
                    kernel=1, stride=stride)
    return g.layer(name=f"{name}_add", type="add", inputs=[c2, x], relu=True)


def _res_bottleneck(g: NetGraph, name: str, x: str, cin: int, cmid: int, stride: int) -> str:
    cout = cmid * 4
    c1 = g.layer(name=f"{name}_c1", type="conv", inputs=[x], out_channels=cmid, kernel=1, relu=True)
    c2 = g.layer(name=f"{name}_c2", type="conv", inputs=[c1], out_channels=cmid,
                 kernel=3, stride=stride, pad=1, relu=True)
    c3 = g.layer(name=f"{name}_c3", type="conv", inputs=[c2], out_channels=cout, kernel=1)
    if stride != 1 or cin != cout:
        x = g.layer(name=f"{name}_sc", type="conv", inputs=[x], out_channels=cout,
                    kernel=1, stride=stride)
    return g.layer(name=f"{name}_add", type="add", inputs=[c3, x], relu=True)


def resnet18() -> NetGraph:
    """ResNet-18 on 3x32x32 (paper Table II input).

    Uses the standard ImageNet-style stride-2 stem (7x7/2 + maxpool/2): the
    paper's 86-layer prototxt and its 16.2 ms @100MHz measurement are only
    consistent with the downsampling stem (~35 MMACs at 32x32), not with the
    CIFAR 3x3/1 stem (~557 MMACs).
    """
    g = NetGraph("resnet18", (3, 32, 32))
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="stem", type="conv", inputs=["data"], out_channels=64,
                kernel=7, stride=2, pad=3, relu=True)
    x = g.layer(name="stem_pool", type="pool", inputs=[x], kernel=3, stride=2,
                pad=1, pool_mode="max")
    cin = 64
    for stage, (cout, blocks, stride) in enumerate([(64, 2, 1), (128, 2, 2),
                                                    (256, 2, 2), (512, 2, 2)]):
        for b in range(blocks):
            x = _res_basic(g, f"s{stage}b{b}", x, cin, cout, stride if b == 0 else 1)
            cin = cout
    x = g.layer(name="gap", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=10)
    return g.infer_shapes()


def resnet50() -> NetGraph:
    """ResNet-50 on 3x224x224 (paper Table II/III input)."""
    g = NetGraph("resnet50", (3, 224, 224))
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="stem", type="conv", inputs=["data"], out_channels=64,
                kernel=7, stride=2, pad=3, relu=True)
    x = g.layer(name="stem_pool", type="pool", inputs=[x], kernel=3, stride=2,
                pad=1, pool_mode="max")
    cin = 64
    for stage, (cmid, blocks, stride) in enumerate([(64, 3, 1), (128, 4, 2),
                                                    (256, 6, 2), (512, 3, 2)]):
        for b in range(blocks):
            x = _res_bottleneck(g, f"s{stage}b{b}", x, cin, cmid, stride if b == 0 else 1)
            cin = cmid * 4
    x = g.layer(name="gap", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=1000)
    return g.infer_shapes()


def alexnet() -> NetGraph:
    """AlexNet on 3x227x227 (paper Table III input)."""
    g = NetGraph("alexnet", (3, 227, 227))
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="conv1", type="conv", inputs=["data"], out_channels=96,
                kernel=11, stride=4, relu=True)
    x = g.layer(name="pool1", type="pool", inputs=[x], kernel=3, stride=2, pool_mode="max")
    x = g.layer(name="conv2", type="conv", inputs=[x], out_channels=256, kernel=5,
                pad=2, relu=True)
    x = g.layer(name="pool2", type="pool", inputs=[x], kernel=3, stride=2, pool_mode="max")
    x = g.layer(name="conv3", type="conv", inputs=[x], out_channels=384, kernel=3,
                pad=1, relu=True)
    x = g.layer(name="conv4", type="conv", inputs=[x], out_channels=384, kernel=3,
                pad=1, relu=True)
    x = g.layer(name="conv5", type="conv", inputs=[x], out_channels=256, kernel=3,
                pad=1, relu=True)
    x = g.layer(name="pool5", type="pool", inputs=[x], kernel=3, stride=2, pool_mode="max")
    x = g.layer(name="fc6", type="fc", inputs=[x], out_channels=4096, relu=True)
    x = g.layer(name="fc7", type="fc", inputs=[x], out_channels=4096, relu=True)
    g.layer(name="fc8", type="fc", inputs=[x], out_channels=1000)
    return g.infer_shapes()


def mobilenet_v1() -> NetGraph:
    """MobileNet v1 on 3x224x224 (paper Table III input); depthwise-separable convs."""
    g = NetGraph("mobilenet", (3, 224, 224))
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="stem", type="conv", inputs=["data"], out_channels=32,
                kernel=3, stride=2, pad=1, relu=True)
    cin = 32
    cfg = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
           (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1)]
    for i, (cout, stride) in enumerate(cfg):
        x = g.layer(name=f"dw{i}", type="conv", inputs=[x], out_channels=cin,
                    kernel=3, stride=stride, pad=1, groups=cin, relu=True)
        x = g.layer(name=f"pw{i}", type="conv", inputs=[x], out_channels=cout,
                    kernel=1, relu=True)
        cin = cout
    x = g.layer(name="gap", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=1000)
    return g.infer_shapes()


def _inception(g: NetGraph, name: str, x: str, c1: int, c3r: int, c3: int,
               c5r: int, c5: int, cp: int) -> str:
    b1 = g.layer(name=f"{name}_1x1", type="conv", inputs=[x], out_channels=c1, kernel=1, relu=True)
    b2a = g.layer(name=f"{name}_3x3r", type="conv", inputs=[x], out_channels=c3r, kernel=1, relu=True)
    b2 = g.layer(name=f"{name}_3x3", type="conv", inputs=[b2a], out_channels=c3,
                 kernel=3, pad=1, relu=True)
    b3a = g.layer(name=f"{name}_5x5r", type="conv", inputs=[x], out_channels=c5r, kernel=1, relu=True)
    b3 = g.layer(name=f"{name}_5x5", type="conv", inputs=[b3a], out_channels=c5,
                 kernel=5, pad=2, relu=True)
    b4a = g.layer(name=f"{name}_pool", type="pool", inputs=[x], kernel=3, stride=1,
                  pad=1, pool_mode="max")
    b4 = g.layer(name=f"{name}_poolp", type="conv", inputs=[b4a], out_channels=cp,
                 kernel=1, relu=True)
    return g.layer(name=f"{name}_cat", type="concat", inputs=[b1, b2, b3, b4])


def googlenet() -> NetGraph:
    """GoogLeNet on 3x224x224 (paper Table III input)."""
    g = NetGraph("googlenet", (3, 224, 224))
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="conv1", type="conv", inputs=["data"], out_channels=64,
                kernel=7, stride=2, pad=3, relu=True)
    x = g.layer(name="pool1", type="pool", inputs=[x], kernel=3, stride=2, pad=1, pool_mode="max")
    x = g.layer(name="conv2r", type="conv", inputs=[x], out_channels=64, kernel=1, relu=True)
    x = g.layer(name="conv2", type="conv", inputs=[x], out_channels=192, kernel=3,
                pad=1, relu=True)
    x = g.layer(name="pool2", type="pool", inputs=[x], kernel=3, stride=2, pad=1, pool_mode="max")
    x = _inception(g, "i3a", x, 64, 96, 128, 16, 32, 32)
    x = _inception(g, "i3b", x, 128, 128, 192, 32, 96, 64)
    x = g.layer(name="pool3", type="pool", inputs=[x], kernel=3, stride=2, pad=1, pool_mode="max")
    x = _inception(g, "i4a", x, 192, 96, 208, 16, 48, 64)
    x = _inception(g, "i4b", x, 160, 112, 224, 24, 64, 64)
    x = _inception(g, "i4c", x, 128, 128, 256, 24, 64, 64)
    x = _inception(g, "i4d", x, 112, 144, 288, 32, 64, 64)
    x = _inception(g, "i4e", x, 256, 160, 320, 32, 128, 128)
    x = g.layer(name="pool4", type="pool", inputs=[x], kernel=3, stride=2, pad=1, pool_mode="max")
    x = _inception(g, "i5a", x, 256, 160, 320, 32, 128, 128)
    x = _inception(g, "i5b", x, 384, 192, 384, 48, 128, 128)
    x = g.layer(name="gap", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=1000)
    return g.infer_shapes()


BUILDERS = {
    "lenet5": lenet5,
    "resnet18": resnet18,
    "resnet50": resnet50,
    "alexnet": alexnet,
    "mobilenet": mobilenet_v1,
    "googlenet": googlenet,
}
