"""Executors: bare-metal (the paper's contribution) vs linux-stack (the baseline).

``BareMetalExecutor`` consumes ONLY the two bare-metal artifacts — the configuration
file (trace) and the extracted weight image — exactly like the paper's µRISC-V
binary.  It decodes the register stream back into engine descriptors and binds the
*entire* network into one jitted XLA program over a single flat DRAM arena:
one binary, zero per-layer dispatch, zero runtime allocation.  This is the
TPU-native analogue of replaying stores from bare-metal assembly.

``LinuxStackExecutor`` models the driver-stack deployments the paper compares
against ([5]-[12]): one executable per layer, a driver-managed tensor table
(dict keyed by DRAM address), per-op submission from the host — i.e. real,
measured software overhead on the same op semantics (no simulated sleeps).

Both executors implement BOTH engine datapaths, dispatched on
``EngineConfig.dtype``:

  * ``int8`` (nv_small) — integer ops, bit-identical to the VP functional
    model; tests assert byte equality.
  * ``bf16`` (nv_full)  — bfloat16 weights/activations at 2 bytes/element in
    the same flat arena, float32 accumulation, f32 bias, no requantisation.
    bf16 products are exact in f32, so the only implementation freedom is f32
    summation order — parity against the VP is therefore *tolerance-bounded*
    (``core/tolerances.py``), never bit-asserted.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import zlib
from typing import Dict, List, Optional, Protocol, Sequence, Union, runtime_checkable

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core import engine, intmath, perfmodel, quant
from repro.core.tracegen import Trace
from repro.kernels import bf16_conv, int8_conv


# ---------------------------------------------------------------------------
# jnp twins of the integer engine semantics (bit-exact vs core/refops.py) —
# one shared copy in core/intmath.py, also used by the Pallas kernel family
# ---------------------------------------------------------------------------
_rha_shift = intmath.rha_shift
_apply_scale = intmath.apply_scale
_unpack_words = intmath.unpack_words
_clip8 = intmath.clip8


def _dot_i8_f32(a, b, dnums):
    """One exact f32 GEMM tile -> int32 (caller guarantees K <= EXACT_K)."""
    # Precision.HIGHEST forces true f32 accumulation — the default matmul
    # precision is tf32/bf16 on GPU/TPU, which would break the exactness
    # proof (products need 15 significand bits).
    acc = jax.lax.dot_general(a.astype(jnp.float32), b.astype(jnp.float32),
                              dnums, preferred_element_type=jnp.float32,
                              precision=jax.lax.Precision.HIGHEST)
    return acc.astype(jnp.int32)


def _dot_i8(a, b, dnums, contract_k: int,
            kernel: str = perfmodel.KERNEL_GEMM_TILED):
    """int8 x int8 -> int32 dot_general on the wide f32 units, exact for ANY K.

    XLA CPU lowers integer GEMMs to scalar loops; the f32 units are far wider.
    Every int8*int8 product has magnitude <= 128*128 = 16384 (both operands
    can be -128), so while the worst-case partial sum K * 16384 stays within
    2^24 every partial sum is an exactly representable f32 integer regardless
    of summation order — the float GEMM returns bit-identical int32
    accumulators.  For K > EXACT_K (= 1024) the contraction is split into
    K-tiles that each satisfy the bound; each tile's f32 accumulator converts
    to int32 exactly and the tiles are summed in int32, which cannot overflow
    (the true accumulator already fits int32 by the engine's design).  The
    scalar integer ``dot_general`` path no longer exists.
    """
    if contract_k <= perfmodel.EXACT_K:
        return _dot_i8_f32(a, b, dnums)
    if kernel == perfmodel.KERNEL_GEMM_EXACT:
        raise ValueError(f"gemm_f32_exact forced for K={contract_k} > "
                         f"{perfmodel.EXACT_K}: not bit-exact")
    (ca,), (cb,) = dnums[0]
    acc = None
    for lo in range(0, contract_k, perfmodel.EXACT_K):
        hi = min(lo + perfmodel.EXACT_K, contract_k)
        part = _dot_i8_f32(jax.lax.slice_in_dim(a, lo, hi, axis=ca),
                           jax.lax.slice_in_dim(b, lo, hi, axis=cb), dnums)
        acc = part if acc is None else acc + part
    return acc


def _pallas_interpret() -> bool:
    """Run the fused kernels through the Pallas interpreter off-TPU."""
    return jax.default_backend() != "tpu"


_im2col = intmath.im2col


def _conv_int8(x, wq, bias, words, k, stride, pad, groups, relu,
               kernel: str = perfmodel.KERNEL_GEMM_TILED):
    if kernel == perfmodel.KERNEL_PALLAS:
        # whole CONV->SDP pipeline fused in the Pallas kernel (epilogue
        # included) — the int32 accumulator never leaves VMEM
        return int8_conv.conv2d_int8(x, wq, bias, words, k, stride, pad,
                                     groups, relu, interpret=_pallas_interpret())
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = _im2col(x, k, stride, pad)
        acc = _dot_i8(wq, cols, (((1,), (0,)), ((), ())), c * k * k, kernel)
    else:
        cg, kg = c // groups, kk // groups
        xg = x.reshape(groups, cg, h, w_in)
        colsg = jax.vmap(lambda xx: _im2col(xx, k, stride, pad))(xg)
        wg = wq.reshape(groups, kg, cg * k * k)
        acc = _dot_i8(wg, colsg, (((2,), (1,)), ((0,), (0,))), cg * k * k,
                      kernel)
        acc = acc.reshape(kk, p * q)
    return intmath.row_epilogue(acc, bias, words, relu).reshape(kk, p, q)


def _fc_int8(x, wq, bias, words, relu,
             kernel: str = perfmodel.KERNEL_GEMM_TILED):
    if kernel == perfmodel.KERNEL_PALLAS:
        return int8_conv.fc_int8(x.reshape(-1), wq, bias, words, relu,
                                 interpret=_pallas_interpret())
    acc = _dot_i8(wq, x.reshape(-1, 1), (((1,), (0,)), ((), ())),
                  int(wq.shape[1]), kernel)
    return intmath.row_epilogue(acc, bias, words, relu).reshape(-1, 1, 1)


def _conv_int8_batch(xs, wq, bias, words, k, stride, pad, groups, relu,
                     kernel: str = perfmodel.KERNEL_GEMM_TILED):
    """Natively batched CONV twin: (B,C,H,W) -> (B,K,P,Q) as ONE GEMM/launch.

    The lanes fold onto the GEMM's N axis (column index = lane * PQ + pos),
    so the weight matrix streams once per bucket instead of once per vmapped
    lane.  GEMM columns are independent — neither any product nor any
    column's accumulation order changes — so this is bit-exact vs vmapping
    ``_conv_int8`` over the lanes, for the Pallas kernel and the exact f32
    GEMM alike.
    """
    if kernel == perfmodel.KERNEL_PALLAS:
        return int8_conv.conv2d_int8_batch(xs, wq, bias, words, k, stride,
                                           pad, groups, relu,
                                           interpret=_pallas_interpret())
    b, c, h, w_in = xs.shape
    kk = wq.shape[0]
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = jax.vmap(lambda x: _im2col(x, k, stride, pad))(xs)
        folded = jnp.moveaxis(cols, 0, 1).reshape(c * k * k, b * p * q)
        acc = _dot_i8(wq, folded, (((1,), (0,)), ((), ())), c * k * k, kernel)
    else:
        cg, kg = c // groups, kk // groups
        xg = xs.reshape(b, groups, cg, h, w_in)
        colsg = jax.vmap(jax.vmap(lambda xx: _im2col(xx, k, stride, pad)))(xg)
        folded = colsg.transpose(1, 2, 0, 3).reshape(groups, cg * k * k,
                                                     b * p * q)
        wg = wq.reshape(groups, kg, cg * k * k)
        acc = _dot_i8(wg, folded, (((2,), (1,)), ((0,), (0,))), cg * k * k,
                      kernel)
        acc = acc.reshape(kk, b * p * q)
    y = intmath.row_epilogue(acc, bias, words, relu)
    return jnp.moveaxis(y.reshape(kk, b, p * q), 0, 1).reshape(b, kk, p, q)


def _fc_int8_batch(xs, wq, bias, words, relu,
                   kernel: str = perfmodel.KERNEL_GEMM_TILED):
    """Natively batched FC twin: the bucket IS the GEMM N axis — (K, Cin)
    streams once against a (Cin, B) activation block instead of B GEMVs."""
    b = xs.shape[0]
    if kernel == perfmodel.KERNEL_PALLAS:
        return int8_conv.fc_int8_batch(xs.reshape(b, -1), wq, bias, words,
                                       relu, interpret=_pallas_interpret())
    acc = _dot_i8(wq, xs.reshape(b, -1).T, (((1,), (0,)), ((), ())),
                  int(wq.shape[1]), kernel)
    y = intmath.row_epilogue(acc, bias, words, relu)
    return y.T.reshape(b, -1, 1, 1)


def _pool_int8(x, kern, stride, pad, mode, scale_word):
    c, h, w = x.shape
    r, s = kern
    if mode == 1:      # max
        xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=-128)
        p = (h + 2 * pad - r) // stride + 1
        q = (w + 2 * pad - s) // stride + 1
        out = jnp.full((c, p, q), -128, jnp.int8)
        for i in range(r):
            for j in range(s):
                out = jnp.maximum(out, xp[:, i:i + stride * p:stride, j:j + stride * q:stride])
        return out
    xp = jnp.pad(x.astype(jnp.int32), ((0, 0), (pad, pad), (pad, pad)))
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    acc = jnp.zeros((c, p, q), jnp.int32)
    for i in range(r):
        for j in range(s):
            acc = acc + xp[:, i:i + stride * p:stride, j:j + stride * q:stride]
    m, pre, post = quant.unpack_scale(scale_word)
    return _clip8(_apply_scale(acc, m, pre, post))


def _add_int8(a, b, word_a, word_b, relu):
    ma, pa, sa = quant.unpack_scale(word_a)
    mb, pb, sb = quant.unpack_scale(word_b)
    acc = (_apply_scale(a.astype(jnp.int32), ma, pa, sa)
           + _apply_scale(b.astype(jnp.int32), mb, pb, sb))
    if relu:
        acc = jnp.maximum(acc, 0)
    return _clip8(acc)


# ---------------------------------------------------------------------------
# bf16 (nv_full) twins — bf16 operands, f32 accumulate, no requantisation.
# Same jnp twins pattern as the int8 family above; the independent oracle is
# numpy core/refops.conv_bf16 (the VP), compared under core/tolerances.py.
# ---------------------------------------------------------------------------
def _conv_bf16(x, wq, bias, k, stride, pad, groups, relu,
               kernel: str = perfmodel.KERNEL_GEMM_BF16):
    if kernel == perfmodel.KERNEL_PALLAS_BF16:
        # whole CONV->SDP pipeline fused in the Pallas kernel — the f32
        # accumulator never leaves VMEM
        return bf16_conv.conv2d_bf16(x, wq, bias, k, stride, pad, groups,
                                     relu, interpret=_pallas_interpret())
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = _im2col(x, k, stride, pad)
        acc = jax.lax.dot_general(wq, cols, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    else:
        cg, kg = c // groups, kk // groups
        xg = x.reshape(groups, cg, h, w_in)
        colsg = jax.vmap(lambda xx: _im2col(xx, k, stride, pad))(xg)
        wg = wq.reshape(groups, kg, cg * k * k)
        acc = jax.lax.dot_general(wg, colsg, (((2,), (1,)), ((0,), (0,))),
                                  preferred_element_type=jnp.float32)
        acc = acc.reshape(kk, p * q)
    acc = acc + bias[:, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(jnp.bfloat16).reshape(kk, p, q)


def _fc_bf16(x, wq, bias, relu, kernel: str = perfmodel.KERNEL_GEMM_BF16):
    if kernel == perfmodel.KERNEL_PALLAS_BF16:
        return bf16_conv.fc_bf16(x.reshape(-1), wq, bias, relu,
                                 interpret=_pallas_interpret())
    acc = jax.lax.dot_general(wq, x.reshape(-1, 1), (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc = acc + bias[:, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(jnp.bfloat16).reshape(-1, 1, 1)


def _conv_bf16_batch(xs, wq, bias, k, stride, pad, groups, relu,
                     kernel: str = perfmodel.KERNEL_GEMM_BF16):
    """Natively batched bf16 CONV twin: lanes fold onto the GEMM N axis.

    Folding preserves each column's f32 accumulation order, so this is
    bit-identical to vmapping ``_conv_bf16`` over the lanes.
    """
    if kernel == perfmodel.KERNEL_PALLAS_BF16:
        return bf16_conv.conv2d_bf16_batch(xs, wq, bias, k, stride, pad,
                                           groups, relu,
                                           interpret=_pallas_interpret())
    b, c, h, w_in = xs.shape
    kk = wq.shape[0]
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = jax.vmap(lambda x: _im2col(x, k, stride, pad))(xs)
        folded = jnp.moveaxis(cols, 0, 1).reshape(c * k * k, b * p * q)
        acc = jax.lax.dot_general(wq, folded, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    else:
        cg, kg = c // groups, kk // groups
        xg = xs.reshape(b, groups, cg, h, w_in)
        colsg = jax.vmap(jax.vmap(lambda xx: _im2col(xx, k, stride, pad)))(xg)
        folded = colsg.transpose(1, 2, 0, 3).reshape(groups, cg * k * k,
                                                     b * p * q)
        wg = wq.reshape(groups, kg, cg * k * k)
        acc = jax.lax.dot_general(wg, folded, (((2,), (1,)), ((0,), (0,))),
                                  preferred_element_type=jnp.float32)
        acc = acc.reshape(kk, b * p * q)
    acc = acc + bias[:, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    y = acc.astype(jnp.bfloat16)
    return jnp.moveaxis(y.reshape(kk, b, p * q), 0, 1).reshape(b, kk, p, q)


def _fc_bf16_batch(xs, wq, bias, relu,
                   kernel: str = perfmodel.KERNEL_GEMM_BF16):
    """Natively batched bf16 FC twin — one (K, Cin) x (Cin, B) GEMM."""
    b = xs.shape[0]
    if kernel == perfmodel.KERNEL_PALLAS_BF16:
        return bf16_conv.fc_bf16_batch(xs.reshape(b, -1), wq, bias, relu,
                                       interpret=_pallas_interpret())
    acc = jax.lax.dot_general(wq, xs.reshape(b, -1).T,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc = acc + bias[:, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(jnp.bfloat16).T.reshape(b, -1, 1, 1)


def _pool_bf16(x, kern, stride, pad, mode):
    """PDP in float: max with -inf fill, avg as f32 sum / window (the gap
    descriptor is avg with kernel == (H, W), which reduces to the mean)."""
    x32 = x.astype(jnp.float32)
    c, h, w = x.shape
    r, s = kern
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    if mode == 1:      # max
        xp = jnp.pad(x32, ((0, 0), (pad, pad), (pad, pad)),
                     constant_values=-jnp.inf)
        out = jnp.full((c, p, q), -jnp.inf, jnp.float32)
        for i in range(r):
            for j in range(s):
                out = jnp.maximum(out, xp[:, i:i + stride * p:stride,
                                          j:j + stride * q:stride])
        return out.astype(jnp.bfloat16)
    xp = jnp.pad(x32, ((0, 0), (pad, pad), (pad, pad)))
    acc = jnp.zeros((c, p, q), jnp.float32)
    for i in range(r):
        for j in range(s):
            acc = acc + xp[:, i:i + stride * p:stride, j:j + stride * q:stride]
    return (acc / (r * s)).astype(jnp.bfloat16)


def _add_bf16(a, b, relu):
    acc = a.astype(jnp.float32) + b.astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(jnp.bfloat16)


def _bf16_to_bytes(y):
    """bf16 tensor -> its flat byte stream (int8), for arena stores."""
    return jax.lax.bitcast_convert_type(y.astype(jnp.bfloat16).reshape(-1),
                                        jnp.int8).reshape(-1)


def _bytes_to_bf16(raw, shape):
    """Flat byte stream (int8, length 2*n) -> bf16 tensor of ``shape``."""
    return jax.lax.bitcast_convert_type(raw.reshape(-1, 2),
                                        jnp.bfloat16).reshape(shape)


def _bf16_to_bytes_batch(y):
    """(B, ...) bf16 tensor -> (B, bytes) int8, per-lane byte layout
    identical to ``_bf16_to_bytes`` on each lane."""
    b = y.shape[0]
    return jax.lax.bitcast_convert_type(
        y.astype(jnp.bfloat16).reshape(b, -1), jnp.int8).reshape(b, -1)


# ---------------------------------------------------------------------------
# Descriptor -> op closure over the flat arena
# ---------------------------------------------------------------------------
def _surface_bytes(dims, elem_bytes: int) -> int:
    n, c, h, w = dims
    return c * h * w * elem_bytes


def _op_from_descriptor(d: engine.Descriptor, base: int, elem_bytes: int,
                        kernel: str = perfmodel.KERNEL_GEMM_TILED):
    """Build f(arena)->arena for one INT8 descriptor (addresses become static
    offsets).  The bf16 twin is ``_op_from_descriptor_bf16``."""
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so, do = d.src_addr - base, d.dst_addr - base
    s_sz, d_sz = _surface_bytes(d.src_dims, elem_bytes), _surface_bytes(d.dst_dims, elem_bytes)

    def read_i8(arena, off, n_, shape):
        return jax.lax.dynamic_slice(arena, (off,), (n_,)).reshape(shape)

    def read_i32(arena, off, n_):
        raw = jax.lax.dynamic_slice(arena, (off,), (n_ * 4,)).reshape(n_, 4)
        return jax.lax.bitcast_convert_type(raw, jnp.int32)

    if d.unit in ("CONV", "FC"):
        r, s = d.kernel
        cin_g = c // d.groups if d.unit == "CONV" else c * h * w
        wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
        wo, bo, sco = d.wt_addr - base, d.bias_addr - base, d.scale_addr - base

        def op(arena):
            x = read_i8(arena, so, s_sz, (c, h, w))
            wq = read_i8(arena, wo, wt_n, (k, -1))
            bias = read_i32(arena, bo, k)
            words = read_i32(arena, sco, k)
            if d.unit == "CONV":
                y = _conv_int8(x, wq, bias, words, r, d.stride, d.pad,
                               d.groups, d.relu, kernel)
            else:
                y = _fc_int8(x, wq, bias, words, d.relu, kernel)
            return jax.lax.dynamic_update_slice(arena, y.reshape(-1), (do,))
    elif d.unit == "PDP":
        word = engine._pack_scale(d.out_scale)

        def op(arena):
            x = read_i8(arena, so, s_sz, (c, h, w))
            y = _pool_int8(x, d.kernel, d.stride, d.pad, d.pool_mode, word)
            return jax.lax.dynamic_update_slice(arena, y.reshape(-1), (do,))
    elif d.unit == "EW":
        ao = d.aux_addr - base
        wa, wb = engine._pack_scale(d.out_scale), engine._pack_scale(d.aux_scale)

        def op(arena):
            a = read_i8(arena, so, s_sz, (c, h, w))
            b = read_i8(arena, ao, s_sz, (c, h, w))
            y = _add_int8(a, b, wa, wb, d.relu)
            return jax.lax.dynamic_update_slice(arena, y.reshape(-1), (do,))
    else:
        raise ValueError(d.unit)
    return op


def _op_from_descriptor_bf16(d: engine.Descriptor, base: int,
                             kernel: str = perfmodel.KERNEL_GEMM_BF16):
    """Build f(arena)->arena for one BF16 descriptor.

    The arena stays a flat int8 byte buffer (exactly the preloaded DRAM
    image); bf16 surfaces are bitcast in and out at 2 bytes/element, f32 bias
    vectors at 4.
    """
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so, do = d.src_addr - base, d.dst_addr - base
    s_n = c * h * w                       # elements, not bytes

    def read_bf16(arena, off, n_, shape):
        raw = jax.lax.dynamic_slice(arena, (off,), (n_ * 2,))
        return _bytes_to_bf16(raw, shape)

    def read_f32(arena, off, n_):
        raw = jax.lax.dynamic_slice(arena, (off,), (n_ * 4,)).reshape(n_, 4)
        return jax.lax.bitcast_convert_type(raw, jnp.float32)

    def write(arena, y):
        return jax.lax.dynamic_update_slice(arena, _bf16_to_bytes(y), (do,))

    if d.unit in ("CONV", "FC"):
        r, s = d.kernel
        cin_g = c // d.groups if d.unit == "CONV" else c * h * w
        wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
        wo, bo = d.wt_addr - base, d.bias_addr - base

        def op(arena):
            x = read_bf16(arena, so, s_n, (c, h, w))
            wq = read_bf16(arena, wo, wt_n, (k, -1))
            bias = read_f32(arena, bo, k)
            if d.unit == "CONV":
                y = _conv_bf16(x, wq, bias, r, d.stride, d.pad, d.groups,
                               d.relu, kernel)
            else:
                y = _fc_bf16(x, wq, bias, d.relu, kernel)
            return write(arena, y)
    elif d.unit == "PDP":
        def op(arena):
            x = read_bf16(arena, so, s_n, (c, h, w))
            return write(arena, _pool_bf16(x, d.kernel, d.stride, d.pad,
                                           d.pool_mode))
    elif d.unit == "EW":
        ao = d.aux_addr - base

        def op(arena):
            a = read_bf16(arena, so, s_n, (c, h, w))
            b = read_bf16(arena, ao, s_n, (c, h, w))
            return write(arena, _add_bf16(a, b, d.relu))
    else:
        raise ValueError(d.unit)
    return op


def _overlaps(a: tuple, b: tuple) -> bool:
    return a[0] < b[0] + b[1] and b[0] < a[0] + a[1]


def _batch_plan(descs, input_region: tuple, elem_bytes: int = 1):
    """Dataflow analysis for the batched program.

    For op ``i``: ``fwd[i]`` — its source region is exactly the previous
    producer's destination (the previous op, or the input surface for op 0),
    so the value is forwarded tensor-to-tensor instead of read back from the
    activation arena; ``store[i]`` — some *other* later read overlaps its
    destination (concat consumers, EW residuals, partial reads), so the value
    must also be stored to the arena.  Forwarding changes only where bytes are
    read from, never their values — the batch path stays bit-exact (int8) /
    bit-identical to its own single-lane program (bf16).
    """
    n = len(descs)
    src_r = [(d.src_addr, _surface_bytes(d.src_dims, elem_bytes)) for d in descs]
    dst_r = [(d.dst_addr, _surface_bytes(d.dst_dims, elem_bytes)) for d in descs]
    aux_r = [(d.aux_addr, _surface_bytes(d.src_dims, elem_bytes))
             if d.unit == "EW" else None for d in descs]
    fwd = [src_r[i] == (dst_r[i - 1] if i else input_region) for i in range(n)]

    def store_needed(region: tuple, producer: int) -> bool:
        for j in range(producer + 1, n):
            if _overlaps(region, src_r[j]) and not (j == producer + 1 and fwd[j]):
                return True
            if aux_r[j] is not None and _overlaps(region, aux_r[j]):
                return True
        return False

    store = [store_needed(dst_r[i], i) for i in range(n - 1)]
    store.append(False)          # final output is forwarded out of the program
    store_input = store_needed(input_region, -1)
    return fwd, store, store_input


def _batched_op_from_descriptor(d: engine.Descriptor, base: int, act_lo: int,
                                fwd: bool, store: bool,
                                kernel: str = perfmodel.KERNEL_GEMM_TILED):
    """Build f(weights, act, y_prev)->(act, y_flat) for the vmapped batch path.

    ``weights`` is the full preload arena, shared (unbatched) across lanes and
    read with *static* slices; ``act`` is a small per-lane arena covering only
    the activation region — so per-op data movement under vmap is
    O(batch * live activations), not O(batch * whole arena).
    """
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so = d.src_addr - base - act_lo
    do = d.dst_addr - base - act_lo
    s_sz = _surface_bytes(d.src_dims, 1)

    def read_src(act, y_prev):
        if fwd:
            return y_prev.reshape(c, h, w)
        return jax.lax.dynamic_slice(act, (so,), (s_sz,)).reshape(c, h, w)

    def finish(act, y):
        y_flat = y.reshape(-1)
        if store:
            act = jax.lax.dynamic_update_slice(act, y_flat, (do,))
        return act, y_flat

    if d.unit in ("CONV", "FC"):
        r, s = d.kernel
        cin_g = c // d.groups if d.unit == "CONV" else c * h * w
        wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
        wo, bo, sco = d.wt_addr - base, d.bias_addr - base, d.scale_addr - base

        def op(weights, act, y_prev):
            x = read_src(act, y_prev)
            wq = weights[wo:wo + wt_n].reshape(k, -1)
            bias = jax.lax.bitcast_convert_type(
                weights[bo:bo + 4 * k].reshape(k, 4), jnp.int32)
            words = jax.lax.bitcast_convert_type(
                weights[sco:sco + 4 * k].reshape(k, 4), jnp.int32)
            if d.unit == "CONV":
                y = _conv_int8(x, wq, bias, words, r, d.stride, d.pad,
                               d.groups, d.relu, kernel)
            else:
                y = _fc_int8(x, wq, bias, words, d.relu, kernel)
            return finish(act, y)
    elif d.unit == "PDP":
        word = engine._pack_scale(d.out_scale)

        def op(weights, act, y_prev):
            y = _pool_int8(read_src(act, y_prev), d.kernel, d.stride, d.pad,
                           d.pool_mode, word)
            return finish(act, y)
    elif d.unit == "EW":
        ao = d.aux_addr - base - act_lo
        wa, wb = engine._pack_scale(d.out_scale), engine._pack_scale(d.aux_scale)

        def op(weights, act, y_prev):
            a = read_src(act, y_prev)
            b = jax.lax.dynamic_slice(act, (ao,), (s_sz,)).reshape(c, h, w)
            y = _add_int8(a, b, wa, wb, d.relu)
            return finish(act, y)
    else:
        raise ValueError(d.unit)
    return op


def _batched_op_from_descriptor_bf16(d: engine.Descriptor, base: int,
                                     act_lo: int, fwd: bool, store: bool,
                                     kernel: str = perfmodel.KERNEL_GEMM_BF16):
    """bf16 twin of ``_batched_op_from_descriptor``.

    Same structure: the full preload arena is shared (unbatched) across lanes
    and read with static slices; the per-lane ``act`` arena and the forwarded
    ``y_prev`` both carry raw bf16 *bytes* (int8), bitcast at the op boundary
    — so the int8 and bf16 batch paths share one replay loop shape.
    """
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so = d.src_addr - base - act_lo
    do = d.dst_addr - base - act_lo
    s_n = c * h * w
    s_bytes = s_n * 2

    def read_src(act, y_prev):
        if fwd:
            return _bytes_to_bf16(y_prev, (c, h, w))
        raw = jax.lax.dynamic_slice(act, (so,), (s_bytes,))
        return _bytes_to_bf16(raw, (c, h, w))

    def finish(act, y):
        y_flat = _bf16_to_bytes(y)
        if store:
            act = jax.lax.dynamic_update_slice(act, y_flat, (do,))
        return act, y_flat

    if d.unit in ("CONV", "FC"):
        r, s = d.kernel
        cin_g = c // d.groups if d.unit == "CONV" else c * h * w
        wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
        wo, bo = d.wt_addr - base, d.bias_addr - base

        def op(weights, act, y_prev):
            x = read_src(act, y_prev)
            wq = _bytes_to_bf16(weights[wo:wo + 2 * wt_n], (k, -1))
            bias = jax.lax.bitcast_convert_type(
                weights[bo:bo + 4 * k].reshape(k, 4), jnp.float32)
            if d.unit == "CONV":
                y = _conv_bf16(x, wq, bias, r, d.stride, d.pad, d.groups,
                               d.relu, kernel)
            else:
                y = _fc_bf16(x, wq, bias, d.relu, kernel)
            return finish(act, y)
    elif d.unit == "PDP":
        def op(weights, act, y_prev):
            y = _pool_bf16(read_src(act, y_prev), d.kernel, d.stride, d.pad,
                           d.pool_mode)
            return finish(act, y)
    elif d.unit == "EW":
        ao = d.aux_addr - base - act_lo

        def op(weights, act, y_prev):
            a = read_src(act, y_prev)
            raw = jax.lax.dynamic_slice(act, (ao,), (s_bytes,))
            b = _bytes_to_bf16(raw, (c, h, w))
            return finish(act, _add_bf16(a, b, d.relu))
    else:
        raise ValueError(d.unit)
    return op


def _native_batched_op_from_descriptor(d: engine.Descriptor, base: int,
                                       act_lo: int, fwd: bool, store: bool,
                                       kernel: str):
    """Build f(weights, actB, yB)->(actB, yB) executing the whole bucket as
    ONE natively batched kernel launch (int8 CONV/FC only).

    Same contract as vmapping ``_batched_op_from_descriptor`` over the lanes
    — ``actB``/``yB`` carry a leading batch axis, ``weights`` stays shared —
    but the GEMM folds the lanes onto its N axis, so the weight/bias/scale
    blocks stream once per bucket.  Bit-exact vs the vmapped path.
    """
    assert d.unit in ("CONV", "FC"), d.unit
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so = d.src_addr - base - act_lo
    do = d.dst_addr - base - act_lo
    s_sz = _surface_bytes(d.src_dims, 1)
    r, s = d.kernel
    cin_g = c // d.groups if d.unit == "CONV" else c * h * w
    wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
    wo, bo, sco = d.wt_addr - base, d.bias_addr - base, d.scale_addr - base

    def op(weights, actB, yB):
        n = actB.shape[0]
        if fwd:
            xs = yB.reshape(n, c, h, w)
        else:
            xs = jax.lax.dynamic_slice(actB, (0, so),
                                       (n, s_sz)).reshape(n, c, h, w)
        wq = weights[wo:wo + wt_n].reshape(k, -1)
        bias = jax.lax.bitcast_convert_type(
            weights[bo:bo + 4 * k].reshape(k, 4), jnp.int32)
        words = jax.lax.bitcast_convert_type(
            weights[sco:sco + 4 * k].reshape(k, 4), jnp.int32)
        if d.unit == "CONV":
            ys = _conv_int8_batch(xs, wq, bias, words, r, d.stride, d.pad,
                                  d.groups, d.relu, kernel)
        else:
            ys = _fc_int8_batch(xs, wq, bias, words, d.relu, kernel)
        yB = ys.reshape(n, -1)
        if store:
            actB = jax.lax.dynamic_update_slice(actB, yB, (0, do))
        return actB, yB

    return op


def _native_batched_op_from_descriptor_bf16(d: engine.Descriptor, base: int,
                                            act_lo: int, fwd: bool,
                                            store: bool, kernel: str):
    """bf16 twin of ``_native_batched_op_from_descriptor`` — bit-identical to
    vmapping ``_batched_op_from_descriptor_bf16`` over the lanes (lane folding
    preserves per-column f32 accumulation order)."""
    assert d.unit in ("CONV", "FC"), d.unit
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so = d.src_addr - base - act_lo
    do = d.dst_addr - base - act_lo
    s_bytes = c * h * w * 2
    r, s = d.kernel
    cin_g = c // d.groups if d.unit == "CONV" else c * h * w
    wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
    wo, bo = d.wt_addr - base, d.bias_addr - base

    def op(weights, actB, yB):
        n = actB.shape[0]
        if fwd:
            xs = _bytes_to_bf16(yB, (n, c, h, w))
        else:
            raw = jax.lax.dynamic_slice(actB, (0, so), (n, s_bytes))
            xs = _bytes_to_bf16(raw, (n, c, h, w))
        wq = _bytes_to_bf16(weights[wo:wo + 2 * wt_n], (k, -1))
        bias = jax.lax.bitcast_convert_type(
            weights[bo:bo + 4 * k].reshape(k, 4), jnp.float32)
        if d.unit == "CONV":
            ys = _conv_bf16_batch(xs, wq, bias, r, d.stride, d.pad, d.groups,
                                  d.relu, kernel)
        else:
            ys = _fc_bf16_batch(xs, wq, bias, d.relu, kernel)
        yB = _bf16_to_bytes_batch(ys)
        if store:
            actB = jax.lax.dynamic_update_slice(actB, yB, (0, do))
        return actB, yB

    return op


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ExecResult:
    output_int8: np.ndarray
    output: np.ndarray
    degraded: bool = False      # served by a fallback backend (circuit open)


@dataclasses.dataclass(frozen=True)
class ExecutorCapabilities:
    """What a backend can do — consulted by the scheduler instead of
    special-casing backend names or classes.

    ``native_batching``  — ``run_batch`` executes the whole batch as one
                           program (vs the sequential fallback loop).
    ``resident_arena``   — keeps device state across calls (``reset_arena``).
    ``shardable``        — the batch program honours ``batch_sharding`` (a
                           ``NamedSharding`` over a 1-axis data mesh) to
                           split lanes across devices.
    ``max_batch``        — hard batch-size ceiling, or ``None`` (unbounded).
    ``kernels``          — the GEMM kernels this backend's plan resolved to
                           (names from ``core.perfmodel``), so callers can
                           see which code path serves each network.
    ``profileable``      — ``run_profiled``/``run_batch_profiled`` exist:
                           the backend can time each descriptor's kernel
                           individually (the observability plane's per-layer
                           sampling consults this before asking).
    """
    native_batching: bool = False
    resident_arena: bool = False
    shardable: bool = False
    max_batch: Optional[int] = None
    dtype: str = "int8"
    kernels: tuple = ()
    profileable: bool = False


@runtime_checkable
class ExecutorBackend(Protocol):
    """Uniform executor contract every registered backend must satisfy.

    ``run(x)`` serves one input.  ``run_batch(X, lanes=None)`` serves a
    (possibly padded) batch ``X`` of shape ``(N, ...)`` and returns results
    for the first ``lanes`` lanes (all ``N`` when ``lanes`` is ``None``) —
    padding and lane masking are owned by the *scheduler*, never by the
    backend.  ``capabilities()`` declares what the backend supports so
    callers never have to special-case backend names.
    """

    def run(self, x: np.ndarray) -> ExecResult: ...

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult: ...

    def capabilities(self) -> ExecutorCapabilities: ...


class _ExecutorBase:
    """Common decode/bind logic from the two bare-metal artifacts."""

    def __init__(self, trace: Trace, weight_image: Dict[int, bytes],
                 cfg: engine.EngineConfig = engine.NV_SMALL,
                 input_scale: float = 1.0, output_scale: float = 1.0,
                 output_elems: Optional[int] = None,
                 kernel_plan: Union[str, Sequence, Dict[int, str], None] = None):
        if cfg.dtype not in ("int8", "bf16"):
            known = ", ".join(f"{n} (dtype={c.dtype})"
                              for n, c in engine.CONFIGS.items())
            raise NotImplementedError(
                f"executor backends implement the int8 (nv_small) and bf16 "
                f"(nv_full) datapaths; engine config {cfg.name!r} declares "
                f"dtype={cfg.dtype!r}.  Known engine configs: {known}")
        self.cfg = cfg
        self.trace = trace
        self.input_scale = input_scale
        self.output_scale = output_scale
        self.descs = engine.decode_descriptors(trace.commands)
        if not self.descs:
            raise ValueError("trace contains no engine ops")
        # Kernel plan: one perfmodel.KernelChoice per descriptor, cost-model
        # selected for the platform jax executes on; ``kernel_plan=`` forces
        # choices for debugging/A-B (a kernel name for all CONV/FC, a
        # per-descriptor sequence, or an {index: name} dict).  The spec is
        # kept so per-bucket plans (``batched_kernel_plan``) re-run the
        # batch-aware cost model under the same overrides.
        self._kernel_plan_spec = kernel_plan
        self.kernel_plan = self._resolve_kernel_plan(kernel_plan)
        self._plan_cache: Dict[int, List[perfmodel.KernelChoice]] = \
            {1: self.kernel_plan}
        # Program builds performed so far (single + one per batch shape for
        # natively batching backends) — the compile-stall observability knob.
        self.compile_count = 0
        # Arena geometry, derived from the trace alone.  All addresses are
        # byte addresses; surfaces occupy elem_bytes per element (1 for int8,
        # 2 for bf16 — see core/memory.plan_arena).
        eb = cfg.elem_bytes
        hi = engine.DRAM_BASE
        for d in self.descs:
            hi = max(hi, d.dst_addr + _surface_bytes(d.dst_dims, eb),
                     d.src_addr + _surface_bytes(d.src_dims, eb))
        for a, b in weight_image.items():
            hi = max(hi, a + len(b))
        self.base = engine.DRAM_BASE
        self.size = hi - self.base
        # Preloaded image: weights + (sample) input, as extracted from the VP log.
        arena0 = np.zeros(self.size, np.uint8)
        for a, b in weight_image.items():
            arena0[a - self.base:a - self.base + len(b)] = np.frombuffer(b, np.uint8)
        self.arena0 = arena0
        # Integrity anchor: the preload regions (weight/bias/scale tables plus
        # the sample input) are the only arena bytes with an authoritative
        # source, so their CRC at preload time defines "arena intact".
        # ``arena_ok()`` re-checksums them; ``reset_arena()`` restores the
        # pristine bytes IN PLACE — ``LinuxStackExecutor`` binds views into
        # ``arena0``, so the array object must never be reallocated.
        self._preload = sorted(
            ((a - self.base, np.frombuffer(b, np.uint8))
             for a, b in weight_image.items()), key=lambda t: t[0])
        self._weight_crc0 = self.weight_checksum()
        # I/O surfaces: input = first op's source; output = last op's dest.
        self.input_off = self.descs[0].src_addr - self.base
        self.input_dims = self.descs[0].src_dims
        self.output_off = self.descs[-1].dst_addr - self.base
        self.output_dims = self.descs[-1].dst_dims
        self.output_elems = output_elems or \
            _surface_bytes(self.output_dims, 1)       # ELEMENT count
        self.output_bytes = self.output_elems * eb    # arena-slice length

    def batched_kernel_plan(self, batch: int) -> List[perfmodel.KernelChoice]:
        """The per-bucket plan: the batch-aware cost model re-selects each
        CONV/FC kernel for this bucket size (cached per bucket).  A choice's
        ``batched`` flag says whether the natively batched variant (one fused
        launch per bucket) beats vmapping the single-image program."""
        batch = max(int(batch), 1)
        plan = self._plan_cache.get(batch)
        if plan is None:
            plan = self._resolve_kernel_plan(self._kernel_plan_spec,
                                             batch=batch)
            self._plan_cache[batch] = plan
        return plan

    def _resolve_kernel_plan(self, spec,
                             batch: int = 1) -> List[perfmodel.KernelChoice]:
        if isinstance(spec, (list, tuple)) and len(spec) != len(self.descs):
            raise ValueError(
                f"kernel_plan sequence has {len(spec)} entries but the trace "
                f"decodes to {len(self.descs)} descriptors (PDP/EW count "
                f"too — use None for non-GEMM positions, or an "
                f"{{index: kernel}} dict)")
        if isinstance(spec, dict):
            try:                    # JSON round-trips stringify object keys
                spec = {int(i): v for i, v in spec.items()}
            except (TypeError, ValueError):
                raise ValueError(
                    f"kernel_plan dict keys must be descriptor indices "
                    f"(ints), got {sorted(map(repr, spec))}") from None
            bad = [i for i in spec
                   if not (0 <= i < len(self.descs)
                           and self.descs[i].unit in ("CONV", "FC"))]
            if bad:
                convfc = [i for i, d in enumerate(self.descs)
                          if d.unit in ("CONV", "FC")]
                raise ValueError(
                    f"kernel_plan dict keys {bad} do not name CONV/FC "
                    f"descriptors (valid indices: {convfc}) — the override "
                    f"would silently no-op")
        backend = perfmodel.default_backend()
        choices = []
        for i, d in enumerate(self.descs):
            if isinstance(spec, dict):
                ov = spec.get(i)
            elif isinstance(spec, (list, tuple)):
                ov = spec[i]
            else:
                ov = spec                      # None or a kernel name for all
            if d.unit not in ("CONV", "FC"):
                ov = None
            choices.append(perfmodel.select_kernel(d, backend, override=ov,
                                                   dtype=self.cfg.dtype,
                                                   batch=batch))
        return choices

    def kernel_plan_summary(self) -> List[Dict]:
        """The resolved plan as JSON-ready dicts (mirrors the manifest)."""
        return [dict(c.to_dict(), index=i, unit=d.unit)
                for i, (d, c) in enumerate(zip(self.descs, self.kernel_plan))]

    def _quant_in(self, x: np.ndarray) -> np.ndarray:
        """Input image -> the engine's surface dtype (int8 or bf16)."""
        x = np.asarray(x)
        if self.cfg.dtype == "int8":
            if x.dtype == np.int8:
                return x
            return quant.quantize_act(x, self.input_scale)
        return np.ascontiguousarray(x).astype(ml_dtypes.bfloat16)

    def _dequant_out(self, y_i8: np.ndarray) -> np.ndarray:
        return y_i8.astype(np.float32) * self.output_scale

    def _finish_out(self, y_bytes: np.ndarray) -> ExecResult:
        """Raw output-surface bytes (last axis = ``output_bytes``) ->
        ``ExecResult``.  ``output_int8`` carries the raw engine bytes — int8
        logits for nv_small, the bf16 byte stream for nv_full (the same
        convention as ``VpResult``); ``output`` is always float32."""
        if self.cfg.dtype == "int8":
            y_i8 = y_bytes.view(np.int8)
            return ExecResult(output_int8=y_i8, output=self._dequant_out(y_i8))
        out = y_bytes.view(ml_dtypes.bfloat16).astype(np.float32) \
            * self.output_scale
        return ExecResult(output_int8=y_bytes.view(np.uint8), output=out)

    def _plan_kernels(self) -> tuple:
        return tuple(sorted({c.kernel for c in self.kernel_plan
                             if c.kernel != perfmodel.KERNEL_VPU}))

    # -- arena integrity -----------------------------------------------------
    def weight_checksum(self) -> int:
        """CRC32 over the preload regions of ``arena0`` as they are NOW."""
        crc = 0
        for off, b in self._preload:
            crc = zlib.crc32(self.arena0[off:off + b.size], crc)
        return crc

    def arena_ok(self) -> bool:
        """True when the preload regions still carry their load-time bytes.
        The scheduler's supervisor checks this after a failed launch — a
        crashed backend call may have scribbled on the weight arena."""
        return self.weight_checksum() == self._weight_crc0

    def reset_arena(self) -> None:
        """Restore the pristine preload bytes in place and drop any
        device-resident copies, so the next run re-materialises from a known
        -good arena.  In-place is load-bearing: ``LinuxStackExecutor`` holds
        weight views INTO ``arena0``."""
        for off, b in self._preload:
            self.arena0[off:off + b.size] = b
        self._drop_device_state()

    def _drop_device_state(self) -> None:
        """Invalidate device-resident arena copies (no-op for host-only
        backends); overridden by backends with ``resident_arena``."""

    # Backends that can time each descriptor's kernel individually set this
    # and implement ``run_profiled``; the scheduler consults
    # ``capabilities().profileable`` before ever calling the profiled path.
    _profileable = False

    def capabilities(self) -> ExecutorCapabilities:
        """Default: sequential batching, no device residency, not shardable."""
        return ExecutorCapabilities(dtype=self.cfg.dtype,
                                    kernels=self._plan_kernels(),
                                    profileable=self._profileable)

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult:
        """Batched inference, default: sequential runs, stacked.

        Only the first ``lanes`` rows are executed (the rest are padding the
        scheduler added to hit a bucket size); ``lanes=None`` runs them all.
        """
        X = np.asarray(X)
        n = X.shape[0] if lanes is None else lanes
        outs = [self.run(x) for x in X[:n]]
        return ExecResult(output_int8=np.stack([o.output_int8 for o in outs]),
                          output=np.stack([o.output for o in outs]))

    def run_profiled(self, x: np.ndarray) -> tuple:
        """``(ExecResult, samples)`` with one per-layer timing sample per
        descriptor: ``{"index", "unit", "kernel", "bucket", "native", "us",
        "t0", "t1"}`` (``t0``/``t1`` are ``time.perf_counter`` bounds, so the
        tracer can place the kernels on its timeline).  Only meaningful when
        ``capabilities().profileable`` — the default raises."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support per-layer profiling "
            f"(capabilities().profileable is False)")

    def run_batch_profiled(self, X: np.ndarray,
                           lanes: Optional[int] = None) -> tuple:
        """Batched profiled inference, default: sequential profiled runs per
        lane (each sample keeps ``bucket=1`` — the lanes really did execute
        as independent single-image programs)."""
        X = np.asarray(X)
        n = X.shape[0] if lanes is None else lanes
        outs, samples = [], []
        for x in X[:n]:
            r, s = self.run_profiled(x)
            outs.append(r)
            samples.extend(s)
        res = ExecResult(output_int8=np.stack([o.output_int8 for o in outs]),
                         output=np.stack([o.output for o in outs]))
        return res, samples


class BareMetalExecutor(_ExecutorBase):
    """One fused XLA executable over a flat arena — the bare-metal binary."""

    def __init__(self, *args, donate: bool = True, native_batch: bool = True,
                 **kw):
        # ``donate`` is accepted for backward compatibility and ignored: the
        # preloaded arena now stays resident on device across calls, which
        # requires the buffer NOT to be donated (the program reads it, threads
        # its own copy, and returns only the output surface — XLA elides the
        # stores of activations that are never read back).
        # ``native_batch`` picks the bucket execution style: True follows the
        # per-bucket cost-model plan, False pins every bucket to the vmapped
        # single-image program (the oracle), "force" runs every CONV/FC as
        # the natively batched fused launch regardless of the plan — the A/B
        # lever the batched_fused bench and the parity tests use.
        del donate
        if native_batch not in (True, False, "force"):
            raise ValueError(f"native_batch must be True, False or 'force', "
                             f"got {native_batch!r}")
        self.native_batch = native_batch
        super().__init__(*args, **kw)
        eb = self.cfg.elem_bytes
        if self.cfg.dtype == "int8":
            ops = [_op_from_descriptor(d, self.base, 1, c.kernel)
                   for d, c in zip(self.descs, self.kernel_plan)]
        else:
            ops = [_op_from_descriptor_bf16(d, self.base, c.kernel)
                   for d, c in zip(self.descs, self.kernel_plan)]
        # kept for the profiled path: the same closures, jitted per-op so
        # each descriptor's kernel can be timed behind block_until_ready
        self._single_ops = ops
        self._profile_fns = None
        self._profile_batch_fns: Dict[int, list] = {}
        n_out = self.output_bytes
        out_off = self.output_off

        def replay(arena, x_flat):
            arena = jax.lax.dynamic_update_slice(arena, x_flat, (self.input_off,))
            for op in ops:
                arena = op(arena)
            return jax.lax.dynamic_slice(arena, (out_off,), (n_out,))

        # Single-image path: the resident arena transfers host->device once;
        # steady-state serving moves only the input surface per call.
        self._fn = jax.jit(replay)
        # Batch path: the immutable weight region stays shared across lanes;
        # only the activation region [act_lo, act_hi) carries a batch axis, so
        # each op moves O(batch * activations), not O(batch * whole arena).
        # Programs are built lazily per batch shape (``_batch_fns``) from the
        # per-bucket kernel plan: CONV/FC ops whose bucket plan says
        # ``batched`` run as ONE natively batched fused launch; everything
        # else (and the whole program when ``native_batch=False``) vmaps the
        # single-image op per lane.
        act_offs = []
        for d in self.descs:
            act_offs.append((d.src_addr - self.base,
                             d.src_addr - self.base + _surface_bytes(d.src_dims, eb)))
            act_offs.append((d.dst_addr - self.base,
                             d.dst_addr - self.base + _surface_bytes(d.dst_dims, eb)))
            if d.unit == "EW":
                act_offs.append((d.aux_addr - self.base,
                                 d.aux_addr - self.base + _surface_bytes(d.src_dims, eb)))
        act_lo = min(lo for lo, _ in act_offs)
        act_hi = max(hi for _, hi in act_offs)
        self._act_lo, self._act_hi = act_lo, act_hi
        in_region = (self.base + self.input_off,
                     _surface_bytes(self.input_dims, eb))
        self._fwd, self._store, self._store_input = \
            _batch_plan(self.descs, in_region, eb)
        self._batch_fns: Dict[int, object] = {}
        self._ran_single = False
        self._arena_dev = None      # created lazily from arena0
        self._batch_state = None    # per-lane activation slice, lazy
        # Optional NamedSharding over a 1-axis data mesh: when set (by the
        # scheduler's dispatcher), batch lanes are placed across devices and
        # GSPMD partitions the batch program; weights/activations replicate.
        self.batch_sharding = None

    def _batch_ops(self, n: int):
        """Per-bucket op list as ``(op, choice, native)`` triples: the
        natively batched fused launch where this bucket's plan says so, the
        vmapped single-image op (the oracle and the non-native fallback)
        everywhere else."""
        int8 = self.cfg.dtype == "int8"
        native = bool(self.native_batch) and n > 1
        forced = self.native_batch == "force"
        plan = self.batched_kernel_plan(n) if native else self.kernel_plan
        lane_b = (_batched_op_from_descriptor if int8
                  else _batched_op_from_descriptor_bf16)
        native_b = (_native_batched_op_from_descriptor if int8
                    else _native_batched_op_from_descriptor_bf16)
        bops = []
        for i, (d, ch) in enumerate(zip(self.descs, plan)):
            if native and (ch.batched or forced) and d.unit in ("CONV", "FC"):
                bops.append((native_b(d, self.base, self._act_lo,
                                      self._fwd[i], self._store[i],
                                      ch.kernel), ch, True))
            else:
                lane = lane_b(d, self.base, self._act_lo, self._fwd[i],
                              self._store[i], ch.kernel)
                bops.append((functools.partial(
                    lambda f, w, a, y: jax.vmap(f, in_axes=(None, 0, 0))(w, a, y),
                    lane), ch, False))
        return bops

    def _make_batch_fn(self, n: int):
        bops = [b for b, _, _ in self._batch_ops(n)]
        in_rel = self.input_off - self._act_lo
        n_out = self.output_bytes
        store_input = self._store_input

        def batch_replay(weights, act0, xs):
            actB = jnp.broadcast_to(act0, (xs.shape[0], act0.shape[0]))
            if store_input:
                actB = jax.lax.dynamic_update_slice(actB, xs, (0, in_rel))
            yB = xs
            for bop in bops:
                actB, yB = bop(weights, actB, yB)
            return yB[:, :n_out]

        return jax.jit(batch_replay)

    def _ensure_arena(self):
        if self._arena_dev is None:
            self._arena_dev = jnp.asarray(self.arena0.view(np.int8))
        return self._arena_dev

    def _drop_device_state(self) -> None:
        """Drop the device-resident arena (next run re-materialises arena0)."""
        self._arena_dev = None
        self._batch_state = None

    def compile(self):
        """AOT-compile the fused program (the 'binary')."""
        x = jax.ShapeDtypeStruct(
            (_surface_bytes(self.input_dims, self.cfg.elem_bytes),), jnp.int8)
        a = jax.ShapeDtypeStruct((self.size,), jnp.int8)
        return self._fn.lower(a, x).compile()

    def run(self, x: np.ndarray) -> ExecResult:
        if not self._ran_single:
            # the single-image program has one fixed shape, so jit compiles
            # it exactly once — on this call
            self._ran_single = True
            self.compile_count += 1
        xq = self._quant_in(x).reshape(-1)
        y = self._fn(self._ensure_arena(), jnp.asarray(xq.view(np.int8)))
        return self._finish_out(np.asarray(y))

    def capabilities(self) -> ExecutorCapabilities:
        return ExecutorCapabilities(native_batching=True, resident_arena=True,
                                    shardable=True, dtype=self.cfg.dtype,
                                    kernels=self._plan_kernels(),
                                    profileable=True)

    def run_profiled(self, x: np.ndarray) -> tuple:
        """Single-image inference with per-descriptor kernel timing.

        Replays the SAME op closures the fused program composes, jitted
        individually so every descriptor has a host-visible boundary
        (``block_until_ready``) to time against.  Integer ops are exact under
        any fusion, so the output is bit-identical to ``run`` for int8 — the
        only cost is losing XLA's cross-op fusion, which is why this path is
        opt-in (``TraceConfig.profile``) rather than the serving default.
        """
        if self._profile_fns is None:
            self._profile_fns = [jax.jit(op) for op in self._single_ops]
            # one program build per op; counted at build time (each fn
            # compiles on its first call below)
            self.compile_count += len(self._profile_fns)
        xq = self._quant_in(x).reshape(-1)
        arena = jax.lax.dynamic_update_slice(
            self._ensure_arena(), jnp.asarray(xq.view(np.int8)),
            (self.input_off,))
        jax.block_until_ready(arena)
        samples = []
        for i, (fn, d, ch) in enumerate(zip(self._profile_fns, self.descs,
                                            self.kernel_plan)):
            t0 = time.perf_counter()
            arena = fn(arena)
            jax.block_until_ready(arena)
            t1 = time.perf_counter()
            samples.append({"index": i, "unit": d.unit, "kernel": ch.kernel,
                            "bucket": 1, "native": False,
                            "us": (t1 - t0) * 1e6, "t0": t0, "t1": t1})
        y = np.asarray(jax.lax.dynamic_slice(arena, (self.output_off,),
                                             (self.output_bytes,)))
        return self._finish_out(y), samples

    def run_batch_profiled(self, X: np.ndarray,
                           lanes: Optional[int] = None) -> tuple:
        """Batched profiled inference: steps the SAME per-bucket op list the
        fused batch program composes (native fused launches included), each
        op jitted and timed individually.  Bit-exact vs ``run_batch`` for
        int8; samples carry the bucket size and each op's execution style."""
        X = np.asarray(X)
        n = X.shape[0]
        xq = self._quant_in(X).reshape(n, -1)
        if self._batch_state is None:
            self._batch_state = jnp.asarray(
                self.arena0.view(np.int8)[self._act_lo:self._act_hi])
        entry = self._profile_batch_fns.get(n)
        if entry is None:
            entry = [(jax.jit(b), ch, nat) for b, ch, nat in
                     self._batch_ops(n)]
            self._profile_batch_fns[n] = entry
            self.compile_count += len(entry)
        xs = jnp.asarray(xq.view(np.int8))
        actB = jnp.broadcast_to(self._batch_state,
                                (n, self._batch_state.shape[0]))
        if self._store_input:
            actB = jax.lax.dynamic_update_slice(
                actB, xs, (0, self.input_off - self._act_lo))
        yB = xs
        jax.block_until_ready((actB, yB))
        arena = self._ensure_arena()
        samples = []
        for i, (fn, ch, nat) in enumerate(entry):
            t0 = time.perf_counter()
            actB, yB = fn(arena, actB, yB)
            jax.block_until_ready((actB, yB))
            t1 = time.perf_counter()
            samples.append({"index": i, "unit": self.descs[i].unit,
                            "kernel": ch.kernel, "bucket": n, "native": nat,
                            "us": (t1 - t0) * 1e6, "t0": t0, "t1": t1})
        y = np.asarray(yB[:, :self.output_bytes])
        return self._finish_out(y[:lanes]), samples

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult:
        """Run a batch as ONE XLA program (bit-exact vs N ``run`` calls).

        CONV/FC ops whose per-bucket plan resolved ``batched`` execute as a
        single natively batched fused launch (weights stream once per
        bucket); the rest vmap the single-image op per lane.  ``lanes`` trims
        the returned results to the first ``lanes`` rows (the rest being
        scheduler padding); the program itself always executes the full
        padded shape so each bucket size compiles exactly once.
        """
        X = np.asarray(X)
        n = X.shape[0]
        xq = self._quant_in(X).reshape(n, -1)
        if self._batch_state is None:
            self._batch_state = jnp.asarray(
                self.arena0.view(np.int8)[self._act_lo:self._act_hi])
        fn = self._batch_fns.get(n)
        if fn is None:
            fn = self._make_batch_fn(n)
            self._batch_fns[n] = fn
            self.compile_count += 1
        xs = jnp.asarray(xq.view(np.int8))
        if self.batch_sharding is not None and n % \
                self.batch_sharding.mesh.size == 0:
            xs = jax.device_put(xs, self.batch_sharding)
        y = np.asarray(fn(self._ensure_arena(), self._batch_state, xs))
        return self._finish_out(y[:lanes])


class LinuxStackExecutor(_ExecutorBase):
    """Driver-stack baseline: per-op executables + tensor-table bookkeeping.

    The per-descriptor binding — jitted op callable, weight/bias/scale-table
    views into the immutable preload image, activation-surface offsets — is
    resolved ONCE at construction (the driver's "model load"), so a ``run``
    measures per-op dispatch overhead, not Python re-parsing of the trace.
    """

    _profileable = True      # per-op dispatch: each op is a natural timing
                             # boundary (the host materialises every result)

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        # Pre-build one jitted callable per op (the 'driver' compiles per-layer
        # kernels); dispatch happens op-at-a-time from Python (the 'syscall').
        self._ops = []
        for d, ch in zip(self.descs, self.kernel_plan):
            self._ops.append((d, jax.jit(self._op_fn(d, ch.kernel)),
                              self._bind(d)))

    def _op_fn(self, d: engine.Descriptor, kernel: str):
        if self.cfg.dtype == "bf16":
            return self._op_fn_bf16(d, kernel)
        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            def f(x, wq, bias, words):
                if d.unit == "CONV":
                    return _conv_int8(x, wq, bias, words, r, d.stride, d.pad,
                                      d.groups, d.relu, kernel)
                return _fc_int8(x, wq, bias, words, d.relu, kernel)
            return f
        if d.unit == "PDP":
            word = engine._pack_scale(d.out_scale)
            return lambda x: _pool_int8(x, d.kernel, d.stride, d.pad, d.pool_mode, word)
        if d.unit == "EW":
            wa, wb = engine._pack_scale(d.out_scale), engine._pack_scale(d.aux_scale)
            return lambda a, b: _add_int8(a, b, wa, wb, d.relu)
        raise ValueError(d.unit)

    def _op_fn_bf16(self, d: engine.Descriptor, kernel: str):
        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            def f(x, wq, bias):
                if d.unit == "CONV":
                    return _conv_bf16(x, wq, bias, r, d.stride, d.pad,
                                      d.groups, d.relu, kernel)
                return _fc_bf16(x, wq, bias, d.relu, kernel)
            return f
        if d.unit == "PDP":
            return lambda x: _pool_bf16(x, d.kernel, d.stride, d.pad,
                                        d.pool_mode)
        if d.unit == "EW":
            return lambda a, b: _add_bf16(a, b, d.relu)
        raise ValueError(d.unit)

    def _bind(self, d: engine.Descriptor):
        """Static per-descriptor binding: weight-region views (the preload
        image is immutable during serving) + activation offsets/shapes."""
        eb = self.cfg.elem_bytes
        bf16 = self.cfg.dtype == "bf16"
        _, c, h, w = d.src_dims
        b = dict(src_off=d.src_addr - self.base, src_shape=(c, h, w),
                 src_n=c * h * w, dst_off=d.dst_addr - self.base)
        if d.unit in ("CONV", "FC"):
            k = d.dst_dims[1]
            r, s = d.kernel
            cin_g = c // d.groups if d.unit == "CONV" else c * h * w
            wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
            wo, bo, so = (d.wt_addr - self.base, d.bias_addr - self.base,
                          d.scale_addr - self.base)
            if bf16:
                b["wq"] = self.arena0[wo:wo + eb * wt_n] \
                    .view(ml_dtypes.bfloat16).reshape(k, -1)
                b["bias"] = self.arena0[bo:bo + 4 * k].view(np.float32)
            else:
                b["wq"] = self.arena0[wo:wo + wt_n].view(np.int8).reshape(k, -1)
                b["bias"] = self.arena0[bo:bo + 4 * k].view(np.int32)
                b["words"] = self.arena0[so:so + 4 * k].view(np.int32)
        elif d.unit == "EW":
            b["aux_off"] = d.aux_addr - self.base
        return b

    def run(self, x: np.ndarray) -> ExecResult:
        return self._run_impl(x)

    def run_profiled(self, x: np.ndarray) -> tuple:
        """Per-op dispatch with per-descriptor timing — the op loop already
        materialises every result on the host, so each iteration IS the
        device-execute bound; the samples simply record it."""
        samples: list = []
        return self._run_impl(x, samples), samples

    def _run_impl(self, x: np.ndarray,
                  samples: Optional[list] = None) -> ExecResult:
        xq = self._quant_in(x)
        dram = self.arena0.copy()       # driver re-stages buffers per submission
        eb = self.cfg.elem_bytes
        sdtype = ml_dtypes.bfloat16 if self.cfg.dtype == "bf16" else np.int8

        def surf(off, shape, n):
            return dram[off:off + n * eb].view(sdtype).reshape(shape)

        in_off = self.descs[0].src_addr - self.base
        x_bytes = np.ascontiguousarray(xq.reshape(-1)).view(np.uint8)
        dram[in_off:in_off + x_bytes.size] = x_bytes
        for i, (d, fn, bnd) in enumerate(self._ops):
            t0 = time.perf_counter()
            src = surf(bnd["src_off"], bnd["src_shape"], bnd["src_n"])
            if d.unit in ("CONV", "FC"):
                if "words" in bnd:
                    y = fn(src, bnd["wq"], bnd["bias"], bnd["words"])
                else:
                    y = fn(src, bnd["wq"], bnd["bias"])
            elif d.unit == "PDP":
                y = fn(src)
            else:
                y = fn(src, surf(bnd["aux_off"], bnd["src_shape"],
                                 bnd["src_n"]))
            y = np.ascontiguousarray(np.asarray(y).reshape(-1))
            dram[bnd["dst_off"]:bnd["dst_off"] + y.size * eb] = \
                y.view(np.uint8)        # driver flushes the buffer
            if samples is not None:
                t1 = time.perf_counter()
                samples.append({"index": i, "unit": d.unit,
                                "kernel": self.kernel_plan[i].kernel,
                                "bucket": 1, "native": False,
                                "us": (t1 - t0) * 1e6, "t0": t0, "t1": t1})
        out = dram[self.output_off:self.output_off + self.output_bytes]
        return self._finish_out(out.copy().view(np.int8))
