"""Executors: bare-metal (the paper's contribution) vs linux-stack (the baseline).

``BareMetalExecutor`` consumes ONLY the two bare-metal artifacts — the configuration
file (trace) and the extracted weight image — exactly like the paper's µRISC-V
binary.  It decodes the register stream back into engine descriptors and binds the
*entire* network into one jitted XLA program over a single flat DRAM arena:
one binary, zero per-layer dispatch, zero runtime allocation.  This is the
TPU-native analogue of replaying stores from bare-metal assembly.

``LinuxStackExecutor`` models the driver-stack deployments the paper compares
against ([5]-[12]): one executable per layer, a driver-managed tensor table
(dict keyed by DRAM address), per-op submission from the host — i.e. real,
measured software overhead on the same op semantics (no simulated sleeps).

Both executors produce bit-identical INT8 results to the VP functional model;
tests assert it.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, quant
from repro.core.tracegen import Trace


# ---------------------------------------------------------------------------
# jnp twins of the integer engine semantics (bit-exact vs core/refops.py)
# ---------------------------------------------------------------------------
def _rha_shift(x, k):
    """Round-half-away right shift (int32)."""
    k = jnp.asarray(k, jnp.int32)
    half = jnp.where(k > 0, jnp.left_shift(jnp.int32(1), jnp.maximum(k - 1, 0)), 0)
    mag = jnp.abs(x) + half
    return jnp.sign(x) * jnp.right_shift(mag, k)


def _apply_scale(x, m, pre, post):
    t = _rha_shift(x, pre)
    return _rha_shift(t * m, post)


def _unpack_words(words_i32):
    """uint32 scale words (bitcast to int32) -> (m, pre, post) int32 arrays."""
    w = words_i32
    m = jnp.right_shift(w, 16) & 0xFFFF            # arithmetic shift ok: masked
    m = jnp.where(m >= 0x8000, m - 0x10000, m)
    pre = jnp.right_shift(w, 8) & 0xFF
    post = w & 0xFF
    return m, pre, post


def _clip8(x):
    return jnp.clip(x, -128, 127).astype(jnp.int8)


def _dot_i8(a, b, dnums, contract_k: int):
    """int8 x int8 -> int32 dot_general, via f32 when provably bit-exact.

    XLA CPU lowers integer GEMMs to scalar loops; the f32 units are far wider.
    Every int8*int8 product has magnitude <= 128*128 = 16384 (both operands can
    be -128), so as long as the worst-case accumulator K * 16384 stays within
    2^24 every partial sum is an exactly representable f32 integer regardless
    of summation order — the float GEMM returns bit-identical int32
    accumulators.  Larger contractions keep the integer path.
    """
    if contract_k * 128 * 128 <= (1 << 24):
        # Precision.HIGHEST forces true f32 accumulation — the default matmul
        # precision is tf32/bf16 on GPU/TPU, which would break the exactness
        # proof (products need 15 significand bits).
        acc = jax.lax.dot_general(a.astype(jnp.float32), b.astype(jnp.float32),
                                  dnums, preferred_element_type=jnp.float32,
                                  precision=jax.lax.Precision.HIGHEST)
        return acc.astype(jnp.int32)
    return jax.lax.dot_general(a, b, dnums, preferred_element_type=jnp.int32)


def _im2col(x, k, stride, pad):
    """(C,H,W) int8 -> (C*k*k, P*Q) int8, static shapes."""
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    p = (h + 2 * pad - k) // stride + 1
    q = (w + 2 * pad - k) // stride + 1
    cols = []
    for r in range(k):
        for s in range(k):
            cols.append(xp[:, r:r + stride * p:stride, s:s + stride * q:stride])
    return jnp.stack(cols, 1).reshape(c * k * k, p * q)


def _conv_int8(x, wq, bias, words, k, stride, pad, groups, relu):
    kk = wq.shape[0]
    c, h, w_in = x.shape
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = _im2col(x, k, stride, pad)
        acc = _dot_i8(wq, cols, (((1,), (0,)), ((), ())), c * k * k)
    else:
        cg, kg = c // groups, kk // groups
        xg = x.reshape(groups, cg, h, w_in)
        colsg = jax.vmap(lambda xx: _im2col(xx, k, stride, pad))(xg)
        wg = wq.reshape(groups, kg, cg * k * k)
        acc = _dot_i8(wg, colsg, (((2,), (1,)), ((0,), (0,))), cg * k * k)
        acc = acc.reshape(kk, p * q)
    acc = acc + bias[:, None]
    m, pre, post = _unpack_words(words)
    out = _apply_scale(acc, m[:, None], pre[:, None], post[:, None])
    if relu:
        out = jnp.maximum(out, 0)
    return _clip8(out).reshape(kk, p, q)


def _fc_int8(x, wq, bias, words, relu):
    acc = _dot_i8(wq, x.reshape(-1), (((1,), (0,)), ((), ())),
                  int(wq.shape[1])) + bias
    m, pre, post = _unpack_words(words)
    out = _apply_scale(acc, m, pre, post)
    if relu:
        out = jnp.maximum(out, 0)
    return _clip8(out).reshape(-1, 1, 1)


def _pool_int8(x, kern, stride, pad, mode, scale_word):
    c, h, w = x.shape
    r, s = kern
    if mode == 1:      # max
        xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=-128)
        p = (h + 2 * pad - r) // stride + 1
        q = (w + 2 * pad - s) // stride + 1
        out = jnp.full((c, p, q), -128, jnp.int8)
        for i in range(r):
            for j in range(s):
                out = jnp.maximum(out, xp[:, i:i + stride * p:stride, j:j + stride * q:stride])
        return out
    xp = jnp.pad(x.astype(jnp.int32), ((0, 0), (pad, pad), (pad, pad)))
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    acc = jnp.zeros((c, p, q), jnp.int32)
    for i in range(r):
        for j in range(s):
            acc = acc + xp[:, i:i + stride * p:stride, j:j + stride * q:stride]
    m, pre, post = quant.unpack_scale(scale_word)
    return _clip8(_apply_scale(acc, m, pre, post))


def _add_int8(a, b, word_a, word_b, relu):
    ma, pa, sa = quant.unpack_scale(word_a)
    mb, pb, sb = quant.unpack_scale(word_b)
    acc = (_apply_scale(a.astype(jnp.int32), ma, pa, sa)
           + _apply_scale(b.astype(jnp.int32), mb, pb, sb))
    if relu:
        acc = jnp.maximum(acc, 0)
    return _clip8(acc)


# ---------------------------------------------------------------------------
# Descriptor -> op closure over the flat arena
# ---------------------------------------------------------------------------
def _surface_bytes(dims, elem_bytes: int) -> int:
    n, c, h, w = dims
    return c * h * w * elem_bytes


def _op_from_descriptor(d: engine.Descriptor, base: int, elem_bytes: int):
    """Build f(arena)->arena for one descriptor (addresses become static offsets)."""
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so, do = d.src_addr - base, d.dst_addr - base
    s_sz, d_sz = _surface_bytes(d.src_dims, elem_bytes), _surface_bytes(d.dst_dims, elem_bytes)

    def read_i8(arena, off, n_, shape):
        return jax.lax.dynamic_slice(arena, (off,), (n_,)).reshape(shape)

    def read_i32(arena, off, n_):
        raw = jax.lax.dynamic_slice(arena, (off,), (n_ * 4,)).reshape(n_, 4)
        return jax.lax.bitcast_convert_type(raw, jnp.int32)

    if d.unit in ("CONV", "FC"):
        r, s = d.kernel
        cin_g = c // d.groups if d.unit == "CONV" else c * h * w
        wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
        wo, bo, sco = d.wt_addr - base, d.bias_addr - base, d.scale_addr - base

        def op(arena):
            x = read_i8(arena, so, s_sz, (c, h, w))
            wq = read_i8(arena, wo, wt_n, (k, -1))
            bias = read_i32(arena, bo, k)
            words = read_i32(arena, sco, k)
            if d.unit == "CONV":
                y = _conv_int8(x, wq, bias, words, r, d.stride, d.pad, d.groups, d.relu)
            else:
                y = _fc_int8(x, wq, bias, words, d.relu)
            return jax.lax.dynamic_update_slice(arena, y.reshape(-1), (do,))
    elif d.unit == "PDP":
        word = engine._pack_scale(d.out_scale)

        def op(arena):
            x = read_i8(arena, so, s_sz, (c, h, w))
            y = _pool_int8(x, d.kernel, d.stride, d.pad, d.pool_mode, word)
            return jax.lax.dynamic_update_slice(arena, y.reshape(-1), (do,))
    elif d.unit == "EW":
        ao = d.aux_addr - base
        wa, wb = engine._pack_scale(d.out_scale), engine._pack_scale(d.aux_scale)

        def op(arena):
            a = read_i8(arena, so, s_sz, (c, h, w))
            b = read_i8(arena, ao, s_sz, (c, h, w))
            y = _add_int8(a, b, wa, wb, d.relu)
            return jax.lax.dynamic_update_slice(arena, y.reshape(-1), (do,))
    else:
        raise ValueError(d.unit)
    return op


def _overlaps(a: tuple, b: tuple) -> bool:
    return a[0] < b[0] + b[1] and b[0] < a[0] + a[1]


def _batch_plan(descs, input_region: tuple):
    """Dataflow analysis for the batched program.

    For op ``i``: ``fwd[i]`` — its source region is exactly the previous
    producer's destination (the previous op, or the input surface for op 0),
    so the value is forwarded tensor-to-tensor instead of read back from the
    activation arena; ``store[i]`` — some *other* later read overlaps its
    destination (concat consumers, EW residuals, partial reads), so the value
    must also be stored to the arena.  Forwarding changes only where bytes are
    read from, never their values — the batch path stays bit-exact.
    """
    n = len(descs)
    src_r = [(d.src_addr, _surface_bytes(d.src_dims, 1)) for d in descs]
    dst_r = [(d.dst_addr, _surface_bytes(d.dst_dims, 1)) for d in descs]
    aux_r = [(d.aux_addr, _surface_bytes(d.src_dims, 1)) if d.unit == "EW"
             else None for d in descs]
    fwd = [src_r[i] == (dst_r[i - 1] if i else input_region) for i in range(n)]

    def store_needed(region: tuple, producer: int) -> bool:
        for j in range(producer + 1, n):
            if _overlaps(region, src_r[j]) and not (j == producer + 1 and fwd[j]):
                return True
            if aux_r[j] is not None and _overlaps(region, aux_r[j]):
                return True
        return False

    store = [store_needed(dst_r[i], i) for i in range(n - 1)]
    store.append(False)          # final output is forwarded out of the program
    store_input = store_needed(input_region, -1)
    return fwd, store, store_input


def _batched_op_from_descriptor(d: engine.Descriptor, base: int, act_lo: int,
                                fwd: bool, store: bool):
    """Build f(weights, act, y_prev)->(act, y_flat) for the vmapped batch path.

    ``weights`` is the full preload arena, shared (unbatched) across lanes and
    read with *static* slices; ``act`` is a small per-lane arena covering only
    the activation region — so per-op data movement under vmap is
    O(batch * live activations), not O(batch * whole arena).
    """
    _, c, h, w = d.src_dims
    _, k, p, q = d.dst_dims
    so = d.src_addr - base - act_lo
    do = d.dst_addr - base - act_lo
    s_sz = _surface_bytes(d.src_dims, 1)

    def read_src(act, y_prev):
        if fwd:
            return y_prev.reshape(c, h, w)
        return jax.lax.dynamic_slice(act, (so,), (s_sz,)).reshape(c, h, w)

    def finish(act, y):
        y_flat = y.reshape(-1)
        if store:
            act = jax.lax.dynamic_update_slice(act, y_flat, (do,))
        return act, y_flat

    if d.unit in ("CONV", "FC"):
        r, s = d.kernel
        cin_g = c // d.groups if d.unit == "CONV" else c * h * w
        wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
        wo, bo, sco = d.wt_addr - base, d.bias_addr - base, d.scale_addr - base

        def op(weights, act, y_prev):
            x = read_src(act, y_prev)
            wq = weights[wo:wo + wt_n].reshape(k, -1)
            bias = jax.lax.bitcast_convert_type(
                weights[bo:bo + 4 * k].reshape(k, 4), jnp.int32)
            words = jax.lax.bitcast_convert_type(
                weights[sco:sco + 4 * k].reshape(k, 4), jnp.int32)
            if d.unit == "CONV":
                y = _conv_int8(x, wq, bias, words, r, d.stride, d.pad, d.groups, d.relu)
            else:
                y = _fc_int8(x, wq, bias, words, d.relu)
            return finish(act, y)
    elif d.unit == "PDP":
        word = engine._pack_scale(d.out_scale)

        def op(weights, act, y_prev):
            y = _pool_int8(read_src(act, y_prev), d.kernel, d.stride, d.pad,
                           d.pool_mode, word)
            return finish(act, y)
    elif d.unit == "EW":
        ao = d.aux_addr - base - act_lo
        wa, wb = engine._pack_scale(d.out_scale), engine._pack_scale(d.aux_scale)

        def op(weights, act, y_prev):
            a = read_src(act, y_prev)
            b = jax.lax.dynamic_slice(act, (ao,), (s_sz,)).reshape(c, h, w)
            y = _add_int8(a, b, wa, wb, d.relu)
            return finish(act, y)
    else:
        raise ValueError(d.unit)
    return op


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ExecResult:
    output_int8: np.ndarray
    output: np.ndarray


@dataclasses.dataclass(frozen=True)
class ExecutorCapabilities:
    """What a backend can do — consulted by the scheduler instead of
    special-casing backend names or classes.

    ``native_batching``  — ``run_batch`` executes the whole batch as one
                           program (vs the sequential fallback loop).
    ``resident_arena``   — keeps device state across calls (``reset_arena``).
    ``shardable``        — the batch program honours ``batch_sharding`` (a
                           ``NamedSharding`` over a 1-axis data mesh) to
                           split lanes across devices.
    ``max_batch``        — hard batch-size ceiling, or ``None`` (unbounded).
    """
    native_batching: bool = False
    resident_arena: bool = False
    shardable: bool = False
    max_batch: Optional[int] = None
    dtype: str = "int8"


@runtime_checkable
class ExecutorBackend(Protocol):
    """Uniform executor contract every registered backend must satisfy.

    ``run(x)`` serves one input.  ``run_batch(X, lanes=None)`` serves a
    (possibly padded) batch ``X`` of shape ``(N, ...)`` and returns results
    for the first ``lanes`` lanes (all ``N`` when ``lanes`` is ``None``) —
    padding and lane masking are owned by the *scheduler*, never by the
    backend.  ``capabilities()`` declares what the backend supports so
    callers never have to special-case backend names.
    """

    def run(self, x: np.ndarray) -> ExecResult: ...

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult: ...

    def capabilities(self) -> ExecutorCapabilities: ...


class _ExecutorBase:
    """Common decode/bind logic from the two bare-metal artifacts."""

    def __init__(self, trace: Trace, weight_image: Dict[int, bytes],
                 cfg: engine.EngineConfig = engine.NV_SMALL,
                 input_scale: float = 1.0, output_scale: float = 1.0,
                 output_elems: Optional[int] = None):
        assert cfg.dtype == "int8", "executors implement the nv_small INT8 path"
        self.cfg = cfg
        self.trace = trace
        self.input_scale = input_scale
        self.output_scale = output_scale
        self.descs = engine.decode_descriptors(trace.commands)
        if not self.descs:
            raise ValueError("trace contains no engine ops")
        # Arena geometry, derived from the trace alone.
        hi = engine.DRAM_BASE
        for d in self.descs:
            hi = max(hi, d.dst_addr + _surface_bytes(d.dst_dims, 1),
                     d.src_addr + _surface_bytes(d.src_dims, 1))
        for a, b in weight_image.items():
            hi = max(hi, a + len(b))
        self.base = engine.DRAM_BASE
        self.size = hi - self.base
        # Preloaded image: weights + (sample) input, as extracted from the VP log.
        arena0 = np.zeros(self.size, np.uint8)
        for a, b in weight_image.items():
            arena0[a - self.base:a - self.base + len(b)] = np.frombuffer(b, np.uint8)
        self.arena0 = arena0
        # I/O surfaces: input = first op's source; output = last op's dest.
        self.input_off = self.descs[0].src_addr - self.base
        self.input_dims = self.descs[0].src_dims
        self.output_off = self.descs[-1].dst_addr - self.base
        self.output_dims = self.descs[-1].dst_dims
        self.output_elems = output_elems or _surface_bytes(self.output_dims, 1)

    def _quant_in(self, x: np.ndarray) -> np.ndarray:
        if x.dtype == np.int8:
            return x
        return quant.quantize_act(x, self.input_scale)

    def _dequant_out(self, y_i8: np.ndarray) -> np.ndarray:
        return y_i8.astype(np.float32) * self.output_scale

    def capabilities(self) -> ExecutorCapabilities:
        """Default: sequential batching, no device residency, not shardable."""
        return ExecutorCapabilities(dtype=self.cfg.dtype)

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult:
        """Batched inference, default: sequential runs, stacked.

        Only the first ``lanes`` rows are executed (the rest are padding the
        scheduler added to hit a bucket size); ``lanes=None`` runs them all.
        """
        X = np.asarray(X)
        n = X.shape[0] if lanes is None else lanes
        outs = [self.run(x) for x in X[:n]]
        return ExecResult(output_int8=np.stack([o.output_int8 for o in outs]),
                          output=np.stack([o.output for o in outs]))


class BareMetalExecutor(_ExecutorBase):
    """One fused XLA executable over a flat arena — the bare-metal binary."""

    def __init__(self, *args, donate: bool = True, **kw):
        # ``donate`` is accepted for backward compatibility and ignored: the
        # preloaded arena now stays resident on device across calls, which
        # requires the buffer NOT to be donated (the program reads it, threads
        # its own copy, and returns only the output surface — XLA elides the
        # stores of activations that are never read back).
        del donate
        super().__init__(*args, **kw)
        ops = [_op_from_descriptor(d, self.base, 1) for d in self.descs]
        n_out = self.output_elems
        out_off = self.output_off

        def replay(arena, x_flat):
            arena = jax.lax.dynamic_update_slice(arena, x_flat, (self.input_off,))
            for op in ops:
                arena = op(arena)
            return jax.lax.dynamic_slice(arena, (out_off,), (n_out,))

        # Single-image path: the resident arena transfers host->device once;
        # steady-state serving moves only the input surface per call.
        self._fn = jax.jit(replay)
        # Batch path: the immutable weight region stays shared across lanes;
        # only the activation region [act_lo, act_hi) is vmapped per lane, so
        # each op moves O(batch * activations), not O(batch * whole arena).
        act_offs = []
        for d in self.descs:
            act_offs.append((d.src_addr - self.base,
                             d.src_addr - self.base + _surface_bytes(d.src_dims, 1)))
            act_offs.append((d.dst_addr - self.base,
                             d.dst_addr - self.base + _surface_bytes(d.dst_dims, 1)))
            if d.unit == "EW":
                act_offs.append((d.aux_addr - self.base,
                                 d.aux_addr - self.base + _surface_bytes(d.src_dims, 1)))
        act_lo = min(lo for lo, _ in act_offs)
        act_hi = max(hi for _, hi in act_offs)
        self._act_lo, self._act_hi = act_lo, act_hi
        in_region = (self.base + self.input_off,
                     _surface_bytes(self.input_dims, 1))
        fwd, store, store_input = _batch_plan(self.descs, in_region)
        bops = [_batched_op_from_descriptor(d, self.base, act_lo, fwd[i], store[i])
                for i, d in enumerate(self.descs)]

        def batch_replay(weights, act0, xs):
            def one(x_flat):
                act = act0
                if store_input:
                    act = jax.lax.dynamic_update_slice(
                        act, x_flat, (self.input_off - act_lo,))
                y = x_flat
                for bop in bops:
                    act, y = bop(weights, act, y)
                return y[:n_out]
            return jax.vmap(one)(xs)

        self._batch_fn = jax.jit(batch_replay)
        self._arena_dev = None      # created lazily from arena0
        self._batch_state = None    # (weights, act0) device pair, lazy
        # Optional NamedSharding over a 1-axis data mesh: when set (by the
        # scheduler's dispatcher), batch lanes are placed across devices and
        # GSPMD partitions the vmapped program; weights/activations replicate.
        self.batch_sharding = None

    def _ensure_arena(self):
        if self._arena_dev is None:
            self._arena_dev = jnp.asarray(self.arena0.view(np.int8))
        return self._arena_dev

    def reset_arena(self) -> None:
        """Drop the device-resident arena (next run re-materialises arena0)."""
        self._arena_dev = None
        self._batch_state = None

    def compile(self):
        """AOT-compile the fused program (the 'binary')."""
        x = jax.ShapeDtypeStruct((_surface_bytes(self.input_dims, 1),), jnp.int8)
        a = jax.ShapeDtypeStruct((self.size,), jnp.int8)
        return self._fn.lower(a, x).compile()

    def run(self, x: np.ndarray) -> ExecResult:
        xq = self._quant_in(x).reshape(-1)
        y = self._fn(self._ensure_arena(), jnp.asarray(xq.view(np.int8)))
        y_i8 = np.asarray(y).view(np.int8)[:self.output_elems]
        return ExecResult(output_int8=y_i8, output=self._dequant_out(y_i8))

    def capabilities(self) -> ExecutorCapabilities:
        return ExecutorCapabilities(native_batching=True, resident_arena=True,
                                    shardable=True, dtype=self.cfg.dtype)

    def run_batch(self, X: np.ndarray,
                  lanes: Optional[int] = None) -> ExecResult:
        """Run a batch as ONE vmapped XLA program (bit-exact vs N run calls).

        ``lanes`` trims the returned results to the first ``lanes`` rows (the
        rest being scheduler padding); the program itself always executes the
        full padded shape so each bucket size compiles exactly once.
        """
        X = np.asarray(X)
        xq = self._quant_in(X).reshape(X.shape[0], -1)
        if self._batch_state is None:
            self._batch_state = jnp.asarray(
                self.arena0.view(np.int8)[self._act_lo:self._act_hi])
        xs = jnp.asarray(xq.view(np.int8))
        if self.batch_sharding is not None and X.shape[0] % \
                self.batch_sharding.mesh.size == 0:
            xs = jax.device_put(xs, self.batch_sharding)
        y = np.asarray(self._batch_fn(self._ensure_arena(), self._batch_state,
                                      xs))
        y_i8 = y.view(np.int8)[:lanes, :self.output_elems]
        return ExecResult(output_int8=y_i8, output=self._dequant_out(y_i8))


class LinuxStackExecutor(_ExecutorBase):
    """Driver-stack baseline: per-op executables + tensor-table bookkeeping."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        # Pre-build one jitted callable per op (the 'driver' compiles per-layer
        # kernels); dispatch happens op-at-a-time from Python (the 'syscall').
        self._ops = []
        for d in self.descs:
            self._ops.append((d, jax.jit(self._op_fn(d))))

    def _op_fn(self, d: engine.Descriptor):
        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            def f(x, wq, bias, words):
                if d.unit == "CONV":
                    return _conv_int8(x, wq, bias, words, r, d.stride, d.pad,
                                      d.groups, d.relu)
                return _fc_int8(x, wq, bias, words, d.relu)
            return f
        if d.unit == "PDP":
            word = engine._pack_scale(d.out_scale)
            return lambda x: _pool_int8(x, d.kernel, d.stride, d.pad, d.pool_mode, word)
        if d.unit == "EW":
            wa, wb = engine._pack_scale(d.out_scale), engine._pack_scale(d.aux_scale)
            return lambda a, b: _add_int8(a, b, wa, wb, d.relu)
        raise ValueError(d.unit)

    def run(self, x: np.ndarray) -> ExecResult:
        xq = self._quant_in(x)
        dram = self.arena0.copy()       # driver re-stages buffers per submission

        def surf_i8(addr, dims):
            off = addr - self.base
            n, c, h, w = dims
            return dram[off:off + c * h * w].view(np.int8).reshape(c, h, w)

        in_off = self.descs[0].src_addr - self.base
        dram[in_off:in_off + xq.size] = xq.reshape(-1).view(np.uint8)
        for d, fn in self._ops:
            if d.unit in ("CONV", "FC"):
                _, c, h, w = d.src_dims
                k = d.dst_dims[1]
                r, s = d.kernel
                cin_g = c // d.groups if d.unit == "CONV" else c * h * w
                wt_n = k * cin_g * (r * s if d.unit == "CONV" else 1)
                wo, bo, so = d.wt_addr - self.base, d.bias_addr - self.base, d.scale_addr - self.base
                wq = dram[wo:wo + wt_n].view(np.int8).reshape(k, -1)
                bias = dram[bo:bo + 4 * k].view(np.int32)
                words = dram[so:so + 4 * k].view(np.int32)
                y = fn(surf_i8(d.src_addr, d.src_dims), wq, bias, words)
            elif d.unit == "PDP":
                y = fn(surf_i8(d.src_addr, d.src_dims))
            else:
                y = fn(surf_i8(d.src_addr, d.src_dims), surf_i8(d.aux_addr, d.src_dims))
            y = np.asarray(y).reshape(-1)
            doff = d.dst_addr - self.base
            dram[doff:doff + y.size] = y.view(np.uint8)   # driver flushes the buffer
        out = dram[self.output_off:self.output_off + self.output_elems].view(np.int8)
        return ExecResult(output_int8=out.copy(), output=self._dequant_out(out))
