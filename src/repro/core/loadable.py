"""Model compiler: (graph, fp32 params, calibration) -> Loadable.

This is the role the NVDLA compiler plays in the paper's Fig. 1: it turns a
Caffe-style model into (a) a fully static sequence of engine descriptors and
(b) the preloaded DRAM image (quantised weights, int32 biases, fixed-point
per-channel scale tables) laid out by the arena planner.

The Loadable is what the virtual platform executes; the CSB/DBB logs of that
execution are then distilled into the bare-metal trace (core/vp.py,
core/tracegen.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine, memory, quant
from repro.core.graph import NetGraph


@dataclasses.dataclass
class Loadable:
    graph: NetGraph
    cfg: engine.EngineConfig
    plan: memory.ArenaPlan
    descriptors: List[engine.Descriptor]
    desc_layers: List[str]              # layer name per descriptor
    dram_image: np.ndarray              # uint8, static region [weights..weight_end)
    input_scale: float
    output_scale: float

    @property
    def input_surface(self) -> memory.Surface:
        return self.plan.surfaces["data"]

    @property
    def output_surface(self) -> memory.Surface:
        return self.plan.surfaces[self.graph.output]


def calibrate(graph: NetGraph, params: Dict[str, Dict[str, np.ndarray]],
              samples: np.ndarray, percentile: float = 99.99) -> quant.CalibrationTable:
    """Run fp32 reference forward passes, record per-layer |activation| scales.

    ``samples``: (N, C, H, W) float32 calibration batch.  Implements the paper's
    future-work item (INT8 calibration-table generation).
    """
    from repro.core import refops   # local import to avoid cycle

    by = graph.by_name()
    maxes: Dict[str, float] = {l.name: 1e-8 for l in graph.layers}
    for x in samples:
        acts: Dict[str, np.ndarray] = {}
        for l in graph.layers:
            if l.type == "input":
                acts[l.name] = x.astype(np.float32)
            elif l.type == "conv":
                p = params[l.name]
                acts[l.name] = refops.conv_bf16(acts[l.inputs[0]], p["w"], p["b"],
                                                l.kernel, l.stride, l.pad, l.groups, l.relu)
            elif l.type == "fc":
                p = params[l.name]
                acts[l.name] = refops.fc_bf16(acts[l.inputs[0]], p["w"], p["b"], l.relu)
            elif l.type == "pool":
                xin = acts[l.inputs[0]]
                if l.pool_mode == "gap":
                    acts[l.name] = xin.mean(axis=(1, 2), keepdims=True)
                elif l.pool_mode == "max":
                    acts[l.name] = _pool_f32(xin, l, "max")
                else:
                    acts[l.name] = _pool_f32(xin, l, "avg")
            elif l.type == "add":
                a = acts[l.inputs[0]] + acts[l.inputs[1]]
                acts[l.name] = np.maximum(a, 0) if l.relu else a
            elif l.type == "concat":
                acts[l.name] = np.concatenate([acts[i] for i in l.inputs], axis=0)
            for name, a in acts.items():
                maxes[name] = max(maxes[name], float(np.percentile(np.abs(a), percentile)))
    scales = {k: v / quant.INT8_MAX for k, v in maxes.items()}

    # Scale unification (standard): pools & concat inherit/unify with their inputs
    # so those ops are scale-free on the engine.
    for l in graph.layers:
        if l.type == "pool" and l.pool_mode == "max":
            scales[l.name] = scales[l.inputs[0]]
        if l.type == "concat":
            for i in l.inputs:
                scales[i] = scales[l.name]
    return quant.CalibrationTable(scales)


def _pool_f32(x: np.ndarray, l, mode: str) -> np.ndarray:
    c, h, w = x.shape
    k, st, pad = l.kernel, l.stride, l.pad
    fill = -np.inf if mode == "max" else 0.0
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=fill)
    p = (h + 2 * pad - k) // st + 1
    q = (w + 2 * pad - k) // st + 1
    acc = np.full((c, p, q), fill, np.float32)
    for r in range(k):
        for s in range(k):
            win = xp[:, r:r + st * p:st, s:s + st * q:st]
            acc = np.maximum(acc, win) if mode == "max" else acc + win
    return acc if mode == "max" else acc / (k * k)


def build_loadable(graph: NetGraph, params: Dict[str, Dict[str, np.ndarray]],
                   cal: quant.CalibrationTable,
                   cfg: engine.EngineConfig = engine.NV_SMALL) -> Loadable:
    if cfg.dtype == "bf16":
        return _build_loadable_bf16(graph, params, cal, cfg)
    if cfg.dtype != "int8":
        known = ", ".join(f"{n} (dtype={c.dtype})"
                          for n, c in engine.CONFIGS.items())
        raise ValueError(
            f"cannot build a loadable for engine dtype {cfg.dtype!r} "
            f"(config {cfg.name!r}); supported datapaths are int8 (nv_small) "
            f"and bf16 (nv_full).  Known engine configs: {known}")
    plan = memory.plan_arena(graph, elem_bytes=1)
    by = graph.by_name()
    image = np.zeros(plan.weight_end - engine.DRAM_BASE, np.uint8)
    descs: List[engine.Descriptor] = []
    names: List[str] = []

    def blit(addr: int, data: np.ndarray):
        off = addr - engine.DRAM_BASE
        raw = data.tobytes()
        image[off:off + len(raw)] = np.frombuffer(raw, np.uint8)

    def dims(name: str) -> tuple:
        c, h, w = by[name].out_shape if by[name].out_shape else graph.input_shape
        return (1, c, h, w)

    for l in graph.layers:
        if l.type in ("input", "concat"):
            continue    # concat is pure addressing (planner laid members adjacently)
        src = l.inputs[0]
        s_in = cal.scales[src]
        s_out = cal.scales[l.name]
        d = engine.Descriptor(
            unit={"conv": "CONV", "fc": "FC", "pool": "PDP", "add": "EW"}[l.type],
            src_addr=plan.surfaces[src].addr,
            src_dims=dims(src),
            dst_addr=plan.surfaces[l.name].addr,
            dst_dims=dims(l.name),
            relu=l.relu,
        )
        if l.type in ("conv", "fc"):
            p = params[l.name]
            wq, wscales = quant.quantize_weights(p["w"])
            cin_g = (by[src].out_shape[0] // l.groups if l.type == "conv"
                     else int(np.prod(by[src].out_shape)))
            kk = l.kernel if l.type == "conv" else 1
            max_acc = cin_g * kk * kk * 128 * 127 + 2**20   # acc bound + bias headroom
            bias_q = quant.quantize_bias(p["b"], s_in, wscales)
            words = quant.requant_table(s_in * wscales, s_out, max_acc)
            blit(plan.surfaces[f"{l.name}.w"].addr, wq.reshape(wq.shape[0], -1))
            blit(plan.surfaces[f"{l.name}.b"].addr, bias_q)
            blit(plan.surfaces[f"{l.name}.s"].addr, words)
            d.wt_addr = plan.surfaces[f"{l.name}.w"].addr
            d.bias_addr = plan.surfaces[f"{l.name}.b"].addr
            d.scale_addr = plan.surfaces[f"{l.name}.s"].addr
            d.kernel = (kk, kk)
            d.stride, d.pad = l.stride, l.pad
            d.groups = l.groups
        elif l.type == "pool":
            d.pool_mode = 1 if l.pool_mode == "max" else 2
            if l.pool_mode == "gap":
                c, h, w = by[src].out_shape
                d.kernel, d.stride, d.pad = (h, w), h, 0
                d.out_scale = quant.fixed_point(s_in / (s_out * h * w), h * w * 128)
            elif l.pool_mode == "avg":
                d.kernel = (l.kernel, l.kernel)
                d.stride, d.pad = l.stride, l.pad
                d.out_scale = quant.fixed_point(
                    s_in / (s_out * l.kernel * l.kernel), l.kernel * l.kernel * 128)
            else:
                d.kernel = (l.kernel, l.kernel)
                d.stride, d.pad = l.stride, l.pad
        elif l.type == "add":
            d.residual = True
            d.aux_addr = plan.surfaces[l.inputs[1]].addr
            d.out_scale = quant.fixed_point(cal.scales[l.inputs[0]] / s_out, 128)
            d.aux_scale = quant.fixed_point(cal.scales[l.inputs[1]] / s_out, 128)
        descs.append(d)
        names.append(l.name)

    return Loadable(graph=graph, cfg=cfg, plan=plan, descriptors=descs,
                    desc_layers=names, dram_image=image,
                    input_scale=cal.scales["data"],
                    output_scale=cal.scales[graph.output])


def _build_loadable_bf16(graph: NetGraph, params, cal, cfg) -> Loadable:
    """nv_full path: bf16 weights/activations, float accumulate, no requant."""
    import ml_dtypes
    plan = memory.plan_arena(graph, elem_bytes=2)
    by = graph.by_name()
    image = np.zeros(plan.weight_end - engine.DRAM_BASE, np.uint8)
    descs: List[engine.Descriptor] = []
    names: List[str] = []

    def blit(addr: int, data: np.ndarray):
        off = addr - engine.DRAM_BASE
        raw = data.tobytes()
        image[off:off + len(raw)] = np.frombuffer(raw, np.uint8)

    def dims(name: str) -> tuple:
        c, h, w = by[name].out_shape if by[name].out_shape else graph.input_shape
        return (1, c, h, w)

    for l in graph.layers:
        if l.type in ("input", "concat"):
            continue
        src = l.inputs[0]
        d = engine.Descriptor(
            unit={"conv": "CONV", "fc": "FC", "pool": "PDP", "add": "EW"}[l.type],
            src_addr=plan.surfaces[src].addr, src_dims=dims(src),
            dst_addr=plan.surfaces[l.name].addr, dst_dims=dims(l.name), relu=l.relu)
        if l.type in ("conv", "fc"):
            p = params[l.name]
            kk = l.kernel if l.type == "conv" else 1
            blit(plan.surfaces[f"{l.name}.w"].addr,
                 p["w"].reshape(p["w"].shape[0], -1).astype(ml_dtypes.bfloat16))
            blit(plan.surfaces[f"{l.name}.b"].addr, p["b"].astype(np.float32))
            d.wt_addr = plan.surfaces[f"{l.name}.w"].addr
            d.bias_addr = plan.surfaces[f"{l.name}.b"].addr
            d.kernel = (kk, kk)
            d.stride, d.pad = l.stride, l.pad
            d.groups = l.groups
        elif l.type == "pool":
            d.pool_mode = 1 if l.pool_mode == "max" else 2
            if l.pool_mode == "gap":
                c, h, w = by[src].out_shape
                d.kernel, d.stride, d.pad = (h, w), h, 0
            else:
                d.kernel = (l.kernel, l.kernel)
                d.stride, d.pad = l.stride, l.pad
        elif l.type == "add":
            d.residual = True
            d.aux_addr = plan.surfaces[l.inputs[1]].addr
        descs.append(d)
        names.append(l.name)
    return Loadable(graph=graph, cfg=cfg, plan=plan, descriptors=descs,
                    desc_layers=names, dram_image=image, input_scale=1.0,
                    output_scale=1.0)
