"""Virtual platform (paper Fig. 3): execute a Loadable, log every interface
transaction.

The real flow runs the NVDLA compiler's output on a QEMU+SystemC co-simulation and
captures the CSB (register) and DBB (data backbone) adaptors' logs.  Our VP is the
functional twin: it executes each descriptor with the numpy reference ops
(core/refops.py) while emitting log lines in the same shape the paper's scripts
parse:

    <t> ns: nvdla.csb_adaptor: iswrite=1 addr=0x00005008 data=0x00100040
    <t> ns: nvdla.dbb_adaptor: iswrite=0 addr=0x00100040 len=64 data=00ab12...

From this log, ``core/tracegen.py`` produces the bare-metal configuration file and
``core/memory.extract_weights`` reconstructs the preloaded weight image — i.e. the
entire bare-metal artifact is derived from the log alone, as in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine, memory, quant, refops
from repro.core.loadable import Loadable


@dataclasses.dataclass
class VpResult:
    log: str                      # full transaction log
    output_int8: np.ndarray       # raw engine output (int8 / bf16 bytes)
    output: np.ndarray            # dequantised float output
    n_csb_writes: int
    n_csb_reads: int
    dbb_bytes: int


class VirtualPlatform:
    """Functional co-simulation of the SoC (µRISC-V + engine + DRAM)."""

    def __init__(self, loadable: Loadable, beat_bytes: int = 4096,
                 log_weight_refetch: bool = False):
        self.ld = loadable
        self.beat = beat_bytes
        self.refetch = log_weight_refetch
        self._lines: List[str] = []
        self._t = 0
        # DRAM model: flat byte array covering the arena
        self.dram = np.zeros(loadable.plan.arena_size, np.uint8)
        img = loadable.dram_image
        self.dram[:img.size] = img

    # ---- bus-level helpers --------------------------------------------------
    def _tick(self, n: int = 1):
        self._t += n

    def _csb_write(self, addr: int, data: int):
        self._lines.append(
            f"{self._t} ns: nvdla.csb_adaptor: iswrite=1 addr={addr:#010x} data={data & 0xFFFFFFFF:#010x}")
        self._tick(4)

    def _csb_read(self, addr: int, data: int):
        self._lines.append(
            f"{self._t} ns: nvdla.csb_adaptor: iswrite=0 addr={addr:#010x} data={data & 0xFFFFFFFF:#010x}")
        self._tick(4)

    def _dbb(self, iswrite: int, addr: int, buf: bytes):
        """Log one burst as beat-sized transactions."""
        for off in range(0, len(buf), self.beat):
            chunk = buf[off:off + self.beat]
            self._lines.append(
                f"{self._t} ns: nvdla.dbb_adaptor: iswrite={iswrite} "
                f"addr={addr + off:#010x} len={len(chunk)} data={chunk.hex()}")
            self._tick(len(chunk) // 8 + 1)

    def _read_dram(self, addr: int, size: int, log: bool = True) -> bytes:
        off = addr - engine.DRAM_BASE
        buf = self.dram[off:off + size].tobytes()
        if log:
            self._dbb(0, addr, buf)
        return buf

    def _write_dram(self, addr: int, buf: bytes, log: bool = True):
        off = addr - engine.DRAM_BASE
        self.dram[off:off + len(buf)] = np.frombuffer(buf, np.uint8)
        if log:
            self._dbb(1, addr, buf)

    # ---- execution ----------------------------------------------------------
    def run(self, x: np.ndarray) -> VpResult:
        """Execute one inference.  ``x``: float32 (C,H,W) input image."""
        ld = self.ld
        int8 = ld.cfg.dtype == "int8"
        if int8:
            xq = quant.quantize_act(x, ld.input_scale)
            in_bytes = xq.tobytes()
        else:
            import ml_dtypes
            in_bytes = x.astype(ml_dtypes.bfloat16).tobytes()
        # Host (Zynq in the paper) preloads the input image — logged as DBB writes
        # so weight extraction sees the input surface as preloaded data.
        self._write_dram(ld.input_surface.addr, in_bytes)

        for d, lname in zip(ld.descriptors, ld.desc_layers):
            for addr, val in d.to_reg_writes():
                self._csb_write(addr, val)
            self._execute(d)
            self._csb_read(engine.reg_addr(d.unit, "STATUS"), engine.DONE)

        out_sf = ld.output_surface
        raw = self._read_dram(out_sf.addr, out_sf.size, log=False)
        if int8:
            out_i8 = np.frombuffer(raw, np.int8).copy()
            n = int(np.prod(ld.graph.by_name()[ld.graph.output].out_shape))
            out_i8 = out_i8[:n]
            out = out_i8.astype(np.float32) * ld.output_scale
        else:
            import ml_dtypes
            out_i8 = np.frombuffer(raw, np.uint8).copy()
            n = int(np.prod(ld.graph.by_name()[ld.graph.output].out_shape))
            out = np.frombuffer(raw, ml_dtypes.bfloat16)[:n].astype(np.float32)
        log = "\n".join(self._lines)
        nw = sum("csb_adaptor: iswrite=1" in l for l in self._lines)
        nr = sum("csb_adaptor: iswrite=0" in l for l in self._lines)
        dbb_b = sum(int(l.split("len=")[1].split(" ")[0])
                    for l in self._lines if "dbb_adaptor" in l)
        return VpResult(log=log, output_int8=out_i8, output=out,
                        n_csb_writes=nw, n_csb_reads=nr, dbb_bytes=dbb_b)

    # -- engine functional model ---------------------------------------------
    def _execute(self, d: engine.Descriptor):
        if self.ld.cfg.dtype == "int8":
            self._execute_int8(d)
        else:
            self._execute_bf16(d)

    def _surface_i8(self, addr: int, dims: tuple) -> np.ndarray:
        n, c, h, w = dims
        raw = self._read_dram(addr, c * h * w, log=True)
        return np.frombuffer(raw, np.int8).reshape(c, h, w)

    def _execute_int8(self, d: engine.Descriptor):
        _, c, h, w = d.src_dims
        _, k, p, q = d.dst_dims
        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            cin_g = c // d.groups if d.unit == "CONV" else c * h * w
            wt_elems = (k * cin_g * r * s) if d.unit == "CONV" else k * cin_g
            n_tiles = 1
            if self.refetch:
                n_tiles = max(1, -(-wt_elems // (self.ld.cfg.conv_buf_kib * 1024)))
            for _ in range(n_tiles):   # CDMA refetches weights per output tile
                wraw = self._read_dram(d.wt_addr, wt_elems)
            wq = np.frombuffer(wraw, np.int8).reshape(k, -1)
            braw = self._read_dram(d.bias_addr, k * 4)
            bias = np.frombuffer(braw, np.int32)
            sraw = self._read_dram(d.scale_addr, k * 4)
            words = np.frombuffer(sraw, np.uint32)
            x = self._surface_i8(d.src_addr, d.src_dims)
            if d.unit == "CONV":
                y = refops.conv_int8(x, wq.reshape(k, cin_g, r, s).reshape(k, -1),
                                     bias, words, r, d.stride, d.pad, d.groups, d.relu)
            else:
                y = refops.fc_int8(x, wq, bias, words, d.relu)
            self._write_dram(d.dst_addr, y.tobytes())
        elif d.unit == "PDP":
            x = self._surface_i8(d.src_addr, d.src_dims)
            r, s = d.kernel
            if d.pool_mode == 1:
                y = refops.maxpool_int8(x, r, d.stride, d.pad)
            else:
                word = engine._pack_scale(d.out_scale)
                if (r, s) == (h, w) and d.pad == 0:
                    y = refops.gap_int8(x, word)
                else:
                    y = refops.avgpool_int8(x, r, d.stride, d.pad, word)
            self._write_dram(d.dst_addr, y.tobytes())
        elif d.unit == "EW":
            a = self._surface_i8(d.src_addr, d.src_dims)
            b = self._surface_i8(d.aux_addr, d.src_dims)
            y = refops.add_int8(a, b, engine._pack_scale(d.out_scale),
                                engine._pack_scale(d.aux_scale), d.relu)
            self._write_dram(d.dst_addr, y.tobytes())
        else:
            raise ValueError(d.unit)

    def _execute_bf16(self, d: engine.Descriptor):
        import ml_dtypes
        _, c, h, w = d.src_dims
        _, k, p, q = d.dst_dims

        def surf(addr, dims):
            n_, c_, h_, w_ = dims
            raw = self._read_dram(addr, c_ * h_ * w_ * 2)
            return np.frombuffer(raw, ml_dtypes.bfloat16).reshape(c_, h_, w_)

        if d.unit in ("CONV", "FC"):
            r, s = d.kernel
            cin_g = c // d.groups if d.unit == "CONV" else c * h * w
            wraw = self._read_dram(d.wt_addr, k * cin_g * (r * s if d.unit == "CONV" else 1) * 2)
            wq = np.frombuffer(wraw, ml_dtypes.bfloat16).reshape(k, -1)
            braw = self._read_dram(d.bias_addr, k * 4)
            bias = np.frombuffer(braw, np.float32)
            x = surf(d.src_addr, d.src_dims)
            if d.unit == "CONV":
                y = refops.conv_bf16(x, wq, bias, r, d.stride, d.pad, d.groups, d.relu)
            else:
                y = refops.fc_bf16(x, wq, bias, d.relu)
            self._write_dram(d.dst_addr, y.astype(ml_dtypes.bfloat16).tobytes())
        elif d.unit == "PDP":
            x = surf(d.src_addr, d.src_dims).astype(np.float32)
            r, s = d.kernel
            if d.pool_mode == 1:
                y = refops.pool_f32(x, r, s, d.stride, d.pad, "max")
            elif (r, s) == (h, w) and d.pad == 0:
                y = x.mean(axis=(1, 2), keepdims=True)
            else:
                y = refops.pool_f32(x, r, s, d.stride, d.pad, "avg")
            self._write_dram(d.dst_addr, y.astype(ml_dtypes.bfloat16).tobytes())
        elif d.unit == "EW":
            a = surf(d.src_addr, d.src_dims).astype(np.float32)
            b = surf(d.aux_addr, d.src_dims).astype(np.float32)
            y = a + b
            if d.relu:
                y = np.maximum(y, 0)
            self._write_dram(d.dst_addr, y.astype(ml_dtypes.bfloat16).tobytes())
