"""jnp twins of the engine's integer arithmetic — the ONE shared copy.

Every jax-side arm of the NVDLA SDP semantics (the executors' op closures,
the Pallas ``int8_conv`` kernel and its oracle) imports these, so a fix to
the round-half-away shift, the scale-word unpack, or the requant pipeline
cannot silently diverge between arms.  The numpy oracle lives separately in
``core/quant.py`` / ``core/refops.py`` — it must stay independent, since the
whole point of the refops parity tests is two implementations.

This is a leaf module: it imports nothing from ``repro`` (both ``core`` and
``kernels`` depend on it).
"""

from __future__ import annotations

import jax.numpy as jnp


def rha_shift(x, k):
    """Round-half-away-from-zero arithmetic right shift on int32."""
    k = jnp.asarray(k, jnp.int32)
    half = jnp.where(k > 0, jnp.left_shift(jnp.int32(1), jnp.maximum(k - 1, 0)), 0)
    return jnp.sign(x) * jnp.right_shift(jnp.abs(x) + half, k)


def apply_scale(x, m, pre, post):
    """Fixed-point rescale: ``((x >> pre) * m) >> post`` with rha rounding."""
    return rha_shift(rha_shift(x, pre) * m, post)


def unpack_words(words_i32):
    """uint32 scale words (bitcast to int32) -> (m, pre, post) int32 arrays."""
    w = words_i32
    m = jnp.right_shift(w, 16) & 0xFFFF            # arithmetic shift ok: masked
    m = jnp.where(m >= 0x8000, m - 0x10000, m)
    pre = jnp.right_shift(w, 8) & 0xFF
    post = w & 0xFF
    return m, pre, post


def clip8(x):
    return jnp.clip(x, -128, 127).astype(jnp.int8)


def row_epilogue(acc, bias, words, relu):
    """SDP epilogue, per-channel on the M (row) axis: +bias, requant, relu,
    int8 clip.  ``acc`` (M, N) int32; ``bias``/``words`` (M,) int32."""
    acc = acc + bias[:, None]
    m, pre, post = unpack_words(words)
    out = apply_scale(acc, m[:, None], pre[:, None], post[:, None])
    if relu:
        out = jnp.maximum(out, 0)
    return clip8(out)


def im2col(x, k: int, stride: int, pad: int):
    """(C,H,W) int8 -> (C*k*k, P*Q) int8, static shapes."""
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    p = (h + 2 * pad - k) // stride + 1
    q = (w + 2 * pad - k) // stride + 1
    cols = []
    for r in range(k):
        for s in range(k):
            cols.append(xp[:, r:r + stride * p:stride, s:s + stride * q:stride])
    return jnp.stack(cols, 1).reshape(c * k * k, p * q)
