"""Numpy reference semantics for every engine op (the VP's functional model).

These are the *oracle* implementations: integer-exact for the nv_small INT8 path
(all int32 intermediates, deterministic across platforms) and float32-accumulate
for the nv_full bf16 path.  The jax executors (core/executor.py) and the Pallas
kernels (kernels/) are tested against these.

Data layout: activations are (C, H, W) int8 (NVDLA feature-data layout, N=1 per
inference as in the paper); conv weights are (K, C/g, R, S) int8 stored row-major
as a (K, C/g*R*S) GEMM matrix — the im2col adaptation that maps NVDLA's direct
convolution onto a TPU MXU-shaped matmul.
"""

from __future__ import annotations

import numpy as np

from repro.core import quant


def im2col(x: np.ndarray, k: int, stride: int, pad: int) -> np.ndarray:
    """(C,H,W) -> (C*k*k, P*Q) patch matrix."""
    c, h, w = x.shape
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    p = (h + 2 * pad - k) // stride + 1
    q = (w + 2 * pad - k) // stride + 1
    cols = np.empty((c, k, k, p, q), x.dtype)
    for r in range(k):
        for s in range(k):
            cols[:, r, s] = xp[:, r:r + stride * p:stride, s:s + stride * q:stride]
    return cols.reshape(c * k * k, p * q)


def conv_int8(x: np.ndarray, w: np.ndarray, bias: np.ndarray,
              scale_words: np.ndarray, k: int, stride: int, pad: int,
              groups: int = 1, relu: bool = False) -> np.ndarray:
    """CONV+SDP pipeline: int8 GEMM -> +bias(int32) -> per-ch requant -> relu."""
    c, h, w_in = x.shape
    kk = w.shape[0]
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        cols = im2col(x, k, stride, pad)                         # (C*k*k, P*Q)
        acc = w.astype(np.int32) @ cols.astype(np.int32)          # (K, P*Q)
    else:
        cg, kg = c // groups, kk // groups
        acc = np.empty((kk, p * q), np.int32)
        xg = x.reshape(groups, cg, h, w_in)
        wg = w.reshape(groups, kg, -1)
        for g in range(groups):                                   # vectorised per group
            cols = im2col(xg[g], k, stride, pad)
            acc[g * kg:(g + 1) * kg] = wg[g].astype(np.int32) @ cols.astype(np.int32)
    acc = acc + bias.astype(np.int32)[:, None]
    m, pre, post = _unpack_words(scale_words)
    out = quant.apply_scale(acc, m[:, None], pre[:, None], post[:, None])
    if relu:
        out = np.maximum(out, 0)
    return quant.clip8(out).reshape(kk, p, q)


def fc_int8(x: np.ndarray, w: np.ndarray, bias: np.ndarray,
            scale_words: np.ndarray, relu: bool = False) -> np.ndarray:
    acc = w.astype(np.int32) @ x.reshape(-1).astype(np.int32) + bias.astype(np.int32)
    m, pre, post = _unpack_words(scale_words)
    out = quant.apply_scale(acc, m, pre, post)
    if relu:
        out = np.maximum(out, 0)
    return quant.clip8(out).reshape(-1, 1, 1)


def maxpool_int8(x: np.ndarray, k: int, stride: int, pad: int) -> np.ndarray:
    c, h, w = x.shape
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=quant.INT8_MIN)
    p = (h + 2 * pad - k) // stride + 1
    q = (w + 2 * pad - k) // stride + 1
    out = np.full((c, p, q), quant.INT8_MIN, np.int8)
    for r in range(k):
        for s in range(k):
            out = np.maximum(out, xp[:, r:r + stride * p:stride, s:s + stride * q:stride])
    return out


def avgpool_int8(x: np.ndarray, k: int, stride: int, pad: int,
                 scale_word: int) -> np.ndarray:
    """Sum in int32, then fixed-point multiply by ~1/(k*k) (SDP-style)."""
    c, h, w = x.shape
    xp = np.pad(x.astype(np.int32), ((0, 0), (pad, pad), (pad, pad)))
    p = (h + 2 * pad - k) // stride + 1
    q = (w + 2 * pad - k) // stride + 1
    acc = np.zeros((c, p, q), np.int32)
    for r in range(k):
        for s in range(k):
            acc += xp[:, r:r + stride * p:stride, s:s + stride * q:stride]
    m, pre, post = quant.unpack_scale(int(scale_word))
    return quant.clip8(quant.apply_scale(acc, m, pre, post))


def gap_int8(x: np.ndarray, scale_word: int) -> np.ndarray:
    acc = x.astype(np.int32).sum(axis=(1, 2), keepdims=True)
    m, pre, post = quant.unpack_scale(int(scale_word))
    return quant.clip8(quant.apply_scale(acc, m, pre, post))


def add_int8(a: np.ndarray, b: np.ndarray, word_a: int, word_b: int,
             relu: bool = False) -> np.ndarray:
    """Residual add: both operands rescaled to the output scale, int32 sum."""
    ma, pa, sa = quant.unpack_scale(int(word_a))
    mb, pb, sb = quant.unpack_scale(int(word_b))
    acc = (quant.apply_scale(a.astype(np.int32), ma, pa, sa)
           + quant.apply_scale(b.astype(np.int32), mb, pb, sb))
    if relu:
        acc = np.maximum(acc, 0)
    return quant.clip8(acc)


def _unpack_words(words: np.ndarray):
    w = np.asarray(words, np.uint32)
    m = ((w >> 16) & 0xFFFF).astype(np.int32)
    m = np.where(m & 0x8000, m - 0x10000, m)
    return m, ((w >> 8) & 0xFF).astype(np.int32), (w & 0xFF).astype(np.int32)


# ---------------------------------------------------------------------------
# nv_full bf16 path (float32 accumulation; checked with tolerances, not bit-exact)
# ---------------------------------------------------------------------------
def conv_bf16(x: np.ndarray, w: np.ndarray, bias: np.ndarray, k: int, stride: int,
              pad: int, groups: int = 1, relu: bool = False) -> np.ndarray:
    x32 = x.astype(np.float32)
    w32 = w.astype(np.float32).reshape(w.shape[0], -1)   # accept (K,C/g,R,S) or (K, C/g*R*S)
    c, h, w_in = x.shape
    kk = w.shape[0]
    p = (h + 2 * pad - k) // stride + 1
    q = (w_in + 2 * pad - k) // stride + 1
    if groups == 1:
        acc = w32 @ im2col(x32, k, stride, pad)
    else:
        cg, kg = c // groups, kk // groups
        acc = np.empty((kk, p * q), np.float32)
        xg, wg = x32.reshape(groups, cg, h, w_in), w32.reshape(groups, kg, -1)
        for g in range(groups):
            acc[g * kg:(g + 1) * kg] = wg[g] @ im2col(xg[g], k, stride, pad)
    acc = acc + bias.astype(np.float32)[:, None]
    if relu:
        acc = np.maximum(acc, 0)
    return acc.reshape(kk, p, q)


def fc_bf16(x: np.ndarray, w: np.ndarray, bias: np.ndarray, relu: bool = False) -> np.ndarray:
    acc = w.astype(np.float32) @ x.reshape(-1).astype(np.float32) + bias.astype(np.float32)
    if relu:
        acc = np.maximum(acc, 0)
    return acc.reshape(-1, 1, 1)


def pool_f32(x: np.ndarray, r: int, s: int, stride: int, pad: int,
             mode: str) -> np.ndarray:
    """Float PDP reference (max with -inf fill / avg as sum over window),
    shared by the VP functional model and the ref executor backend — ONE
    copy of the nv_full pooling semantics."""
    c, h, w = x.shape
    fill = -np.inf if mode == "max" else 0.0
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=fill)
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    acc = np.full((c, p, q), fill, np.float32)
    for i in range(r):
        for j in range(s):
            win = xp[:, i:i + stride * p:stride, j:j + stride * q:stride]
            acc = np.maximum(acc, win) if mode == "max" else acc + win
    return acc if mode == "max" else acc / (r * s)
