"""Core API — now two composable layers (compiler / runtime).

**Compiler** (``repro.core.pipeline``): the paper's toolflow (Fig. 1) as a
``CompilerPipeline`` of named, individually-runnable stages —

    calibrate -> build_loadable -> vp_run -> parse_trace -> assemble
                                          -> extract_weights
                 build_loadable -> cost_model

    pipe = CompilerPipeline(graph)
    cal  = pipe.run_stage("calibrate")      # any intermediate, on demand
    art  = pipe.run()                       # full Artifacts
    art.save("bundle/")                     # trace.cfg + weights.img + program.bin
    art2 = Artifacts.load("bundle/")        # runnable again, no VP re-execution

**Runtime** (``repro.runtime``): a ``Session`` serving one or more compiled
networks over registered executor backends (``baremetal`` / ``linuxstack`` /
``ref``; extensible via ``@register_backend``):

    ses = Session(art)                      # arena resident on device
    ses.run(x)                              # single image
    ses.run_batch(X)                        # one vmapped program per batch

**Migration from the old one-shot API** (both shims below still work but emit
``DeprecationWarning``):

    compile_network(g, ...)         -> CompilerPipeline(g, ...).run()
    make_executor(art, "baremetal") -> Session(art, backend="baremetal")
                                       (or repro.runtime.create_executor)
"""

from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.core import engine
from repro.core.graph import NetGraph
from repro.core.pipeline import Artifacts, CompilerPipeline

__all__ = ["Artifacts", "CompilerPipeline", "compile_network", "make_executor"]


def compile_network(graph: NetGraph, params=None,
                    calib_samples: Optional[np.ndarray] = None,
                    cfg: engine.EngineConfig = engine.NV_SMALL,
                    sample_input: Optional[np.ndarray] = None,
                    seed: int = 0) -> Artifacts:
    """Deprecated one-shot compile; use ``CompilerPipeline(graph, ...).run()``."""
    warnings.warn(
        "compile_network() is deprecated; use "
        "repro.core.pipeline.CompilerPipeline(graph, ...).run()",
        DeprecationWarning, stacklevel=2)
    # use_cache=False: legacy callers expect a real compile returning fresh,
    # independently-owned artifacts — not aliases into the shared stage cache
    return CompilerPipeline(graph, params=params, calib_samples=calib_samples,
                            cfg=cfg, sample_input=sample_input, seed=seed,
                            use_cache=False).run()


def make_executor(art: Artifacts, kind: str = "baremetal"):
    """Deprecated executor factory; use ``repro.runtime.Session`` (or
    ``repro.runtime.create_executor``).  Unknown kinds raise ``ValueError``."""
    warnings.warn(
        "make_executor() is deprecated; use repro.runtime.Session(artifacts, "
        "backend=...) or repro.runtime.create_executor(kind, artifacts)",
        DeprecationWarning, stacklevel=2)
    from repro.runtime import create_executor
    return create_executor(kind, art)
