"""End-to-end toolflow (paper Fig. 1): Caffe-style model -> bare-metal artifacts.

    artifacts = compile_network(graph, params, calib_samples)
      1. calibrate INT8 scales               (paper future work, implemented)
      2. build Loadable                      (NVDLA-compiler stage)
      3. run once on the Virtual Platform    (QEMU+SystemC analogue) -> logs
      4. parse CSB log -> configuration file (trace)
      5. parse DBB log -> weight image       (first-occurrence dedup)
      6. assemble trace -> RV32I binary      (program memory image)

    ex = BareMetalExecutor(artifacts.trace, artifacts.weight_image, ...)
    ex.run(image)
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import asm as asm_mod
from repro.core import engine, memory, quant, tracegen
from repro.core.executor import BareMetalExecutor, LinuxStackExecutor
from repro.core.graph import NetGraph
from repro.core.loadable import Loadable, build_loadable, calibrate
from repro.core.perfmodel import ModelCost, model_cost
from repro.core.vp import VirtualPlatform


@dataclasses.dataclass
class Artifacts:
    """Everything the bare-metal SoC needs (and nothing else)."""
    graph_name: str
    cfg: engine.EngineConfig
    trace: tracegen.Trace            # configuration file
    trace_text: str                  # its serialised form
    weight_image: Dict[int, bytes]   # extracted, deduped preload image
    asm_text: str                    # RISC-V assembly
    program_binary: bytes            # assembled program-memory image
    input_scale: float
    output_scale: float
    output_elems: int
    loadable: Loadable               # kept for tests/benchmarks (not shipped)
    vp_output: np.ndarray            # VP reference output (float)
    vp_output_int8: np.ndarray
    cost: ModelCost                  # cycle model (Tables II/III)

    # -- storage accounting (Table I analogue) -------------------------------
    def storage_report(self) -> Dict[str, int]:
        wbytes = sum(len(b) for b in self.weight_image.values())
        return {
            "config_file_bytes": len(self.trace_text.encode()),
            "program_binary_bytes": len(self.program_binary),
            "weight_image_bytes": wbytes,
            "n_write_reg": self.trace.n_writes,
            "n_read_reg": self.trace.n_reads,
        }


def compile_network(graph: NetGraph, params=None,
                    calib_samples: Optional[np.ndarray] = None,
                    cfg: engine.EngineConfig = engine.NV_SMALL,
                    sample_input: Optional[np.ndarray] = None,
                    seed: int = 0) -> Artifacts:
    params = params if params is not None else graph.init_params(seed)
    if calib_samples is None:
        rng = np.random.default_rng(seed + 1)
        calib_samples = rng.normal(0, 1, (2,) + graph.input_shape).astype(np.float32)
    cal = calibrate(graph, params, calib_samples)
    ld = build_loadable(graph, params, cal, cfg)

    vp = VirtualPlatform(ld)
    x0 = sample_input if sample_input is not None else calib_samples[0]
    res = vp.run(x0)

    trace = tracegen.parse_csb(res.log)
    weight_image = memory.extract_weights(tracegen.parse_dbb(res.log))
    asm_text, binary = asm_mod.assemble(trace)
    cost = model_cost(ld.descriptors, cfg, ld.desc_layers)
    n_out = int(np.prod(graph.by_name()[graph.output].out_shape))
    return Artifacts(
        graph_name=graph.name, cfg=cfg, trace=trace, trace_text=trace.to_text(),
        weight_image=weight_image, asm_text=asm_text, program_binary=binary,
        input_scale=ld.input_scale, output_scale=ld.output_scale,
        output_elems=n_out, loadable=ld, vp_output=res.output,
        vp_output_int8=res.output_int8, cost=cost)


def make_executor(art: Artifacts, kind: str = "baremetal"):
    cls = BareMetalExecutor if kind == "baremetal" else LinuxStackExecutor
    return cls(art.trace, art.weight_image, art.cfg,
               input_scale=art.input_scale, output_scale=art.output_scale,
               output_elems=art.output_elems)
