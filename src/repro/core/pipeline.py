"""Staged compiler pipeline (paper Fig. 1), with inspectable intermediates.

The paper's toolflow is a *pipeline*, not one opaque call:

    calibrate -> build_loadable -> vp_run -> {parse_trace, extract_weights}
                                          -> assemble
                 build_loadable -> cost_model

``CompilerPipeline`` exposes exactly those stages by name.  Each stage is
individually runnable (``pipe.run_stage("parse_trace")`` runs only the stages
it depends on) and its output is kept on the pipeline for inspection.  Stage
outputs are also memoised in a process-wide content-hash cache, so recompiling
an identical (graph, params, calibration, config) is free — the key is a
SHA-256 over the actual stage inputs, chained through the dependency graph.

``Artifacts`` is the pipeline's end product.  ``Artifacts.save(path)`` ships
exactly the paper's bare-metal bundle — the configuration trace, the extracted
weight image and the RV32I program binary (plus a small JSON manifest with the
I/O scales the host needs) — and ``Artifacts.load(path)`` rebuilds a runnable
artifact set from that bundle alone, with no VP re-execution.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import pathlib
import pickle
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import asm as asm_mod
from repro.core import engine, memory, tracegen
from repro.core.graph import NetGraph
from repro.core.loadable import Loadable, build_loadable, calibrate
from repro.core import perfmodel
from repro.core.perfmodel import ModelCost, model_cost
from repro.core.tracegen import Trace
from repro.core.vp import VirtualPlatform

# ---------------------------------------------------------------------------
# Artifacts: the pipeline's product, and the shippable bare-metal bundle
# ---------------------------------------------------------------------------
_BUNDLE_FILES = ("trace.cfg", "weights.img", "program.bin", "manifest.json")


@dataclasses.dataclass
class Artifacts:
    """Everything the bare-metal SoC needs (and nothing else).

    The first block is the shipped bundle (the paper's three files + scales);
    the second block holds compile-time intermediates that only exist on a
    freshly compiled artifact set (``None`` after ``Artifacts.load``).
    """
    graph_name: str
    cfg: engine.EngineConfig
    trace: Trace                     # configuration file
    trace_text: str                  # its serialised form
    weight_image: Dict[int, bytes]   # extracted, deduped preload image
    program_binary: bytes            # assembled program-memory image
    input_scale: float
    output_scale: float
    output_elems: int
    # per-layer kernel plan (cost_model stage) — which GEMM kernel serves each
    # descriptor on the compile host's platform; shipped in the manifest so
    # the chosen code path is visible on any bundle
    kernel_plan: Optional[list] = None
    # per-(layer, bucket) plans over the coalescing ladder — whether the
    # natively batched fused kernel serves each bucket size (keys are bucket
    # sizes; stringified in the JSON manifest, normalised back to int on load)
    batched_kernel_plans: Optional[dict] = None
    # -- compile-time intermediates (not shipped) ----------------------------
    asm_text: str = ""               # RISC-V assembly listing
    loadable: Optional[Loadable] = None
    vp_output: Optional[np.ndarray] = None      # VP reference output (float)
    vp_output_int8: Optional[np.ndarray] = None
    cost: Optional[ModelCost] = None            # cycle model (Tables II/III)

    # -- storage accounting (Table I analogue) -------------------------------
    def storage_report(self) -> Dict[str, int]:
        wbytes = sum(len(b) for b in self.weight_image.values())
        return {
            "config_file_bytes": len(self.trace_text.encode()),
            "program_binary_bytes": len(self.program_binary),
            "weight_image_bytes": wbytes,
            "n_write_reg": self.trace.n_writes,
            "n_read_reg": self.trace.n_reads,
        }

    # -- bundle serialisation ------------------------------------------------
    def save(self, path) -> pathlib.Path:
        """Write the bare-metal bundle: trace.cfg + weights.img + program.bin.

        The weight image is stored as one flat blob; the manifest records the
        (address, length) segment table plus the engine config and I/O scales.
        """
        p = pathlib.Path(path)
        p.mkdir(parents=True, exist_ok=True)
        segs = sorted(self.weight_image.items())
        (p / "trace.cfg").write_text(self.trace_text)
        (p / "weights.img").write_bytes(b"".join(b for _, b in segs))
        (p / "program.bin").write_bytes(self.program_binary)
        manifest = {
            "format": 1,
            "graph_name": self.graph_name,
            "cfg": dataclasses.asdict(self.cfg),
            "input_scale": self.input_scale,
            "output_scale": self.output_scale,
            "output_elems": self.output_elems,
            "weight_segments": [[addr, len(b)] for addr, b in segs],
        }
        if self.kernel_plan is not None:
            manifest["kernel_plan"] = self.kernel_plan
        if self.batched_kernel_plans is not None:
            manifest["batched_kernel_plans"] = {
                str(b): plan for b, plan in
                sorted(self.batched_kernel_plans.items())}
        (p / "manifest.json").write_text(json.dumps(manifest, indent=1))
        return p

    @classmethod
    def load(cls, path) -> "Artifacts":
        """Rebuild a runnable artifact set from a saved bundle (no recompile).

        Raises ``FileNotFoundError`` when bundle files are missing, and
        ``ValueError`` (naming the file and the problem) for a corrupt
        manifest, an unsupported bundle format version, or a weight image
        shorter than its manifest segment table claims.
        """
        p = pathlib.Path(path)
        missing = [f for f in _BUNDLE_FILES if not (p / f).exists()]
        if missing:
            raise FileNotFoundError(f"{p} is not an artifact bundle "
                                    f"(missing {', '.join(missing)})")
        try:
            manifest = json.loads((p / "manifest.json").read_text())
        except json.JSONDecodeError as e:
            raise ValueError(f"{p / 'manifest.json'}: corrupt manifest "
                             f"(not valid JSON: {e})") from None
        fmt = manifest.get("format")
        if fmt != 1:
            raise ValueError(f"{p / 'manifest.json'}: unsupported bundle "
                             f"format version {fmt!r} (this build reads "
                             f"format 1)")
        required = ("graph_name", "cfg", "input_scale", "output_scale",
                    "output_elems", "weight_segments")
        absent = [k for k in required if k not in manifest]
        if absent:
            raise ValueError(f"{p / 'manifest.json'}: manifest missing "
                             f"required keys: {', '.join(absent)}")
        trace_text = (p / "trace.cfg").read_text()
        blob = (p / "weights.img").read_bytes()
        need = sum(n for _, n in manifest["weight_segments"])
        if need > len(blob):
            raise ValueError(
                f"{p / 'weights.img'}: truncated weight image — manifest "
                f"segment table needs {need} bytes, file has {len(blob)}")
        weight_image: Dict[int, bytes] = {}
        off = 0
        for addr, n in manifest["weight_segments"]:
            weight_image[addr] = blob[off:off + n]
            off += n
        return cls(
            graph_name=manifest["graph_name"],
            cfg=engine.EngineConfig(**manifest["cfg"]),
            trace=Trace.from_text(trace_text),
            trace_text=trace_text,
            weight_image=weight_image,
            program_binary=(p / "program.bin").read_bytes(),
            input_scale=manifest["input_scale"],
            output_scale=manifest["output_scale"],
            output_elems=manifest["output_elems"],
            kernel_plan=manifest.get("kernel_plan"),
            batched_kernel_plans={
                int(b): plan for b, plan in
                manifest["batched_kernel_plans"].items()}
            if "batched_kernel_plans" in manifest else None,
        )


# ---------------------------------------------------------------------------
# Content-hash stage cache (process-wide, plus opt-in disk tier)
#
# Bounded LRU: stage outputs (Loadables, VP logs, traces) are heavyweight, so
# the cache evicts least-recently-used entries past _CACHE_MAX to keep a
# long-lived process from growing without bound.  Cached objects are shared
# between pipelines with equal fingerprints — treat stage outputs and the
# Artifacts built from them as immutable.
#
# The disk tier (``CompilerPipeline(cache_dir=...)``) persists pickled stage
# outputs keyed by the same content hash, so a *second process* compiling the
# same (graph, params, calibration, config) skips every stage — including the
# VP run.  Writes are atomic (tmp + rename); unreadable entries are treated
# as misses and deleted; total size is capped by ``cache_dir_max_bytes``
# with least-recently-*used* eviction (hits refresh the file mtime).
# ---------------------------------------------------------------------------
_CACHE: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
_CACHE_MAX = 128
_CACHE_STATS = {"hits": 0, "misses": 0, "disk_hits": 0, "disk_misses": 0}

DEFAULT_CACHE_DIR_MAX_BYTES = 1 << 30        # 1 GiB


def clear_cache() -> None:
    """Reset the in-memory tier and all counters (disk entries persist)."""
    _CACHE.clear()
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0


def cache_stats() -> Dict[str, int]:
    return dict(_CACHE_STATS, entries=len(_CACHE))


def _cache_put(key: str, value: Any) -> None:
    _CACHE[key] = value
    _CACHE.move_to_end(key)
    while len(_CACHE) > _CACHE_MAX:
        _CACHE.popitem(last=False)


def _disk_get(cache_dir: pathlib.Path, key: str) -> Tuple[bool, Any]:
    f = cache_dir / f"{key}.pkl"
    if not f.exists():
        _CACHE_STATS["disk_misses"] += 1
        return False, None
    try:
        with f.open("rb") as fh:
            value = pickle.load(fh)
    except Exception:                        # corrupt/partial entry: a miss
        try:
            f.unlink(missing_ok=True)
        except OSError:
            pass
        _CACHE_STATS["disk_misses"] += 1
        return False, None
    try:
        os.utime(f)                          # refresh LRU recency; the file
    except OSError:                          # may race a concurrent eviction
        pass
    _CACHE_STATS["disk_hits"] += 1
    return True, value


def _disk_put(cache_dir: pathlib.Path, key: str, value: Any,
              max_bytes: int) -> None:
    """Best-effort persist: an unwritable or full cache dir degrades to a
    cache miss next process, never a compile failure."""
    tmp = cache_dir / f".{key}.{os.getpid()}.tmp"
    try:
        cache_dir.mkdir(parents=True, exist_ok=True)
        with tmp.open("wb") as fh:
            pickle.dump(value, fh, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, cache_dir / f"{key}.pkl")
    except Exception as e:
        try:
            tmp.unlink(missing_ok=True)
        except OSError:
            pass
        warnings.warn(f"stage cache write to {cache_dir} failed "
                      f"({type(e).__name__}: {e}); continuing uncached")
        return
    _disk_evict(cache_dir, max_bytes)


def _disk_evict(cache_dir: pathlib.Path, max_bytes: int) -> None:
    entries = []
    for f in cache_dir.glob("*.pkl"):
        try:
            st = f.stat()
        except OSError:
            continue
        entries.append((st.st_mtime, st.st_size, f))
    total = sum(size for _, size, _ in entries)
    for _, size, f in sorted(entries):       # oldest mtime first
        if total <= max_bytes:
            break
        f.unlink(missing_ok=True)
        total -= size


def _hash_update_array(h, a: Optional[np.ndarray]) -> None:
    if a is None:
        h.update(b"none")
    else:
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())


# Mixed into every cache key.  Bump whenever a stage's implementation changes
# semantics, so the *persistent* disk tier never serves stage outputs pickled
# by an older build (the in-memory tier dies with the process; disk doesn't).
CACHE_SCHEMA_VERSION = 6   # 4: kernel_plan entries gained a dtype field
                           #    (bf16/nv_full kernel family)
                           # 5: fingerprint covers NetGraph.source_digest
                           #    (imported nets, repro.frontend)
                           # 6: cost_model outputs gained batched_kernel_plans
                           #    (batch-aware selection over the bucket ladder)


def _fingerprint(graph: NetGraph, params, calib_samples, cfg, sample_input,
                 calibration=None) -> str:
    """SHA-256 over everything the pipeline's output depends on."""
    h = hashlib.sha256()
    h.update(f"schema:{CACHE_SCHEMA_VERSION}".encode())
    if calibration is not None:
        h.update(repr(sorted(calibration.scales.items())).encode())
    h.update(graph.name.encode())
    h.update(graph.source_digest.encode())
    h.update(str(graph.input_shape).encode())
    for l in graph.layers:
        h.update(repr(dataclasses.astuple(l)).encode())
    for lname in sorted(params):
        h.update(lname.encode())
        for k in sorted(params[lname]):
            _hash_update_array(h, params[lname][k])
    _hash_update_array(h, calib_samples)
    _hash_update_array(h, sample_input)
    h.update(repr(dataclasses.astuple(cfg)).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Stage graph
# ---------------------------------------------------------------------------
def _stage_calibrate(p: "CompilerPipeline"):
    return calibrate(p.graph, p.params, p.calib_samples)


def _stage_build_loadable(p: "CompilerPipeline"):
    return build_loadable(p.graph, p.params, p.stage("calibrate"), p.cfg)


def _stage_vp_run(p: "CompilerPipeline"):
    return VirtualPlatform(p.stage("build_loadable")).run(p.sample_input)


def _stage_parse_trace(p: "CompilerPipeline"):
    return tracegen.parse_csb(p.stage("vp_run").log)


def _stage_extract_weights(p: "CompilerPipeline"):
    return memory.extract_weights(tracegen.parse_dbb(p.stage("vp_run").log))


def _stage_assemble(p: "CompilerPipeline"):
    return asm_mod.assemble(p.stage("parse_trace"))


def _stage_cost_model(p: "CompilerPipeline"):
    ld = p.stage("build_loadable")
    return model_cost(ld.descriptors, p.cfg, ld.desc_layers,
                      backend=perfmodel.default_backend())


_STAGES: Dict[str, Tuple[Tuple[str, ...], Callable]] = {
    # name            -> (dependencies, fn)
    "calibrate":       ((), _stage_calibrate),
    "build_loadable":  (("calibrate",), _stage_build_loadable),
    "vp_run":          (("build_loadable",), _stage_vp_run),
    "parse_trace":     (("vp_run",), _stage_parse_trace),
    "extract_weights": (("vp_run",), _stage_extract_weights),
    "assemble":        (("parse_trace",), _stage_assemble),
    "cost_model":      (("build_loadable",), _stage_cost_model),
}

STAGE_NAMES = tuple(_STAGES)


class CompilerPipeline:
    """The paper's toolflow as named, individually-runnable stages.

        pipe = CompilerPipeline(graph)
        cal = pipe.run_stage("calibrate")      # inspect any intermediate
        art = pipe.run()                       # full Artifacts

    Stage outputs are memoised per-pipeline and in a process-wide
    content-hash cache, so identical inputs never recompile.
    """

    stages = STAGE_NAMES

    def __init__(self, graph: NetGraph, params=None,
                 calib_samples: Optional[np.ndarray] = None,
                 cfg: engine.EngineConfig = engine.NV_SMALL,
                 sample_input: Optional[np.ndarray] = None,
                 seed: int = 0, use_cache: bool = True,
                 calibration=None, cache_dir=None,
                 cache_dir_max_bytes: int = DEFAULT_CACHE_DIR_MAX_BYTES):
        # fail malformed graphs (hand-built or imported) here, with a
        # descriptive error, not stages deep in the toolflow
        self.graph = graph.validate()
        self.cfg = cfg
        self.use_cache = use_cache
        # opt-in disk tier: persists stage outputs across processes
        self.cache_dir = pathlib.Path(cache_dir) if cache_dir else None
        self.cache_dir_max_bytes = cache_dir_max_bytes
        self.params = params if params is not None else graph.init_params(seed)
        if calib_samples is None:
            rng = np.random.default_rng(seed + 1)
            calib_samples = rng.normal(
                0, 1, (2,) + graph.input_shape).astype(np.float32)
        self.calib_samples = calib_samples
        self.sample_input = (sample_input if sample_input is not None
                             else calib_samples[0])
        self._results: Dict[str, Any] = {}
        # a pre-computed CalibrationTable overrides the calibrate stage
        # (e.g. a different percentile); it seeds the stage-result map so the
        # content hash must cover it too.
        if calibration is not None:
            self._results["calibrate"] = calibration
        self._root = _fingerprint(graph, self.params, self.calib_samples,
                                  cfg, self.sample_input, calibration)
        self._keys: Dict[str, str] = {}

    # -- cache keys, chained through the stage dependency graph --------------
    def _key(self, name: str) -> str:
        if name not in self._keys:
            deps, _ = _STAGES[name]
            h = hashlib.sha256(self._root.encode())
            h.update(name.encode())
            if name == "cost_model":
                # the kernel plan is selected for the host's platform — a
                # shared disk cache must never serve a CPU plan to a TPU host
                h.update(perfmodel.default_backend().encode())
            for d in deps:
                h.update(self._key(d).encode())
            self._keys[name] = h.hexdigest()
        return self._keys[name]

    # -- execution -----------------------------------------------------------
    def run_stage(self, name: str):
        """Run one stage (and any stages it depends on); return its output."""
        if name not in _STAGES:
            raise ValueError(f"unknown stage {name!r}; stages: "
                             f"{', '.join(STAGE_NAMES)}")
        if name in self._results:
            return self._results[name]
        key = self._key(name)
        if self.use_cache and key in _CACHE:
            _CACHE_STATS["hits"] += 1
            _CACHE.move_to_end(key)
            out = _CACHE[key]
            # mirror memory hits to the disk tier so a warm process still
            # populates the cross-process cache
            if self.cache_dir is not None and \
                    not (self.cache_dir / f"{key}.pkl").exists():
                _disk_put(self.cache_dir, key, out, self.cache_dir_max_bytes)
        else:
            hit = False
            if self.use_cache and self.cache_dir is not None:
                hit, out = _disk_get(self.cache_dir, key)
            if not hit:
                deps, fn = _STAGES[name]
                for d in deps:
                    self.run_stage(d)
                _CACHE_STATS["misses"] += 1
                out = fn(self)
                if self.use_cache and self.cache_dir is not None:
                    _disk_put(self.cache_dir, key, out,
                              self.cache_dir_max_bytes)
            if self.use_cache:
                _cache_put(key, out)
        self._results[name] = out
        return out

    # alias used by the stage functions themselves
    stage = run_stage

    @property
    def results(self) -> Dict[str, Any]:
        """Stage outputs computed so far (inspectable intermediates)."""
        return dict(self._results)

    def run(self) -> Artifacts:
        """Run every stage and assemble the final Artifacts."""
        for name in STAGE_NAMES:
            self.run_stage(name)
        r = self._results
        trace: Trace = r["parse_trace"]
        asm_text, binary = r["assemble"]
        ld: Loadable = r["build_loadable"]
        vp = r["vp_run"]
        out_shape = self.graph.by_name()[self.graph.output].out_shape
        cost: ModelCost = r["cost_model"]
        return Artifacts(
            graph_name=self.graph.name, cfg=self.cfg,
            trace=trace, trace_text=trace.to_text(),
            weight_image=r["extract_weights"],
            program_binary=binary, asm_text=asm_text,
            input_scale=ld.input_scale, output_scale=ld.output_scale,
            output_elems=int(np.prod(out_shape)),
            kernel_plan=cost.kernel_plan,
            batched_kernel_plans=cost.batched_kernel_plans,
            loadable=ld, vp_output=vp.output, vp_output_int8=vp.output_int8,
            cost=cost)
