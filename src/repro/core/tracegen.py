"""Configuration-file generation from VP logs (paper §IV-B2).

Exactly the paper's methodology:

  * lines containing ``nvdla.csb_adaptor`` are register transactions;
    ``iswrite=1`` -> ``write_reg addr data``; ``iswrite=0`` -> ``read_reg addr
    expected`` (the logged data value is the expected status).
  * lines containing ``nvdla.dbb_adaptor`` are data transactions, consumed by
    ``core/memory.extract_weights`` for the weight file.

The resulting command sequence is the *configuration file* — the single artifact
(besides the weight image) a bare-metal core needs to run the network.  It
serialises to the NVDLA trace-player text format and round-trips losslessly.
"""

from __future__ import annotations

import dataclasses
import re
from typing import List

from repro.core.memory import DbbTxn

_CSB_RE = re.compile(
    r"nvdla\.csb_adaptor:\s*iswrite=(\d)\s*addr=0x([0-9a-fA-F]+)\s*data=0x([0-9a-fA-F]+)")
_DBB_RE = re.compile(
    r"nvdla\.dbb_adaptor:\s*iswrite=(\d)\s*addr=0x([0-9a-fA-F]+)\s*len=(\d+)\s*data=([0-9a-fA-F]*)")


@dataclasses.dataclass(frozen=True)
class Command:
    kind: str        # "write_reg" | "read_reg"
    addr: int
    data: int        # write value, or expected read value
    mask: int = 0xFFFFFFFF


@dataclasses.dataclass
class Trace:
    """The configuration file: an ordered command stream."""
    commands: List[Command]

    # -- serialisation (NVDLA trace-player style text) -----------------------
    def to_text(self) -> str:
        out = []
        for c in self.commands:
            if c.kind == "write_reg":
                out.append(f"write_reg {c.addr:#x} {c.data:#010x}")
            else:
                out.append(f"read_reg {c.addr:#x} {c.data:#010x} {c.mask:#010x}")
        return "\n".join(out) + "\n"

    @classmethod
    def from_text(cls, text: str) -> "Trace":
        cmds = []
        for line in text.splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split()
            if parts[0] == "write_reg":
                cmds.append(Command("write_reg", int(parts[1], 16), int(parts[2], 16)))
            elif parts[0] == "read_reg":
                cmds.append(Command("read_reg", int(parts[1], 16), int(parts[2], 16),
                                    int(parts[3], 16)))
            else:
                raise ValueError(f"bad trace line: {line}")
        return cls(cmds)

    @property
    def n_writes(self) -> int:
        return sum(c.kind == "write_reg" for c in self.commands)

    @property
    def n_reads(self) -> int:
        return sum(c.kind == "read_reg" for c in self.commands)


def parse_csb(log: str) -> Trace:
    """VP log -> configuration file (the paper's Python post-processing script)."""
    cmds: List[Command] = []
    for m in _CSB_RE.finditer(log):
        iswrite, addr, data = int(m.group(1)), int(m.group(2), 16), int(m.group(3), 16)
        if iswrite:
            cmds.append(Command("write_reg", addr, data))
        else:
            cmds.append(Command("read_reg", addr, data))
    return Trace(cmds)


def parse_dbb(log: str) -> List[DbbTxn]:
    """VP log -> DBB transaction list (input to weight extraction)."""
    txns: List[DbbTxn] = []
    for m in _DBB_RE.finditer(log):
        iswrite, addr = int(m.group(1)), int(m.group(2), 16)
        data = bytes.fromhex(m.group(4))
        txns.append(DbbTxn(iswrite=iswrite, addr=addr, data=data))
    return txns
