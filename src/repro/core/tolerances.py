"""Tolerance model for the bf16 ``nv_full`` datapath — the parity harness.

The INT8 ``nv_small`` path is bit-exact by construction, so its parity tests
use ``assert_array_equal``.  The bf16 path cannot be: weights and activations
are stored as bfloat16 (8 significand bits) and accumulated in float32, so two
correct implementations of the same layer — numpy ``refops.conv_bf16`` (the VP
oracle), the executors' XLA GEMM, the Pallas block-K kernel — legitimately
differ in f32 *summation order*.  That ordering drift is tiny (~K * 2^-24
relative), but each layer output is rounded back to bf16, and a value sitting
on a rounding boundary can land one bf16 ulp apart between arms.  A flipped
ulp is a 2^-8 relative perturbation that propagates through every downstream
layer.

The harness therefore derives a per-layer budget from the accumulation depth
and composes it over the network:

  * one GEMM layer of contraction depth K:
      ``rtol_layer = BF16_EPS + K * F32_ORDER_EPS``
    — one bf16 output-rounding ulp, plus the worst-case f32 reassociation
    drift of a K-deep sum (both sides round from f32 values at most
    ``K * 2^-22`` apart, relative).
  * a network: layer budgets add — a flipped ulp entering layer *l* is a
    relative perturbation of its inputs, and the layers evaluated here
    (conv/fc/pool/add with ReLU) are 1-Lipschitz in relative terms at this
    granularity, so
      ``rtol_net = sum over CONV/FC layers of rtol_layer``.

``atol`` is tied to the magnitude of the expected tensor (ReLU makes exact
zeros common, where a pure rtol check is vacuous or a pure atol check is
arbitrary): ``atol = rtol * max|expected|``.

These are deliberately *upper* bounds: tight enough that a wrong epilogue, a
bf16 (instead of f32) accumulator, or a transposed weight view fails by orders
of magnitude; loose enough that legal reassociation never flakes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

BF16_EPS = 2.0 ** -8          # one bf16 ulp, relative (8 significand bits)
F32_ORDER_EPS = 2.0 ** -22    # per-element f32 reassociation budget (4 eps)


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """A relative budget plus how to anchor the absolute one."""
    rtol: float

    def atol_for(self, expected: np.ndarray) -> float:
        """Scale-invariant absolute anchor: rtol * max|expected|.

        No magnitude floor — a network whose outputs are all ~0.05 must be
        checked at 0.05's scale or the gate goes vacuous.  The degenerate
        all-zero tensor (both arms produced exact zeros) keeps a tiny
        rtol-sized allowance so it never divides by the signal.
        """
        e = np.asarray(expected, np.float64)
        m = float(np.max(np.abs(e))) if e.size else 0.0
        return self.rtol * (m if m > 0.0 else 1.0)

    def merged(self, other: "Tolerance") -> "Tolerance":
        return Tolerance(rtol=self.rtol + other.rtol)


def gemm_tolerance(contract_k: int) -> Tolerance:
    """Budget for ONE bf16 GEMM layer (conv or fc) of contraction depth K."""
    return Tolerance(rtol=BF16_EPS + max(int(contract_k), 1) * F32_ORDER_EPS)


def net_tolerance(kernel_plan: Optional[Sequence] = None,
                  contract_ks: Optional[Iterable[int]] = None) -> Tolerance:
    """Whole-network budget: per-layer GEMM budgets, summed.

    Pass either the ``Artifacts.kernel_plan`` manifest entries (CONV/FC rows
    carry ``contract_k``) or an explicit iterable of contraction depths.
    """
    if contract_ks is None:
        if kernel_plan is None:
            raise ValueError("need a kernel_plan or explicit contract_ks")
        contract_ks = [e["contract_k"] for e in kernel_plan
                       if e.get("unit") in ("CONV", "FC")]
    ks = list(contract_ks)
    if not ks:
        return Tolerance(rtol=BF16_EPS)
    return Tolerance(rtol=sum(gemm_tolerance(k).rtol for k in ks))


def assert_close(got, want, tol: Tolerance, context: str = "") -> None:
    """``assert_allclose`` with the tolerance model's (rtol, atol) anchoring.

    ``got``/``want`` are compared as float64; ``atol`` is anchored to the
    magnitude of ``want`` so exact zeros (ReLU) don't make the check vacuous.
    """
    got = np.asarray(got, np.float64).reshape(-1)
    want = np.asarray(want, np.float64).reshape(-1)
    np.testing.assert_allclose(
        got, want, rtol=tol.rtol, atol=tol.atol_for(want),
        err_msg=f"bf16 parity exceeded the derived tolerance "
                f"(rtol={tol.rtol:.2e}){' in ' + context if context else ''}")


def max_rel_err(got, want) -> float:
    """Max |got-want| / max|want| — the scalar the benchmarks report."""
    got = np.asarray(got, np.float64).reshape(-1)
    want = np.asarray(want, np.float64).reshape(-1)
    denom = max(float(np.max(np.abs(want))), 1e-30)
    return float(np.max(np.abs(got - want))) / denom
