"""Engine model: a TPU-native analogue of the NVDLA accelerator.

The paper couples a µRISC-V control core to NVDLA, whose compute is organised as
fixed-function units driven by memory-mapped registers on the CSB:

  * CONV  (CDMA/CSC/CMAC/CACC) — the MAC array.  TPU analogue: the MXU, fed by an
    im2col GEMM (this is how we *adapt*, not port: NVDLA's direct-conv dataflow is
    re-blocked as a GEMM so it maps onto the systolic array; see DESIGN.md §2).
  * SDP — single-point unit: bias add, per-channel rescale (requantisation), ReLU.
    TPU analogue: the VPU epilogue fused into the GEMM kernel.
  * PDP — planar pooling unit (max/avg).
  * (CDP/RUBIK/BDMA are not needed for the evaluated models and are not modelled.)

Two hardware configurations mirror the paper:

  * ``nv_small`` — INT8 only, 64 MACs, 8-bit datapath (what fits the ZCU102).
  * ``nv_full``  — adds FP16 (we use bf16: the TPU-native 16-bit type), 2048 MACs.

The register map below is a *simplified but faithful in spirit* CSB layout: every op
executed by the engine is described purely by register writes (addresses into the
DRAM arena, packed dimensions, fixed-point requant scales) followed by an OP_ENABLE
write and a STATUS read — exactly the command stream the paper replays from bare-metal
RISC-V assembly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

# ---------------------------------------------------------------------------
# Address map (paper §IV-A2): NVDLA CSB registers live at 0x0..0xFFFFF and DRAM
# at 0x10_0000 upward (512 MB window).
# ---------------------------------------------------------------------------
CSB_BASE = 0x0
CSB_SIZE = 0x10_0000
DRAM_BASE = 0x10_0000
DRAM_SIZE = 512 * 1024 * 1024

# Unit base addresses inside the CSB window (one "descriptor file" per unit).
UNIT_BASE = {
    "GLB": 0x0000,   # global: interrupt/status
    "CONV": 0x5000,  # convolution core (CDMA+CSC+CMAC+CACC collapsed)
    "SDP": 0x7000,   # single-point: bias / rescale / activation
    "PDP": 0x9000,   # planar pooling
    "FC": 0xB000,    # fully-connected (CONV core in 1x1 mode; separate file for clarity)
    "EW": 0xD000,    # element-wise (residual add) — SDP X1 path in real NVDLA
}

# Register offsets (byte offsets, 32-bit registers) within a unit's file.
REG = {
    "OP_ENABLE": 0x00,    # write 1 to kick the op
    "STATUS": 0x04,       # reads 0x1 when done
    "SRC_ADDR": 0x08,     # input surface address (DRAM)
    "SRC_DIMS0": 0x0C,    # (C << 16) | H
    "SRC_DIMS1": 0x10,    # (W << 16) | N
    "DST_ADDR": 0x14,     # output surface address
    "DST_DIMS0": 0x18,    # (K << 16) | P
    "DST_DIMS1": 0x1C,    # (Q << 16) | N
    "WT_ADDR": 0x20,      # weight base address
    "WT_DIMS": 0x24,      # (R << 24) | (S << 16) | reserved
    "STRIDE_PAD": 0x28,   # (stride << 16) | pad
    "BIAS_ADDR": 0x2C,    # int32 bias vector address (SDP)
    "SCALE_ADDR": 0x30,   # per-channel fixed-point scale table address (SDP)
    "FLAGS": 0x34,        # bit0: relu, bits1-2: pool mode (1=max,2=avg), bit3: residual
    "AUX_ADDR": 0x38,     # second operand (element-wise add)
    "AUX_SCALE": 0x3C,    # (m<<16)|(pre<<8)|post fixed-point rescale, aux operand
    "OUT_SCALE": 0x40,    # (m<<16)|(pre<<8)|post output requant (per-tensor ops)
}

REG_WIDTH = 4
DONE = 0x1

# Reverse maps for decoding traces back into descriptors.
_UNIT_BY_BASE = {v: k for k, v in UNIT_BASE.items()}
_REG_BY_OFF = {v: k for k, v in REG.items()}


def reg_addr(unit: str, reg: str) -> int:
    return CSB_BASE + UNIT_BASE[unit] + REG[reg]


def split_reg_addr(addr: int) -> tuple[str, str]:
    """Inverse of :func:`reg_addr`."""
    off = addr - CSB_BASE
    base = off & ~0xFFF
    if base not in _UNIT_BY_BASE:
        raise ValueError(f"address {addr:#x} does not decode to a unit")
    reg_off = off - base
    if reg_off not in _REG_BY_OFF:
        raise ValueError(f"address {addr:#x} does not decode to a register")
    return _UNIT_BY_BASE[base], _REG_BY_OFF[reg_off]


# ---------------------------------------------------------------------------
# Engine configurations (paper Tables II & III)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static description of one engine build (nv_small / nv_full analogue)."""

    name: str
    dtype: str                # "int8" or "bf16"
    macs: int                 # MAC count (nv_small=64, nv_full=2048)
    dbb_bytes_per_cycle: int  # data-backbone width (8B for 64-bit AXI, 64B for 512-bit)
    conv_buf_kib: int         # on-chip conv buffer (VMEM analogue)
    csb_cycles_per_access: int = 4   # cost of one register write/read from the core
    freq_mhz: int = 100              # paper's system clock
    # First-order efficiency derates, calibrated once against the paper's own
    # measurements (Table II): real DDR4 + DMA pipelines do not hit 100% MAC
    # utilisation or bus efficiency, and each hardware-layer launch pays a fixed
    # DMA-programming + completion-polling latency.
    mac_util: float = 0.85
    dbb_eff: float = 0.85
    op_overhead_cycles: int = 64_000

    @property
    def acc_dtype(self) -> str:
        return "int32" if self.dtype == "int8" else "float32"

    @property
    def elem_bytes(self) -> int:
        return 1 if self.dtype == "int8" else 2

    # ---- cycle model -------------------------------------------------------
    # A simple max(compute, memory) + configuration-overhead model, used to derive
    # the "processing time @100MHz" columns of Tables II/III.
    def op_cycles(self, macs_ops: int, bytes_moved: int, n_reg_writes: int) -> int:
        compute = int(np.ceil(macs_ops / (self.macs * self.mac_util)))
        memory = int(np.ceil(bytes_moved / (self.dbb_bytes_per_cycle * self.dbb_eff)))
        config = n_reg_writes * self.csb_cycles_per_access + self.op_overhead_cycles
        return max(compute, memory) + config

    def cycles_to_ms(self, cycles: int) -> float:
        return cycles / (self.freq_mhz * 1e6) * 1e3


NV_SMALL = EngineConfig(
    name="nv_small", dtype="int8", macs=64, dbb_bytes_per_cycle=8, conv_buf_kib=128
)
# nv_full: 2048 MACs, 512-bit AXI (paper §VI), much deeper pipelines -> lower fixed
# per-layer overhead fraction; op overhead calibrated against Table III LeNet row.
NV_FULL = EngineConfig(
    name="nv_full", dtype="bf16", macs=2048, dbb_bytes_per_cycle=64, conv_buf_kib=512,
    op_overhead_cycles=16_000
)

CONFIGS: Dict[str, EngineConfig] = {"nv_small": NV_SMALL, "nv_full": NV_FULL}


# ---------------------------------------------------------------------------
# Descriptors: the decoded form of one engine op's register file.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Descriptor:
    """One engine operation, as decoded from (or encoded into) register writes."""

    unit: str
    src_addr: int = 0
    src_dims: tuple = (0, 0, 0, 0)   # (N, C, H, W)
    dst_addr: int = 0
    dst_dims: tuple = (0, 0, 0, 0)   # (N, K, P, Q)
    wt_addr: int = 0
    kernel: tuple = (0, 0)           # (R, S)
    groups: int = 1                  # grouped/depthwise conv
    stride: int = 1
    pad: int = 0
    bias_addr: int = -1
    scale_addr: int = -1
    relu: bool = False
    pool_mode: int = 0               # 0 none, 1 max, 2 avg
    residual: bool = False
    aux_addr: int = -1
    aux_scale: tuple = (1, 0, 0)     # (m, pre, post) fixed-point, see core/quant.py
    out_scale: tuple = (1, 0, 0)

    def to_reg_writes(self) -> list[tuple[int, int]]:
        """Encode this descriptor as the (addr, data) register-write sequence."""
        u = self.unit
        n, c, h, w = self.src_dims
        n2, k, p, q = self.dst_dims
        r, s = self.kernel
        flags = (int(self.relu) | (self.pool_mode << 1) | (int(self.residual) << 3))
        writes = [
            (reg_addr(u, "SRC_ADDR"), self.src_addr),
            (reg_addr(u, "SRC_DIMS0"), ((c & 0xFFFF) << 16) | (h & 0xFFFF)),
            (reg_addr(u, "SRC_DIMS1"), ((w & 0xFFFF) << 16) | (n & 0xFFFF)),
            (reg_addr(u, "DST_ADDR"), self.dst_addr),
            (reg_addr(u, "DST_DIMS0"), ((k & 0xFFFF) << 16) | (p & 0xFFFF)),
            (reg_addr(u, "DST_DIMS1"), ((q & 0xFFFF) << 16) | (n2 & 0xFFFF)),
            (reg_addr(u, "WT_ADDR"), self.wt_addr if self.wt_addr >= 0 else 0),
            (reg_addr(u, "WT_DIMS"),
             ((r & 0xFF) << 24) | ((s & 0xFF) << 16) | (self.groups & 0xFFFF)),
            (reg_addr(u, "STRIDE_PAD"), ((self.stride & 0xFFFF) << 16) | (self.pad & 0xFFFF)),
            (reg_addr(u, "FLAGS"), flags),
        ]
        if self.bias_addr >= 0:
            writes.append((reg_addr(u, "BIAS_ADDR"), self.bias_addr))
        if self.scale_addr >= 0:
            writes.append((reg_addr(u, "SCALE_ADDR"), self.scale_addr))
        if self.aux_addr >= 0:
            writes.append((reg_addr(u, "AUX_ADDR"), self.aux_addr))
            writes.append((reg_addr(u, "AUX_SCALE"), _pack_scale(self.aux_scale)))
        writes.append((reg_addr(u, "OUT_SCALE"), _pack_scale(self.out_scale)))
        writes.append((reg_addr(u, "OP_ENABLE"), 1))
        return writes


def decode_descriptors(commands) -> list[Descriptor]:
    """Rebuild descriptors from a ``write_reg``/``read_reg`` command stream.

    This is the bare-metal executor's front-end: given ONLY the trace (no model
    graph), reconstruct what the engine was asked to do.  An op is complete when
    its unit's OP_ENABLE register is written.
    """
    pending: Dict[str, Descriptor] = {}
    out: list[Descriptor] = []
    for cmd in commands:
        if cmd.kind != "write_reg":
            continue
        unit, reg = split_reg_addr(cmd.addr)
        if unit == "GLB":
            continue
        d = pending.setdefault(unit, Descriptor(unit=unit))
        v = cmd.data
        if reg == "SRC_ADDR":
            d.src_addr = v
        elif reg == "SRC_DIMS0":
            c, h = v >> 16, v & 0xFFFF
            d.src_dims = (d.src_dims[0], c, h, d.src_dims[3])
        elif reg == "SRC_DIMS1":
            w, n = v >> 16, v & 0xFFFF
            d.src_dims = (n, d.src_dims[1], d.src_dims[2], w)
        elif reg == "DST_ADDR":
            d.dst_addr = v
        elif reg == "DST_DIMS0":
            k, p = v >> 16, v & 0xFFFF
            d.dst_dims = (d.dst_dims[0], k, p, d.dst_dims[3])
        elif reg == "DST_DIMS1":
            q, n = v >> 16, v & 0xFFFF
            d.dst_dims = (n, d.dst_dims[1], d.dst_dims[2], q)
        elif reg == "WT_ADDR":
            d.wt_addr = v
        elif reg == "WT_DIMS":
            d.kernel = ((v >> 24) & 0xFF, (v >> 16) & 0xFF)
            d.groups = max(v & 0xFFFF, 1)
        elif reg == "STRIDE_PAD":
            d.stride, d.pad = v >> 16, v & 0xFFFF
        elif reg == "BIAS_ADDR":
            d.bias_addr = v
        elif reg == "SCALE_ADDR":
            d.scale_addr = v
        elif reg == "FLAGS":
            d.relu = bool(v & 1)
            d.pool_mode = (v >> 1) & 0x3
            d.residual = bool(v & 0x8)
        elif reg == "AUX_ADDR":
            d.aux_addr = v
        elif reg == "AUX_SCALE":
            d.aux_scale = _unpack_scale(v)
        elif reg == "OUT_SCALE":
            d.out_scale = _unpack_scale(v)
        elif reg == "OP_ENABLE":
            out.append(pending.pop(unit))
    return out


def _pack_scale(mps: tuple) -> int:
    m, pre, post = mps
    return ((m & 0xFFFF) << 16) | ((pre & 0xFF) << 8) | (post & 0xFF)


def _unpack_scale(v: int) -> tuple:
    m = (v >> 16) & 0xFFFF
    if m & 0x8000:
        m -= 0x10000
    return (m, (v >> 8) & 0xFF, v & 0xFF)
