"""shard_map compatibility shim: jax>=0.8 renamed check_rep -> check_vma."""

from __future__ import annotations

import functools


def shard_map_norep(f, *, mesh, in_specs, out_specs):
    """shard_map with replication checking disabled, across jax versions."""
    try:
        from jax import shard_map as sm
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:                              # pragma: no cover
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)
    except ImportError:                                # pragma: no cover
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
