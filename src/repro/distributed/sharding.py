"""Sharding rules: DP / TP / EP / SP partition specs for every arch family.

Megatron-style tensor parallelism on the 'model' axis:
  * QKV / gate / up projections column-sharded (heads / d_ff),
  * output / down projections row-sharded (one all-reduce per block half),
  * embedding + LM head vocab-sharded (vocab-parallel cross entropy),
  * MoE experts expert-sharded on 'model' (EP; combine = one all-reduce),
  * Mamba2 in/out projections row-sharded (keeps the heterogeneous
    [z|x|B|C|dt] stream boundaries intact; see DESIGN.md §5),
  * RWKV6 time-mix head-sharded (state (B,H,D,D) splits on H, WKV is
    collective-free).

Batch is sharded over ('pod','data'); the ``long_500k`` cells shard the KV
cache's *sequence* axis over 'data' instead (SP) — softmax over that axis
lowers to the cross-device partial-softmax combine.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import registry
from repro.models.common import ArchConfig

MODEL = "model"


def _match(rules: Dict[str, Tuple], path, leaf,
           moe_overrides: Optional[Dict[str, Tuple]] = None) -> P:
    """Pick a spec by the final dict key; prepend None for stacked layer dims.

    ``moe_overrides`` apply to mlp weights inside MoE (expert-stacked) blocks —
    identified by NOT being under a "dense" subtree (interleaved MoE keeps its
    dense sub-layers' mlp under blocks/dense/mlp).
    """
    keys = [part.key for part in path if hasattr(part, "key")]
    key = keys[-1] if keys else None
    spec = None
    if moe_overrides and key in moe_overrides and "mlp" in keys \
            and "dense" not in keys:
        spec = moe_overrides[key]
    elif key in rules:
        spec = rules[key]
    if spec is None:
        return P()                                   # replicate by default
    ndim = len(leaf.shape)
    if len(spec) < ndim:                             # stacked layer dim(s)
        spec = (None,) * (ndim - len(spec)) + tuple(spec)
    assert len(spec) == ndim, (key, spec, leaf.shape)
    return P(*spec)


# per-family rule tables: final-key -> spec for the UNSTACKED param
_TRANSFORMER_RULES = {
    "embed": ((MODEL, None)), "head": ((MODEL, None)),
    "wq": (None, MODEL), "wk": (None, MODEL), "wv": (None, MODEL),
    "wo": (MODEL, None),
    "wg": (None, MODEL), "wu": (None, MODEL), "wd": (MODEL, None),
    # MLA
    "wq_a": (None, None), "wq_b": (None, MODEL),
    "wkv_a": (None, None), "wkv_b": (None, MODEL),
}

_MOE_OVERRIDES = {
    "router": (None, None),
    "wg": (MODEL, None, None), "wu": (MODEL, None, None),
    "wd": (MODEL, None, None),                      # (E, f, d): EP on experts
}

_SHARED_EXPERT_RULES = {                            # always-active shared experts
    "wg_s": (None, MODEL), "wu_s": (None, MODEL), "wd_s": (MODEL, None),
}

_RWKV_RULES = {
    "embed": (MODEL, None), "head": (MODEL, None),
    "wr": (None, MODEL), "wk": (None, MODEL), "wv": (None, MODEL),
    "wg": (None, MODEL), "wo": (MODEL, None),
    "w_a": (None, None), "w_b": (None, MODEL),
    "w_bias": (MODEL,), "u": (MODEL,), "ln_x": (MODEL,),
    "ck": (None, MODEL), "cv": (MODEL, None), "cr": (None, MODEL),
}

_MAMBA_RULES = {
    "in_proj": (MODEL, None),                       # row-parallel
    "out_proj": (MODEL, None),
    "conv_w": (None, None), "conv_b": (None,),
}

_WHISPER_RULES = {
    "embed": (MODEL, None), "head": (MODEL, None),
    "wq": (None, MODEL), "wk": (None, MODEL), "wv": (None, MODEL),
    "wo": (MODEL, None),
    "w1": (None, MODEL), "w2": (MODEL, None),
}


_FSDP_MIN_ELEMS = 1 << 20      # don't FSDP-shard tiny params (norms, biases)


def _axes_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axes]))
    return mesh.shape[axes]


def _sanitize(spec: P, shape, mesh) -> P:
    """Drop axis assignments whose dimension isn't divisible (e.g. vocab 51865
    on a 16-way model axis, 40 experts on 16 shards) — replicate instead."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        out.append(axes if axes and dim % _axes_size(mesh, axes) == 0 else None)
    return P(*out)


def _add_fsdp(spec: P, shape, mesh) -> P:
    """ZeRO-3 style: additionally shard the largest unsharded dim over 'data'.

    Params (and congruent optimizer state) then occupy 1/(model*data) per chip;
    GSPMD inserts the per-layer param all-gathers / grad reduce-scatters.  The
    'pod' axis is deliberately NOT used: cross-pod links carry only the one
    per-step gradient all-reduce (DESIGN.md §5).
    """
    if "data" not in mesh.axis_names or int(np.prod(shape)) < _FSDP_MIN_ELEMS:
        return spec
    cur = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    for axis, min_elems in (("data", _FSDP_MIN_ELEMS), ("pod", 1 << 28)):
        # 'pod' tier: ZeRO-3 across pods for giant tensors only (the per-layer
        # cross-pod all-gather is worth it when the alternative is not fitting
        # HBM at all — e.g. llama4's 386B expert bank)
        if axis not in mesh.axis_names or int(np.prod(shape)) < min_elems:
            continue
        n = mesh.shape[axis]
        cands = [(dim, i) for i, (dim, ax) in enumerate(zip(shape, cur))
                 if ax is None and dim % n == 0]
        if cands:
            _, idx = max(cands)
            cur[idx] = axis
    return P(*cur)


def param_specs(cfg: ArchConfig, mesh: jax.sharding.Mesh, fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching registry.get(cfg.family).param_shapes."""
    shapes = registry.get(cfg.family).param_shapes(cfg)
    moe_overrides = None
    if cfg.family in ("dense", "mla", "vlm"):
        rules = _TRANSFORMER_RULES
    elif cfg.family == "moe":
        rules = {**_TRANSFORMER_RULES, **_SHARED_EXPERT_RULES}
        if cfg.n_experts % mesh.shape[MODEL] == 0:
            moe_overrides = _MOE_OVERRIDES            # EP over experts
        else:
            # experts don't divide the model axis (e.g. 40 on 16): fall back
            # to Megatron TP *within* each expert over d_ff
            moe_overrides = {"router": (None, None),
                             "wg": (None, None, MODEL), "wu": (None, None, MODEL),
                             "wd": (None, MODEL, None)}
    elif cfg.family == "ssm":
        rules = _RWKV_RULES
    elif cfg.family == "hybrid":
        rules = {**_TRANSFORMER_RULES, **_MAMBA_RULES,
                 "embed": (MODEL, None), "head": (MODEL, None)}
    elif cfg.family == "encdec":
        rules = _WHISPER_RULES
    else:
        raise ValueError(cfg.family)

    def pick(path, leaf):
        spec = _match(rules, path, leaf, moe_overrides=moe_overrides)
        spec = _sanitize(spec, leaf.shape, mesh)
        if fsdp:
            spec = _add_fsdp(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(pick, shapes)


def batch_specs(cfg: ArchConfig, mesh: jax.sharding.Mesh, shapes: Dict,
                long_context: bool = False) -> Dict[str, P]:
    """Input batch specs. ``long_context``: batch=1 cells shard SEQUENCE over
    'data' (SP) instead of batch."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    out = {}
    for k, v in shapes.items():
        if k == "pos3":
            spec = P(None, dp, None)
        elif k == "frames":
            spec = P(dp, None, None)
        elif k in ("tokens", "labels"):
            if long_context and v.shape[0] == 1 and v.shape[1] > 1:
                spec = P(None, "data")               # SP over sequence
            else:
                spec = P(dp, None)
        else:
            spec = P(*((dp,) + (None,) * (len(v.shape) - 1)))
        out[k] = _sanitize(spec, v.shape, mesh)
    return out


def cache_specs(cfg: ArchConfig, mesh: jax.sharding.Mesh, cache_shapes,
                long_context: bool = False) -> Any:
    """KV/state cache specs.

    decode_32k: batch over dp; kv-heads over 'model' when divisible.
    long_500k (batch=1): sequence axis over 'data' (SP cache), heads over
    'model' when possible.
    """
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    model_size = mesh.shape[MODEL]

    def spec_for(path, leaf) -> P:
        key = None
        for part in reversed(path):
            if hasattr(part, "key"):
                key = part.key
                break
        shape = leaf.shape
        if key in ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
                   "dk", "dv", "mk", "mv"):
            # (..., B, Hkv, S, D): rank 5 (L leading) or 6 (G, me-1 leading)
            lead = len(shape) - 4
            hkv, seq = shape[lead + 1], shape[lead + 2]
            h_ax = MODEL if hkv % model_size == 0 else None
            # kv heads that don't divide the model axis (GQA kv<16, MQA):
            # shard the cache SEQUENCE over 'model' instead — decode attention
            # becomes a distributed flash-decode (partial-softmax combine),
            # which both fits the cache and parallelises the decode read.
            s_ax = MODEL if (h_ax is None and seq % model_size == 0) else None
            if long_context:
                return P(*((None,) * lead), None, h_ax, "data", None)  # SP on seq
            return P(*((None,) * lead), dp, h_ax, s_ax, None)
        if key in ("ckv", "kr"):                              # MLA latent (L,B,S,r)
            if long_context:
                return P(None, None, "data", None)
            s_ax = MODEL if shape[2] % model_size == 0 else None
            return P(None, dp, s_ax, None)
        if key == "S":                                        # RWKV state (L,B,H,D,D)
            h_ax = MODEL if shape[2] % model_size == 0 else None
            return P(None, dp, h_ax, None, None) if shape[1] > 1 \
                else P(None, None, h_ax, None, None)
        if key in ("tm_x", "cm_x"):                           # (L,B,d)
            return P(None, dp, None) if shape[1] > 1 else P(None, None, MODEL)
        if key == "conv":                                     # (L,B,K-1,conv_dim)
            return P(None, dp, None, None) if shape[1] > 1 else P()
        if key == "ssm":                                      # (L,B,H,P,N)
            h_ax = MODEL if shape[2] % model_size == 0 else None
            return P(None, dp, h_ax, None, None) if shape[1] > 1 \
                else P(None, None, h_ax, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _sanitize(spec_for(path, leaf), leaf.shape, mesh),
        cache_shapes)


def named(mesh: jax.sharding.Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def logits_spec(mesh: jax.sharding.Mesh) -> P:
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp = dp if len(dp) > 1 else dp[0]
    return P(dp, MODEL)


# ---------------------------------------------------------------------------
# Serving: lane (batch) sharding for the runtime scheduler's dispatcher
# ---------------------------------------------------------------------------
def serving_mesh(max_devices: Optional[int] = None) -> Optional[jax.sharding.Mesh]:
    """1-axis ``('data',)`` mesh over the available devices, for splitting a
    coalesced inference batch lane-wise.  Returns ``None`` on a single device
    (sharding would be a no-op) so callers can gate cheaply."""
    devs = jax.devices()
    if max_devices is not None:
        devs = devs[:max_devices]
    if len(devs) < 2:
        return None
    return jax.sharding.Mesh(np.asarray(devs), ("data",))


def lane_sharding(mesh: jax.sharding.Mesh) -> NamedSharding:
    """Shard the leading (lane) axis of a batch over the data mesh; every
    other axis — and everything else the jitted program touches (weights,
    activation arena) — replicates."""
    return NamedSharding(mesh, P("data"))
