"""INT8 error-feedback gradient compression (inter-pod link optimisation).

The multi-pod mesh's weakest links carry exactly one collective per step: the
gradient all-reduce over the 'pod' axis.  Compressing that traffic 4x (f32 ->
int8 + per-tensor scale) is the standard trick for slow cross-pod fabrics;
error feedback (Seide et al., 1-bit SGD lineage) keeps the quantisation noise
from biasing convergence: the residual of each step is carried into the next.

Two layers:
  * pure quantise/dequantise + error-feedback state (testable without devices),
  * ``compressed_psum`` — a shard_map collective that all-reduces int8 payloads
    with an f32 scale (used by launch/train.py when ``--compress-grads``).

This reuses the paper's nv_small INT8 insight at the *fabric* level: the same
symmetric-scale quantisation the engine applies to activations is applied to
gradient traffic.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Any, residual: Any) -> Tuple[Any, Any, Any]:
    """Error-feedback compression of a gradient pytree.

    Returns (quantised payloads, scales, new residual):
        corrected = g + residual
        q = Q(corrected); new_residual = corrected - deQ(q)
    """
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs = jax.tree.map(quantize, corrected)
    payload = jax.tree.map(lambda t: t[0], qs,
                           is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(dequantize, payload, scales)
    new_residual = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return payload, scales, new_residual


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Any, residual: Any, mesh: jax.sharding.Mesh,
                    axis: str = "pod") -> Tuple[Any, Any]:
    """All-reduce ``grads`` over ``axis`` with int8 payloads + error feedback.

    int8 payloads are summed in int32 (max pod count 128 before overflow
    concern: 127 * 128 < 2^15), then rescaled by the max participating scale.
    """
    n = mesh.shape[axis]

    def inner(g_and_r):
        grads_, residual_ = g_and_r
        payload, scales, new_res = ef_compress(grads_, residual_)
        # share a common scale = max over participants so the int32 sum is exact
        common = jax.tree.map(lambda s: jax.lax.pmax(s, axis), scales)
        requant = jax.tree.map(
            lambda q, s_old, s_new: jnp.clip(
                jnp.round(q.astype(jnp.float32) * (s_old / s_new)),
                -127, 127).astype(jnp.int32),
            payload, scales, common)
        summed = jax.tree.map(lambda q: jax.lax.psum(q, axis), requant)
        mean = jax.tree.map(lambda q, s: q.astype(jnp.float32) * s / n,
                            summed, common)
        return mean, new_res

    from jax.sharding import PartitionSpec as P
    from repro.distributed.shmap import shard_map_norep as shard_map
    spec = jax.tree.map(lambda _: P(), grads)
    res_spec = jax.tree.map(lambda _: P(), residual)
    fn = shard_map(inner, mesh=mesh, in_specs=((spec, res_spec),),
                   out_specs=(spec, res_spec))
    return fn((grads, residual))
