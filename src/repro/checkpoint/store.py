"""Fault-tolerant checkpointing: atomic, keep-last-k, async, elastic-reshard.

Layout:  <dir>/step_<N>/
             manifest.json      (tree structure, shapes, dtypes, step, extras)
             <leaf-path>.npy    (one file per leaf)
         <dir>/step_<N>.tmp_*   (staging; renamed atomically on completion)

Restart semantics: ``latest_step`` + ``restore`` resume training exactly
(optimizer state + data-iterator state included).  ``restore(..., mesh=...)``
re-shards onto ANY mesh — the elastic-scaling path: a checkpoint written on a
2x16x16 run restores onto 16x16 (or a 1-CPU dev box) because leaves are saved
as full logical arrays and re-placed with the target mesh's NamedShardings.

On a real multi-host pod each host would write only its addressable shards
(process-local ``.npy`` per shard index) — the manifest format already carries
everything needed; this single-process container writes full arrays.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any, extras: Optional[Dict] = None,
         keep_last: int = 3, async_write: bool = False):
    """Atomic checkpoint write. ``extras``: JSON-serialisable (data state etc.)."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    if async_write:
        t = threading.Thread(target=_write, args=(ckpt_dir, step, host_tree,
                                                  extras, keep_last), daemon=True)
        t.start()
        return t
    _write(ckpt_dir, step, host_tree, extras, keep_last)
    return None


def _write(ckpt_dir: str, step: int, host_tree, extras, keep_last):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp_", dir=ckpt_dir)
    flat = _flatten(host_tree)
    manifest = {"step": step, "extras": extras or {},
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in flat.items()}}
    for k, v in flat.items():
        np.save(os.path.join(tmp, k + ".npy"), v)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    _gc(ckpt_dir, keep_last)


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and ".tmp_" not in d)
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and ".tmp_" not in d]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Optional[Any] = None) -> tuple[Any, Dict]:
    """Restore into the structure of ``like``; place with ``shardings`` if given
    (a pytree of NamedSharding — THE elastic-reshard path)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, ref in flat_like.items():
        arr = np.load(os.path.join(d, k + ".npy"))
        assert list(arr.shape) == list(ref.shape), (k, arr.shape, ref.shape)
        if k in flat_sh:
            out[k] = jax.device_put(arr, flat_sh[k])
        else:
            out[k] = jax.numpy.asarray(arr)
    # rebuild tree in like's structure
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    keys = [_SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            for p in paths]
    leaves = [out[k] for k in keys]
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)
    return tree, manifest["extras"]
