"""Serving front-end under mixed-priority open-loop load (table 5).

An open-loop Poisson load generator drives the in-process ``ServeClient``
(the exact code path the HTTP front-end uses, minus sockets) against a
Session with **two resident nets** — a fast one and a deliberately heavier
one, each with its own dispatcher thread.  Offered load is ~4x measured
capacity so real queueing forms; the same arrival trace replays per phase:

  * **FIFO baseline** — every request submitted at priority 0.
  * **SLA run** — 25% of requests are high priority (priority=2) and carry a
    tight ``deadline_us``; the rest are low priority with a loose deadline.

Reported per priority class: p50/p99 submit->result latency and **goodput**
(requests completed within their deadline per second of wall time; the
regression gate checks it alongside ``us_per_call``).  The
``fast_net_isolation`` row compares the fast net's p99 under mixed traffic
against a solo replay of the same trace — with per-net dispatchers the
heavy net must not head-of-line block the fast one.  Every completed
response is checked bit-exact against ``Session.run`` on the same input,
and every request must resolve (result, 429 fail-fast, or deadline shed).
"""

from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.loadgen import class_stats, drive, goodput, make_schedule
from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session, SchedulerConfig
from repro.serve.client import ServeClient

HIGH_PRIORITY = 2
HIGH_FRACTION = 0.25            # fraction of traffic that is high priority
FAST_FRACTION = 0.75            # fraction of traffic aimed at the fast net
OVERLOAD = 4.0                  # offered load vs measured capacity: deep
                                # queues make scheduling policy visible
BURST_FRACTION = 0.4            # head of each trace arriving at t=0
HIGH_DEADLINE_US = 2.0e6        # tight-ish budget for high priority
LOW_DEADLINE_US = 20.0e6        # loose budget for background traffic
POOL = 8                        # distinct inputs per net (refs precomputed)

_SHAPES = {"fastnet": (2, 8, 8), "slownet": (4, 16, 16)}


def _fast_net() -> graph.NetGraph:
    g = graph.NetGraph("fastnet", _SHAPES["fastnet"])
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="c1", type="conv", inputs=["data"], out_channels=4,
                kernel=3, pad=1, relu=True)
    x = g.layer(name="p1", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=8)
    return g.infer_shapes()


def _slow_net() -> graph.NetGraph:
    # deliberately heavier per image than fastnet (bigger surface, more
    # channels) but with a dispatch time well under the FIFO backlog drain,
    # so scheduling policy — not the non-preemptive batch floor — owns p99
    g = graph.NetGraph("slownet", _SHAPES["slownet"])
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="c1", type="conv", inputs=["data"], out_channels=8,
                kernel=3, pad=1, relu=True)
    x = g.layer(name="p1", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=16)
    return g.infer_shapes()


def _make_schedule(seed: int, n_total: int, mean_interarrival_us: float,
                   nets_filter=None):
    """This table's traffic mix over the shared schedule builder
    (``benchmarks.loadgen.make_schedule`` — same RNG stream as before the
    extraction, so the committed baselines stay valid)."""
    return make_schedule(seed, n_total, mean_interarrival_us,
                         fast_net="fastnet", slow_net="slownet",
                         fast_fraction=FAST_FRACTION,
                         high_fraction=HIGH_FRACTION,
                         high_priority=HIGH_PRIORITY,
                         high_deadline_us=HIGH_DEADLINE_US,
                         low_deadline_us=LOW_DEADLINE_US,
                         pool=POOL, burst_fraction=BURST_FRACTION,
                         nets_filter=nets_filter)


def run(fast: bool = False):
    # deep enough that FIFO queueing delay (what scheduling policy controls)
    # is hundreds of ms — an order of magnitude above thread-scheduling noise
    n_total = 960 if fast else 1920
    # submitter + two dispatchers + done-callbacks are all GIL-bound between
    # XLA calls; the default 5ms switch interval quantises latencies to
    # multi-ms slices and masks the scheduling policy under test
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)
    try:
        return _run(fast, n_total)
    finally:
        sys.setswitchinterval(old_switch)


def _run(fast: bool, n_total: int):
    arts = {"fastnet": CompilerPipeline(_fast_net()).run(),
            "slownet": CompilerPipeline(_slow_net()).run()}
    cfg = SchedulerConfig(max_batch=8, max_wait_us=1000.0, max_queue=4096)
    ses = Session(scheduler=cfg)
    for art in arts.values():
        ses.load(art)
    client = ServeClient(ses)
    rng = np.random.default_rng(0)
    inputs = {name: [rng.normal(0, 1, _SHAPES[name]).astype(np.float32)
                     for _ in range(POOL)] for name in arts}
    # ground truth through the Session API itself (bit-exactness oracle)
    refs = {name: [np.asarray(ses.run(x, net=name).output_int8)
                   for x in xs] for name, xs in inputs.items()}

    # warm every power-of-two bucket so the load phases measure dispatch,
    # not XLA compiles
    for name in arts:
        k = 1
        while k <= cfg.max_batch:
            ses.run_batch(np.stack((inputs[name] * 2)[:k]), net=name)
            k *= 2

    # capacity estimate -> offered load at OVERLOAD x
    per_img_us = {}
    for name in arts:
        X = np.stack(inputs[name])
        iters = 3
        t0 = time.perf_counter()
        for _ in range(iters):
            ses.run_batch(X, net=name)
        per_img_us[name] = (time.perf_counter() - t0) / (iters * POOL) * 1e6
    mean_service_us = (FAST_FRACTION * per_img_us["fastnet"]
                       + (1 - FAST_FRACTION) * per_img_us["slownet"])
    mean_interarrival_us = mean_service_us / OVERLOAD

    # one arrival trace (seed 7), replayed for every phase of every repeat;
    # phase-level medians over the repeats tame thread-scheduling noise on
    # shared CI boxes (each replay is only tens of ms of traffic)
    mixed = _make_schedule(7, n_total, mean_interarrival_us)
    solo_fast = _make_schedule(7, n_total, mean_interarrival_us,
                               nets_filter={"fastnet"})

    def is_high(r):
        # the FIFO replay strips priorities but keeps class deadlines, so
        # the class label survives for apples-to-apples percentiles
        return r.deadline_us == HIGH_DEADLINE_US

    reps = 3 if fast else 5
    m = {k: [] for k in ("hi_p50", "hi_p99", "lo_p50", "lo_p99", "fifo_p99",
                         "solo_p99", "mixed_fast_p99", "goodput_hi",
                         "goodput_sla", "goodput_fifo")}
    all_recs, last, max_inflight = [], {}, 0
    for _ in range(reps):
        # phase 1: fast net alone (head-of-line baseline)
        solo_recs, _, _ = drive(client, solo_fast, inputs, refs,
                                honor_sla=False)
        # phase 2: FIFO baseline — same mixed trace, priorities stripped
        fifo_recs, fifo_wall, fifo_infl = drive(client, mixed, inputs,
                                                refs, honor_sla=False)
        # phase 3: SLA run — same mixed trace, priorities+deadlines honored
        sla_recs, sla_wall, sla_infl = drive(client, mixed, inputs, refs,
                                             honor_sla=True)
        all_recs += solo_recs + fifo_recs + sla_recs
        max_inflight = max(max_inflight, fifo_infl, sla_infl)
        last = {"hi": class_stats(sla_recs, is_high),
                "lo": class_stats(sla_recs, lambda r: not is_high(r))}
        m["hi_p50"].append(last["hi"]["p50"])
        m["hi_p99"].append(last["hi"]["p99"])
        m["lo_p50"].append(last["lo"]["p50"])
        m["lo_p99"].append(last["lo"]["p99"])
        m["fifo_p99"].append(class_stats(fifo_recs, is_high)["p99"])
        m["solo_p99"].append(class_stats(
            solo_recs, lambda r: r.net == "fastnet")["p99"])
        # cross-net interference read from the unprioritized mixed phase, so
        # the solo-vs-mixed delta isolates the slow net's presence (the SLA
        # phase would fold priority-induced low-class delay into it)
        m["mixed_fast_p99"].append(class_stats(
            fifo_recs, lambda r: r.net == "fastnet")["p99"])
        m["goodput_hi"].append(goodput(sla_recs, sla_wall, is_high))
        m["goodput_sla"].append(goodput(sla_recs, sla_wall))
        m["goodput_fifo"].append(goodput(fifo_recs, fifo_wall))
    med = {k: float(np.median(v)) for k, v in m.items()}

    exact_all = all(r.exact for r in all_recs if r.ok)
    resolved_all = all(r.t_done > 0.0 for r in all_recs)
    rejected = sum(1 for r in all_recs if r.error == "overloaded")
    shed = sum(1 for r in all_recs if r.error == "deadline_exceeded")
    hol_ratio = (med["mixed_fast_p99"] / med["solo_p99"]
                 if med["solo_p99"] else 0.0)
    prio_win = med["fifo_p99"] / med["hi_p99"] if med["hi_p99"] else 0.0

    ok_lats = [r.latency_us for r in all_recs if r.ok]
    # load-test latencies amplify ambient machine noise superlinearly
    # (queueing): observed cross-run spread on a contended box is ~3x, so
    # these rows declare a budget that only catastrophic regressions (e.g.
    # priority ordering collapsing to FIFO, goodput collapse) can exceed;
    # the dimensionless policy ratios (priority_win, hol_ratio) are the
    # robust per-run signals and live in `derived`
    tol = 2.5
    rows = [
        {
            "name": "table5_serving_frontend/high_priority",
            "us_per_call": med["hi_p99"],
            "goodput": med["goodput_hi"],
            "tolerance": tol,
            "derived": (f"p50_us={med['hi_p50']:.0f} n={last['hi']['n']} "
                        f"fifo_p99_us={med['fifo_p99']:.0f} "
                        f"priority_win={prio_win:.2f}x "
                        f"goodput_rps={med['goodput_hi']:.0f}"),
        },
        {
            "name": "table5_serving_frontend/low_priority",
            "us_per_call": med["lo_p99"],
            "goodput": med["goodput_sla"],
            "tolerance": tol,
            "derived": (f"p50_us={med['lo_p50']:.0f} n={last['lo']['n']} "
                        f"total_goodput_rps={med['goodput_sla']:.0f} "
                        f"fifo_goodput_rps={med['goodput_fifo']:.0f}"),
        },
        {
            "name": "table5_serving_frontend/fast_net_isolation",
            "us_per_call": med["mixed_fast_p99"],
            # the solo-replay phase is pure backlog drain — the most
            # noise-amplified number here; the deterministic isolation
            # proof is tests/test_scheduler.py::TestPerNetDispatchers
            "tolerance": 6.0,
            "derived": (f"solo_p99_us={med['solo_p99']:.0f} "
                        f"hol_ratio={hol_ratio:.2f} "
                        f"max_inflight={max_inflight} reps={reps}"),
        },
        {
            "name": "table5_serving_frontend/integrity",
            "us_per_call": sum(ok_lats) / max(1, len(ok_lats)),
            "tolerance": tol,
            "derived": (f"bit_exact_vs_session_run={exact_all} "
                        f"all_resolved={resolved_all} "
                        f"admitted={len(all_recs) - rejected} "
                        f"rejected_429={rejected} shed_deadline={shed} "
                        f"requests={len(all_recs)}"),
        },
    ]
    ses.close()
    return rows
