"""Shared open-loop load generation for the serving benchmarks.

Extracted from the table-5 serving front-end benchmark so table 6 (the
MLPerf-style saturation search) replays traffic through the exact same
machinery: a Poisson arrival-schedule builder, an open-loop replayer over
the ``ServeClient`` surface (in-process or HTTP — the submitter never
waits for completions, so queueing pressure is real), and the per-class
latency/goodput reducers.

The schedule builder draws from ``np.random.default_rng(seed)`` in a fixed
per-arrival order (interarrival, net pick, priority pick, input index) —
table 5's committed baselines depend on that stream, so keep the order.
"""

from __future__ import annotations

import threading
import time

import numpy as np


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if xs else 0.0


class Record:
    """One request's client-side outcome (submit/done stamps, typed error
    code, bit-exactness vs the precomputed reference)."""

    __slots__ = ("net", "idx", "priority", "deadline_us", "t_submit",
                 "t_done", "error", "exact")

    def __init__(self, net, idx, priority, deadline_us):
        self.net, self.idx = net, idx
        self.priority, self.deadline_us = priority, deadline_us
        self.t_submit = self.t_done = 0.0
        self.error: str = ""
        self.exact = False

    @property
    def ok(self) -> bool:
        return not self.error

    @property
    def latency_us(self) -> float:
        return (self.t_done - self.t_submit) * 1e6

    @property
    def in_deadline(self) -> bool:
        return self.ok and self.latency_us <= self.deadline_us


def drive(client, schedule, inputs, refs, honor_sla: bool,
          timeout_s: float = 600.0):
    """Replay one arrival trace open-loop; returns (records, wall_s,
    max_inflight).  The submitter never waits for completions — arrivals
    land on schedule (or as fast as possible once the trace runs behind).

    ``client`` is anything with the ``ServeClient`` surface (``infer_async``
    + ``resolve_future``); ``schedule`` is ``[(t, net, idx, priority,
    deadline_us), ...]``; ``inputs``/``refs`` map net -> input pool /
    expected ``output_int8`` per index.

    ``honor_sla=False`` is the FIFO baseline: priorities AND deadlines are
    stripped at submit (deadlines feed EDF ordering, so leaving them in
    would smuggle priority scheduling into the baseline); the class labels
    stay on the records for apples-to-apples per-class reporting, and
    goodput is still judged against each class's deadline client-side."""
    records = []
    lock = threading.Lock()
    state = {"inflight": 0, "max_inflight": 0, "remaining": len(schedule)}
    done_evt = threading.Event()
    resolve = type(client).resolve_future
    t0 = time.perf_counter()

    def finish_one(was_inflight: bool) -> None:
        with lock:
            if was_inflight:
                state["inflight"] -= 1
            state["remaining"] -= 1
            if state["remaining"] == 0:
                done_evt.set()

    def on_done(rec: Record, fut) -> None:
        rec.t_done = time.perf_counter()
        try:
            res = resolve(fut)
            rec.exact = bool(np.array_equal(
                np.asarray(res.output_int8), refs[rec.net][rec.idx]))
        except Exception as e:
            rec.error = getattr(e, "code", type(e).__name__)
        finish_one(True)

    for dt, net, idx, priority, deadline_us in schedule:
        target = t0 + dt
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        rec = Record(net, idx, priority if honor_sla else 0, deadline_us)
        records.append(rec)
        rec.t_submit = time.perf_counter()
        try:
            fut = client.infer_async(net, inputs[net][idx],
                                     priority=rec.priority,
                                     deadline_us=(deadline_us if honor_sla
                                                  else None))
        except Exception as e:              # admission control: fail-fast
            rec.t_done = time.perf_counter()
            rec.error = getattr(e, "code", type(e).__name__)
            finish_one(False)
            continue
        with lock:
            state["inflight"] += 1
            state["max_inflight"] = max(state["max_inflight"],
                                        state["inflight"])
        fut.add_done_callback(lambda f, r=rec: on_done(r, f))
    done_evt.wait(timeout=timeout_s)
    return records, time.perf_counter() - t0, state["max_inflight"]


def class_stats(records, pred):
    xs = [r for r in records if pred(r) and r.ok]
    lats = [r.latency_us for r in xs]
    return {"n": sum(1 for r in records if pred(r)), "ok": len(xs),
            "p50": percentile(lats, 50), "p99": percentile(lats, 99)}


def goodput(records, wall_s, pred=lambda r: True):
    return sum(1 for r in records if pred(r) and r.in_deadline) / wall_s


def make_schedule(seed: int, n_total: int, mean_interarrival_us: float, *,
                  fast_net: str, slow_net: str, fast_fraction: float,
                  high_fraction: float, high_priority: int,
                  high_deadline_us: float, low_deadline_us: float,
                  pool: int, burst_fraction: float, nets_filter=None):
    """Arrival burst (``burst_fraction`` of the trace at t=0) followed by
    open-loop Poisson arrivals.  The burst guarantees a deep backlog on any
    machine speed — without it, a fast box serves requests as fast as the
    submitter can offer them and no queueing (the thing scheduling policy
    acts on) ever forms; the Poisson tail then models the arrival bursts
    the collector continuously batches across.

    A single-net workload is ``fast_net == slow_net`` (the net draw still
    happens, keeping the RNG stream schedule-shape independent);
    ``nets_filter`` drops arrivals for other nets *after* all draws, so a
    filtered trace is the exact subsequence of the unfiltered one."""
    rng = np.random.default_rng(seed)
    burst = int(burst_fraction * n_total)
    sched, t = [], 0.0
    for i in range(n_total):
        if i >= burst:
            t += rng.exponential(mean_interarrival_us) * 1e-6
        net = fast_net if rng.random() < fast_fraction else slow_net
        high = rng.random() < high_fraction
        idx = int(rng.integers(pool))
        if nets_filter and net not in nets_filter:
            continue
        sched.append((t, net, idx, high_priority if high else 0,
                      high_deadline_us if high else low_deadline_us))
    return sched
