"""MLPerf-style saturation search under a declared SLO (table 6).

The ROADMAP's "saturation-scale load harness" item: what sustained request
rate can the serving stack hold while *still meeting its objectives*?
One-shot latency means (tables 2/3) and fixed-overload queueing behavior
(table 5) don't answer that — MLPerf Inference's server scenario does, by
searching for the highest Poisson arrival rate whose latency percentile
stays under a bound.  This harness reproduces that shape over the
in-process ``ServeClient`` (the exact serving code path minus sockets),
with the PR-10 windowed telemetry as the measurement oracle:

  * **offline mode** — every request issued at t=0, closed-loop drain:
    peak throughput with unbounded latency (MLPerf "offline").
  * **server mode** — open-loop Poisson arrivals via the shared
    ``benchmarks.loadgen``; a binary search over the arrival rate finds
    ``max_rps_under_slo``, the highest rate where the *declared*
    ``SloPolicy`` (p99 latency bound + <=1% error/shed/reject rate) holds.
    Each probe phase resets the telemetry, replays ~``PHASE_S`` seconds of
    traffic, and judges the phase via ``SloPolicy.check`` over the
    smallest telemetry window — the same windowed quantile/error-rate
    machinery the burn-rate alerting engine reads in production.
  * **confirmation phase** — a final, longer replay at the found rate;
    its windowed p50/p90/p99/error-rate/goodput land in the committed row.

The p99 bound is declared *relative to this machine's unloaded p50*
(``SLO_P50_MULT`` x, floored at ``SLO_FLOOR_US``), so the committed
``max_rps_under_slo`` measures queueing capacity rather than raw host
speed, and the row stays comparable across machines via the regression
gate's ``--normalize``.  Every completed response in every phase is
checked bit-exact against ``Session.run`` refs; any mismatch aborts the
table (self-gating, like tables 5/7).

``check_regression.py`` gates ``max_rps_under_slo`` with the direction
inverted (lower RPS = regression) and this row's widened tolerance, like
table 5's queueing rows.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.loadgen import drive, make_schedule, percentile
from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session, SchedulerConfig
from repro.serve.client import ServeClient
from repro.obs.slo import SloObjective, SloPolicy

NET = "satnet"
SHAPE = (2, 8, 8)
POOL = 8                        # distinct inputs (refs precomputed)
SLO_P50_MULT = 25.0             # p99 bound = mult x unloaded p50 ...
SLO_FLOOR_US = 5_000.0          # ... but never tighter than this
ERROR_BUDGET = 0.01             # <=1% of requests may error/shed/reject
DEADLINE_US = 30.0e6            # loose per-request label (loadgen plumbing)
SEARCH_ITERS = 7                # binary-search probes (halves the bracket)


def _net() -> graph.NetGraph:
    g = graph.NetGraph(NET, SHAPE)
    g.layer(name="data", type="input", inputs=[])
    x = g.layer(name="c1", type="conv", inputs=["data"], out_channels=4,
                kernel=3, pad=1, relu=True)
    x = g.layer(name="p1", type="pool", inputs=[x], pool_mode="gap")
    g.layer(name="fc", type="fc", inputs=[x], out_channels=8)
    return g.infer_shapes()


def _schedule(seed: int, n: int, rate_rps: float):
    """Pure-Poisson single-net arrivals at ``rate_rps`` (no t=0 burst —
    the search probes the feasible region, it doesn't force a backlog)."""
    return make_schedule(seed, n, 1e6 / rate_rps,
                         fast_net=NET, slow_net=NET, fast_fraction=1.0,
                         high_fraction=0.0, high_priority=0,
                         high_deadline_us=DEADLINE_US,
                         low_deadline_us=DEADLINE_US,
                         pool=POOL, burst_fraction=0.0)


def _window(ses):
    """The probe oracle: merged stats over the smallest configured window
    (30s by default — every probe phase fits inside it post-reset)."""
    return ses.telemetry.window(NET, ses.telemetry.config.windows[0])


def run(fast: bool = False):
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)   # same rationale as table 5
    try:
        return _run(fast)
    finally:
        sys.setswitchinterval(old_switch)


def _run(fast: bool):
    phase_s = 0.5 if fast else 1.5
    confirm_s = 1.0 if fast else 3.0
    # queue deep enough for the offline phase's all-at-t=0 submit; server
    # probes then bind on the p99 objective (queueing delay), not on 429s
    cfg = SchedulerConfig(max_batch=8, max_wait_us=1000.0, max_queue=4096)
    ses = Session(CompilerPipeline(_net()).run(), scheduler=cfg)
    client = ServeClient(ses)
    rng = np.random.default_rng(0)
    inputs = {NET: [rng.normal(0, 1, SHAPE).astype(np.float32)
                    for _ in range(POOL)]}
    refs = {NET: [np.asarray(ses.run(x).output_int8) for x in inputs[NET]]}

    # warm every power-of-two bucket: the search measures dispatch, not XLA
    k = 1
    while k <= cfg.max_batch:
        ses.run_batch(np.stack((inputs[NET] * 2)[:k]))
        k *= 2

    all_recs = []

    def probe(rate_rps: float, seconds: float, seed: int):
        """One telemetry-isolated phase at ``rate_rps``; returns the
        windowed stats (the oracle) + the phase's client-side records."""
        n = max(96, min(4096, int(rate_rps * seconds)))
        sched = _schedule(seed, n, rate_rps)
        ses.telemetry.reset()
        recs, wall, _ = drive(client, sched, inputs, refs, honor_sla=False)
        all_recs.extend(recs)
        time.sleep(0.02)                 # let trailing records land
        return _window(ses), recs, wall

    # unloaded p50 through the same windowed-telemetry path -> declared SLO
    ses.telemetry.reset()
    for i in range(48):
        client.infer(NET, inputs[NET][i % POOL])
    base = _window(ses)
    base_p50 = base.quantile(0.50)
    threshold_us = max(SLO_FLOOR_US, SLO_P50_MULT * base_p50)
    policy = SloPolicy(net=NET, objectives=(
        SloObjective(kind="latency", quantile=0.99,
                     threshold_us=threshold_us),
        SloObjective(kind="error_rate", budget=ERROR_BUDGET,
                     bad_statuses=("error", "shed", "rejected")),
    ))

    # offline mode: issue everything at once, closed-loop drain
    n_off = 512 if fast else 1024
    ses.telemetry.reset()
    t0 = time.perf_counter()
    futs = [client.infer_async(NET, inputs[NET][i % POOL])
            for i in range(n_off)]
    outs = [ServeClient.resolve_future(f) for f in futs]
    offline_wall = time.perf_counter() - t0
    offline_rps = n_off / offline_wall
    off_w = _window(ses)
    for i, o in enumerate(outs):
        if not np.array_equal(np.asarray(o.output_int8),
                              refs[NET][i % POOL]):
            raise RuntimeError("offline phase response mismatch vs "
                               "Session.run — refusing to report rows")

    # server mode: binary-search the highest Poisson rate meeting the SLO
    lo, lo_ok = 0.0, False
    hi = offline_rps * 1.25
    trajectory = []
    for it in range(SEARCH_ITERS):
        rate = (lo + hi) / 2.0
        w, _, _ = probe(rate, phase_s, seed=100 + it)
        ok, details = policy.check(w)
        trajectory.append(
            f"{rate:.0f}rps:"
            f"p99={w.quantile(0.99) / 1e3:.1f}ms,"
            f"err={w.bad_fraction(('error', 'shed', 'rejected')):.3f},"
            f"{'ok' if ok else 'fail'}")
        if ok:
            lo, lo_ok = rate, True
        else:
            hi = rate
    if not lo_ok:
        raise RuntimeError(
            f"SLO (p99<={threshold_us / 1e3:.1f}ms, err<={ERROR_BUDGET}) "
            f"unmeetable even at {lo + (hi - lo) / 2:.0f} rps — "
            f"serving stack or bound is broken: {trajectory}")
    max_rps = lo

    # confirmation phase at the found rate: the committed percentiles.
    # search probes are short, so a rate that squeaks past one can fail a
    # sustained replay — the confirmation is authoritative: back off until
    # the longer phase actually holds the SLO
    conf, conf_recs, conf_wall = probe(max_rps, confirm_s, seed=999)
    conf_ok, conf_details = policy.check(conf)
    backoffs = 0
    while not conf_ok and backoffs < 4:
        backoffs += 1
        max_rps *= 0.85
        conf, conf_recs, conf_wall = probe(max_rps, confirm_s,
                                           seed=999 + backoffs)
        conf_ok, conf_details = policy.check(conf)
    conf_lats = [r.latency_us for r in conf_recs if r.ok]

    exact_all = all(r.exact for r in all_recs if r.ok)
    resolved_all = all(r.t_done > 0.0 for r in all_recs)
    if not exact_all:
        raise RuntimeError("served responses diverged from Session.run — "
                           "refusing to report rows")

    rows = [
        {
            # MLPerf "offline": peak closed-loop throughput, no latency bound
            "name": "table6_saturation/offline",
            "us_per_call": 1e6 / offline_rps,
            "tolerance": 2.5,
            "derived": (f"offline_rps={offline_rps:.0f} n={n_off} "
                        f"window_p50_us={off_w.quantile(0.5):.0f} "
                        f"window_p99_us={off_w.quantile(0.99):.0f}"),
        },
        {
            # MLPerf "server": max sustainable Poisson rate under the SLO.
            # max_rps_under_slo is gated inverted (lower = regression) with
            # this row's tolerance; us_per_call mirrors it as a latency-like
            # quantity so the row also rides the standard gate + --normalize
            "name": "table6_saturation/max_rps_under_slo",
            "us_per_call": 1e6 / max_rps,
            "max_rps_under_slo": max_rps,
            "tolerance": 2.5,
            "derived": (f"slo=p99<={threshold_us / 1e3:.1f}ms,"
                        f"err<={ERROR_BUDGET:.0%} "
                        f"base_p50_us={base_p50:.0f} "
                        f"offline_rps={offline_rps:.0f} "
                        f"probes={SEARCH_ITERS} confirm_backoffs={backoffs} "
                        f"search=[{' '.join(trajectory)}] "
                        f"bit_exact={exact_all} all_resolved={resolved_all}"),
        },
        {
            # the confirmation replay's windowed view at max_rps: per-phase
            # percentiles from the telemetry (oracle) + client-side p99
            "name": "table6_saturation/server_confirm",
            "us_per_call": conf.quantile(0.99),
            "tolerance": 2.5,
            "derived": (f"rate_rps={max_rps:.0f} n={conf.total} "
                        f"wall_s={conf_wall:.2f} "
                        f"window_p50_us={conf.quantile(0.5):.0f} "
                        f"window_p90_us={conf.quantile(0.9):.0f} "
                        f"window_p99_us={conf.quantile(0.99):.0f} "
                        f"client_p99_us={percentile(conf_lats, 99):.0f} "
                        f"error_rate="
                        f"{conf.bad_fraction(('error', 'shed', 'rejected')):.4f} "
                        f"goodput_rps={conf.goodput_rps:.0f} "
                        f"slo_met={conf_ok}"),
        },
    ]
    ses.close()
    return rows
