"""Perf-regression gate: fresh BENCH_*.json vs the committed baselines.

    PYTHONPATH=src python benchmarks/check_regression.py --new bench
        [--baseline benchmarks] [--tolerance 0.15] [--update]

Each committed ``benchmarks/BENCH_table<N>.json`` is compared row-by-row
(matched on ``name``) against the same file in ``--new`` (written by
``benchmarks.run --smoke --out <dir>``).  A row whose measured
``us_per_call`` exceeds baseline * (1 + tolerance) fails the gate, so the
perf trajectory is recorded in-tree and guarded in CI.  Rows that also
carry a ``goodput`` field (table 5's serving front-end: requests completed
within deadline per second) are gated on it too, with the direction
inverted — goodput *shrinking* past the tolerance fails.  Table 7's chaos
rows add two more: ``recovery_ms`` (circuit-breaker outage -> healed
primary; growth fails like us_per_call) and ``hang_count``, which is gated
*absolutely* — any unresolved future in the fresh run fails regardless of
baseline or tolerance, because a hung future is an outage, not a slowdown.
Table 8's sampled-tracing row is gated absolutely too: its fresh
``tracing_overhead_pct`` must stay under the ``overhead_budget_pct`` the
baseline row declares (default 5%), on any machine.
``--update`` rewrites the baselines from the fresh run instead (use after
an intentional change, and commit the result).

Only tables with a committed baseline participate — add a table by committing
its JSON.  Rows present only on one side are reported but never fail: new
benchmarks shouldn't need a lockstep baseline commit to land.  A baseline row
may declare its own ``"tolerance"`` (the table-5 load-test rows use 2.5 —
6.0 for the backlog-dominated isolation row — because queueing delays
amplify ambient machine noise superlinearly); the gate uses
``max(global, per-row)``, and such wide-budget rows are excluded from
electing the ``--normalize`` machine-speed median.

``--normalize`` (CI mode) divides every row's ratio by the median ratio
across all rows, treating it as a machine-speed factor.  Known limitation:
a regression hitting the *majority* of baselined rows shifts the median and
masks itself — the gate is a per-row relative guard, not an absolute one.
The factor is printed (with a warning when it exceeds the tolerance) so a
uniform shift is visible in the CI log even when the gate passes.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import shutil
import sys


def load_rows(path: pathlib.Path):
    data = json.loads(path.read_text())
    return {r["name"]: r for r in data.get("rows", [])}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(pathlib.Path(__file__).parent),
                    help="directory with committed BENCH_*.json baselines")
    ap.add_argument("--new", required=True,
                    help="directory with freshly measured BENCH_*.json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_TOLERANCE", "0.15")),
                    help="allowed per-row us_per_call growth (0.15 = +15%%; "
                         "default overridable via $BENCH_REGRESSION_TOLERANCE)")
    ap.add_argument("--normalize", action="store_true",
                    help="divide each row's ratio by the median ratio across "
                         "all rows (a machine-speed factor), so the gate "
                         "flags rows that regressed relative to the rest — "
                         "robust when CI hardware differs from the machine "
                         "that produced the committed baselines")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines from --new instead of checking")
    args = ap.parse_args()

    base_dir = pathlib.Path(args.baseline)
    new_dir = pathlib.Path(args.new)
    baselines = sorted(base_dir.glob("BENCH_table*.json"))
    if not baselines:
        print(f"no BENCH_table*.json baselines in {base_dir}", file=sys.stderr)
        return 2

    # pass 1: collect per-metric ratios across every baselined table.  Every
    # check is normalised to "ratio > 1 means regressed": us_per_call uses
    # new/base (slower is worse), goodput uses base/new (lower is worse) —
    # both move the same way under a machine-speed change, so they share the
    # median normalization.
    rows = []                            # (label, base, new, unit, ratio, tol)
    failures, checked = [], 0
    for bfile in baselines:
        nfile = new_dir / bfile.name
        if not nfile.exists():
            print(f"WARN {bfile.name}: no fresh measurement in {new_dir}")
            continue
        if args.update:
            shutil.copyfile(nfile, bfile)
            print(f"updated baseline {bfile}")
            continue
        base_rows, new_rows = load_rows(bfile), load_rows(nfile)
        for name, brow in sorted(base_rows.items()):
            nrow = new_rows.get(name)
            if nrow is None:
                print(f"WARN {name}: row missing from fresh run")
                continue
            tol = max(args.tolerance, float(brow.get("tolerance", 0.0)))
            b_us, n_us = brow["us_per_call"], nrow["us_per_call"]
            rows.append((name, b_us, n_us, "us",
                         (n_us / b_us) if b_us else float("inf"), tol))
            b_gp, n_gp = brow.get("goodput"), nrow.get("goodput")
            if b_gp is not None and n_gp is not None:
                rows.append((f"{name} [goodput]", b_gp, n_gp, "req/s",
                             (b_gp / n_gp) if n_gp else float("inf"), tol))
            # max_rps_under_slo (table 6's saturation search) gates like
            # goodput: inverted direction (serving FEWER rps under the same
            # SLO is the regression) with the row's widened tolerance
            b_mr, n_mr = (brow.get("max_rps_under_slo"),
                          nrow.get("max_rps_under_slo"))
            if b_mr is not None and n_mr is not None:
                rows.append((f"{name} [max_rps]", b_mr, n_mr, "req/s",
                             (b_mr / n_mr) if n_mr else float("inf"), tol))
            b_rm, n_rm = brow.get("recovery_ms"), nrow.get("recovery_ms")
            if b_rm is not None and n_rm is not None:
                rows.append((f"{name} [recovery]", b_rm, n_rm, "ms",
                             (n_rm / b_rm) if b_rm else float("inf"), tol))
            # hang_count is absolute, not relative: a hung future is an
            # outage, so no tolerance/normalization can excuse one
            n_hang = nrow.get("hang_count")
            if n_hang is not None:
                checked += 1
                if n_hang > 0:
                    failures.append(f"{name} [hang_count]")
                    print(f"FAIL {name} [hang_count]: {n_hang} unresolved "
                          f"future(s) (must be 0)")
                else:
                    print(f"OK   {name} [hang_count]: 0")
            # tracing_overhead_pct (table 8's sampled row) is also gated
            # absolutely, against the budget the baseline row declares:
            # sampled tracing past a few percent is a bug on any machine,
            # so no baseline ratio or normalization applies
            n_ov = nrow.get("tracing_overhead_pct")
            if n_ov is not None:
                budget = float(brow.get("overhead_budget_pct", 5.0))
                checked += 1
                if n_ov > budget:
                    failures.append(f"{name} [tracing_overhead]")
                    print(f"FAIL {name} [tracing_overhead]: {n_ov:+.2f}% "
                          f"(budget {budget:.1f}%)")
                else:
                    print(f"OK   {name} [tracing_overhead]: {n_ov:+.2f}% "
                          f"<= {budget:.1f}%")
        for name in sorted(set(new_rows) - set(base_rows)):
            print(f"NEW  {name}: {new_rows[name]['us_per_call']:.1f}us "
                  f"(no baseline — commit --update output to start tracking)")

    # pass 2: gate, optionally normalizing out the machine-speed factor
    scale = 1.0
    if rows and args.normalize:
        # the machine-speed factor comes from the *stable* checks only: a
        # row that declared a wider-than-global tolerance self-identifies
        # as noisy (load-test queueing), and letting those elect the median
        # would drag the scale away from the tight-loop rows and fail them
        stable = [r for _, _, _, _, r, tol in rows
                  if tol <= args.tolerance] or \
                 [r for _, _, _, _, r, _ in rows]
        ratios = sorted(stable)
        mid = len(ratios) // 2
        # true median: with an even count, average the two middle elements —
        # taking the upper-middle would let a regressed pair elect itself as
        # the machine-speed factor and mask its own regression
        scale = (ratios[mid] if len(ratios) % 2
                 else (ratios[mid - 1] + ratios[mid]) / 2.0)
        print(f"machine-speed factor (median ratio): {scale:.3f}")
        if scale > 1.0 + args.tolerance:
            # normalization cannot distinguish "slower machine" from "uniform
            # regression across a majority of rows" — surface it rather than
            # silently absorbing it into the scale factor
            print(f"WARN every-row shift of {scale - 1.0:+.1%} absorbed as "
                  f"machine speed; if this is the same hardware that "
                  f"produced the baselines, investigate a global regression")
    for name, base, new, unit, raw_ratio, tol in rows:
        ratio = raw_ratio / scale
        checked += 1
        status = "OK"
        if ratio > 1.0 + tol:
            status = "FAIL"
            failures.append(name)
        print(f"{status:4s} {name}: {new:.1f}{unit} vs baseline "
              f"{base:.1f}{unit} "
              f"({ratio - 1.0:+.1%}{' normalized' if args.normalize else ''}"
              f", budget +{tol:.0%})")

    if args.update:
        return 0
    if failures:
        print(f"\n{len(failures)} row(s) regressed past their budget "
              f"(global +{args.tolerance:.0%}): {', '.join(failures)}",
              file=sys.stderr)
        return 1
    print(f"\nall {checked} baselined checks within budget "
          f"(global +{args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
