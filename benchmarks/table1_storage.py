"""Table I analogue: resource / storage accounting of the bare-metal artifacts.

The paper's Table I reports FPGA resource utilisation; the storage-efficiency
claim is that bare-metal deployment needs only (program memory + weight image)
— no Linux kernel / rootfs / driver stack (tens of MB).  We measure, per model:

  * configuration-file bytes and register-command counts,
  * RV32I program-binary bytes (program memory, BRAM analogue),
  * extracted + deduped weight-image bytes,
  * the linux-stack baseline's equivalent footprint: per-op executable count
    + driver bookkeeping structures + (constant) kernel/rootfs overhead the
    paper's references carry (alpine-class minimal rootfs ~48 MB).
"""

from __future__ import annotations

import pathlib
import time

import numpy as np

from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.frontend.resolve import resolve_net

LINUX_STACK_BASE_MB = 48.0      # minimal kernel+rootfs+driver the refs require

MODELS = ["lenet5", "resnet18", "resnet50"]
# an imported (no-builder) net rides along so the storage table always
# exercises the frontend path too; --model on benchmarks.run adds more
IMPORTED = [str(pathlib.Path(__file__).resolve().parent.parent
                / "examples" / "models" / "tinynet.json")]


def run(fast: bool = False, extra_models=()):
    rows = []
    models = (MODELS[:2] if fast else MODELS) + IMPORTED + list(extra_models)
    for name in models:
        g, params = resolve_net(name)
        label = g.name if name in graph.BUILDERS \
            else f"{g.name}(imported)"
        t0 = time.perf_counter()
        art = CompilerPipeline(g, params=params,
                               use_cache=False).run()  # time a real compile
        compile_us = (time.perf_counter() - t0) * 1e6
        rep = art.storage_report()
        baremetal_kb = (rep["config_file_bytes"] + rep["program_binary_bytes"]) / 1024
        weights_mb = rep["weight_image_bytes"] / 1e6
        linux_mb = LINUX_STACK_BASE_MB + weights_mb + rep["program_binary_bytes"] / 1e6
        rows.append({
            "name": f"table1_storage/{label}",
            "us_per_call": compile_us,
            "derived": (f"cfg_kb={rep['config_file_bytes']/1024:.1f} "
                        f"prog_kb={rep['program_binary_bytes']/1024:.1f} "
                        f"weights_mb={weights_mb:.2f} "
                        f"writes={rep['n_write_reg']} reads={rep['n_read_reg']} "
                        f"baremetal_total_mb={baremetal_kb/1024 + weights_mb:.2f} "
                        f"linux_stack_total_mb={linux_mb:.1f} "
                        f"storage_saving_mb={LINUX_STACK_BASE_MB:.0f}"),
        })
    return rows
