"""Table II: nv_small INT8 end-to-end inference (LeNet-5 / ResNet-18 / ResNet-50).

Reproduces the paper's evaluation on the functional engine model:
  * wall-clock per inference for the BARE-METAL backend (one fused XLA binary,
    arena resident on device) vs the LINUX-STACK baseline (per-op dispatch +
    driver tensor table) — the paper's core speed claim, measured on identical
    op semantics,
  * modeled cycles -> ms @ 100 MHz from the calibrated engine cycle model,
    against the paper's measured numbers (LeNet 4.8 ms / ResNet-18 16.2 ms /
    ResNet-50 1.1 s) and against [8] (Linux-stack FPGA: LeNet 263 ms,
    ResNet-50 2.5 s @ 50 MHz).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session

PAPER_MS = {"lenet5": 4.8, "resnet18": 16.2, "resnet50": 1100.0}
MODELS = ["lenet5", "resnet18", "resnet50"]


def _time_run(ses: Session, x, iters: int, net: str) -> float:
    # executor-direct, like table4's arena row: Table II compares engine
    # latency, so keep the scheduler's submit->future hop out of the numbers
    ex = ses.executor(net)
    ex.run(x)                                   # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        ex.run(x)
    return (time.perf_counter() - t0) / iters * 1e6


def run(fast: bool = False):
    rows = []
    models = MODELS[:2] if fast else MODELS
    for name in models:
        g = graph.BUILDERS[name]()
        art = CompilerPipeline(g).run()
        ses = Session(art, backend="baremetal", name="bm")
        ses.load(art, name="ls", backend="linuxstack")
        x = np.random.default_rng(0).normal(0, 1, g.input_shape).astype(np.float32)
        iters = 20 if name == "lenet5" else (5 if name == "resnet18" else 2)
        bm_us = _time_run(ses, x, iters, net="bm")
        ls_us = _time_run(ses, x, iters, net="ls")
        modeled_ms = art.cost.ms_at_clock
        rows.append({
            "name": f"table2_nvsmall/{name}",
            "us_per_call": bm_us,
            "derived": (f"linuxstack_us={ls_us:.0f} "
                        f"baremetal_speedup={ls_us/bm_us:.2f}x "
                        f"modeled_ms@100MHz={modeled_ms:.1f} "
                        f"paper_ms={PAPER_MS[name]} "
                        f"model_ratio={modeled_ms/PAPER_MS[name]:.2f} "
                        f"dominant={art.cost.dominant()}"),
        })
    return rows
