"""Table II: nv_small INT8 end-to-end inference (LeNet-5 / ResNet-18 / ResNet-50).

Reproduces the paper's evaluation on the functional engine model:
  * wall-clock per inference for the BARE-METAL backend (one fused XLA binary,
    arena resident on device) vs the LINUX-STACK baseline (per-op dispatch +
    driver tensor table) — the paper's core speed claim, measured on identical
    op semantics,
  * modeled cycles -> ms @ 100 MHz from the calibrated engine cycle model,
    against the paper's measured numbers (LeNet 4.8 ms / ResNet-18 16.2 ms /
    ResNet-50 1.1 s) and against [8] (Linux-stack FPGA: LeNet 263 ms,
    ResNet-50 2.5 s @ 50 MHz).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import graph, perfmodel
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session

PAPER_MS = {"lenet5": 4.8, "resnet18": 16.2, "resnet50": 1100.0}
MODELS = ["lenet5", "resnet18", "resnet50"]


def _time_run(ses: Session, x, iters: int, net: str) -> float:
    # executor-direct, like table4's arena row: Table II compares engine
    # latency, so keep the scheduler's submit->future hop out of the numbers
    ex = ses.executor(net)
    ex.run(x)                                   # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        ex.run(x)
    return (time.perf_counter() - t0) / iters * 1e6


def _layer_profile(art, total_us: float, top: int = 3):
    """Per-layer breakdown from the kernel plan + cycle model.

    The cycle model gives each layer's share of modeled time; applying that
    share to the *measured* per-image latency yields a per-layer time estimate
    next to the kernel that serves it — the profile future kernel work aims at.
    """
    rows = art.cost.layer_breakdown()
    for r in rows:
        r["est_us"] = total_us * r["share"]
    summary = " ".join(
        f"{r['layer']}:{r['kernel'] or r['unit'].lower()}"
        f"[K={r['contract_k']}x{r['k_tiles']}t]={r['est_us']:.0f}us"
        for r in rows[:top] if r["unit"] in ("CONV", "FC"))
    return rows, summary


def _largeK_ab(art, iters: int = 10, batch: int = 8):
    """A/B the large-K (> EXACT_K) CONV/FC GEMMs: scalar integer dot_general
    (the pre-kernel-engine fallback, kept here only as the comparison arm)
    vs the tiled-exact kernel the plan selected.

    Measures both executor paths — solo (one image: GEMV-shaped layers are
    weight-bandwidth-bound, where int8 streaming is at parity) and the
    vmapped batch-``batch`` program (the scheduler's coalesced hot path,
    where lanes widen the GEMM and the f32 units win outright).  Returns
    ``(solo_speedup, batch_speedup)`` as old/new ratios, or ``(0, 0)`` when
    the network has no large-K layer.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import engine
    from repro.core.executor import _dot_i8

    descs = engine.decode_descriptors(art.trace.commands)
    rng = np.random.default_rng(0)
    dn = (((1,), (0,)), ((), ()))
    t = {"solo_old": 0.0, "solo_new": 0.0, "batch_old": 0.0, "batch_new": 0.0}
    seen = set()
    for d in descs:
        kdim = perfmodel.contract_k(d)
        if kdim <= perfmodel.EXACT_K:
            continue
        _, kout, p, q = d.dst_dims
        if (kout, kdim, p * q) in seen:      # identical GEMM shape: same time
            continue
        seen.add((kout, kdim, p * q))
        w = jnp.asarray(rng.integers(-128, 128, (kout, kdim), dtype=np.int8))
        cols = jnp.asarray(
            rng.integers(-128, 128, (batch, kdim, p * q), dtype=np.int8))

        def one_old(c):
            return jax.lax.dot_general(w, c, dn,
                                       preferred_element_type=jnp.int32)

        def one_new(c, kd=kdim):
            return _dot_i8(w, c, dn, kd)

        arms = {
            "solo_old": jax.jit(one_old), "solo_new": jax.jit(one_new),
            "batch_old": jax.jit(jax.vmap(one_old)),
            "batch_new": jax.jit(jax.vmap(one_new)),
        }
        for name, f in arms.items():
            x = cols[0] if name.startswith("solo") else cols
            f(x).block_until_ready()                # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                f(x).block_until_ready()
            t[name] += (time.perf_counter() - t0) / iters
    if not seen:
        return 0.0, 0.0
    return t["solo_old"] / t["solo_new"], t["batch_old"] / t["batch_new"]


def run(fast: bool = False):
    rows = []
    models = MODELS[:2] if fast else MODELS
    for name in models:
        g = graph.BUILDERS[name]()
        art = CompilerPipeline(g).run()
        ses = Session(art, backend="baremetal", name="bm")
        ses.load(art, name="ls", backend="linuxstack")
        x = np.random.default_rng(0).normal(0, 1, g.input_shape).astype(np.float32)
        iters = 20 if name == "lenet5" else (5 if name == "resnet18" else 2)
        bm_us = _time_run(ses, x, iters, net="bm")
        ls_us = _time_run(ses, x, iters, net="ls")
        modeled_ms = art.cost.ms_at_clock
        kernels = ses.executor("bm").capabilities().kernels
        layers, top_layers = _layer_profile(art, bm_us)
        solo_ab, batch_ab = _largeK_ab(art)
        largek = (f"largeK_batch8_speedup={batch_ab:.2f}x "
                  f"largeK_solo_speedup={solo_ab:.2f}x ") if batch_ab else ""
        rows.append({
            "name": f"table2_nvsmall/{name}",
            "us_per_call": bm_us,
            "derived": (f"linuxstack_us={ls_us:.0f} "
                        f"baremetal_speedup={ls_us/bm_us:.2f}x "
                        f"modeled_ms@100MHz={modeled_ms:.1f} "
                        f"paper_ms={PAPER_MS[name]} "
                        f"model_ratio={modeled_ms/PAPER_MS[name]:.2f} "
                        f"dominant={art.cost.dominant()} "
                        f"kernels={'+'.join(kernels)} "
                        f"{largek}"
                        f"top_layers=[{top_layers}]"),
            "layers": layers,
        })
    return rows
