"""Table III: nv_full bf16 cycle counts (6 models, simulation/model results).

The paper reports VP-simulated cycle counts for the nv_full configuration
(FP16, 2048 MACs); we report the calibrated cycle model's counts for the same
six networks and compare processing time @ 100 MHz.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import engine, graph
from repro.core.pipeline import CompilerPipeline

PAPER = {  # model -> (paper cycles, paper ms @100MHz)
    "lenet5": (143188, 1.4),
    "resnet18": (324387, 3.2),
    "resnet50": (26565315, 265.0),
    "mobilenet": (22525704, 220.0),
    "googlenet": (40889646, 408.0),
    "alexnet": (35535582, 355.0),
}
MODELS = ["lenet5", "resnet18", "resnet50", "mobilenet", "googlenet", "alexnet"]


def run(fast: bool = False):
    rows = []
    models = MODELS[:2] if fast else MODELS
    for name in models:
        g = graph.BUILDERS[name]()
        params = g.init_params(0)
        t0 = time.perf_counter()
        rng = np.random.default_rng(1)
        pipe = CompilerPipeline(
            g, params, rng.normal(0, 1, (1,) + g.input_shape).astype(np.float32),
            cfg=engine.NV_FULL, use_cache=False)
        # staged pipeline: cost_model depends only on the loadable, so the
        # VP / trace / assembly stages never run for this table
        mc = pipe.run_stage("cost_model")
        us = (time.perf_counter() - t0) * 1e6
        pc, pms = PAPER[name]
        rows.append({
            "name": f"table3_nvfull/{name}",
            "us_per_call": us,
            "derived": (f"modeled_cycles={mc.total_cycles} paper_cycles={pc} "
                        f"modeled_ms={mc.ms_at_clock:.1f} paper_ms={pms} "
                        f"cycle_ratio={mc.total_cycles/pc:.2f} "
                        f"macs_M={g.macs()/1e6:.0f} dominant={mc.dominant()}"),
        })
    return rows
