"""Table III: nv_full bf16 — LIVE execution plus the calibrated cycle model.

The paper reports VP-simulated cycle counts for the nv_full configuration
(FP16, 2048 MACs).  Since PR 5 the bf16 datapath actually *executes*: LeNet-5
and ResNet-18 are compiled with ``cfg=NV_FULL``, run end-to-end through the
bare-metal bf16 executor (single image, arena-resident weights), checked
against the VP oracle under the derived tolerance bounds
(``core/tolerances.py``), and timed — ``us_per_call`` is the live per-image
latency and is what the CI regression gate tracks.  The calibrated cycle
model's counts and the paper's numbers ride along in ``derived`` for every
model; the four networks too large to VP-simulate in a smoke run
(resnet50/mobilenet/googlenet/alexnet) keep their cost-model-only rows in
full mode.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import engine, graph
from repro.core.pipeline import CompilerPipeline
from repro.core.tolerances import assert_close, max_rel_err, net_tolerance
from repro.runtime import create_executor

PAPER = {  # model -> (paper cycles, paper ms @100MHz)
    "lenet5": (143188, 1.4),
    "resnet18": (324387, 3.2),
    "resnet50": (26565315, 265.0),
    "mobilenet": (22525704, 220.0),
    "googlenet": (40889646, 408.0),
    "alexnet": (35535582, 355.0),
}
MODELS = ["lenet5", "resnet18", "resnet50", "mobilenet", "googlenet", "alexnet"]
LIVE = ("lenet5", "resnet18")     # executed end-to-end through the executor


def _live_row(name: str, fast: bool) -> dict:
    g = graph.BUILDERS[name]()
    rng = np.random.default_rng(1)
    pipe = CompilerPipeline(
        g, g.init_params(0),
        rng.normal(0, 1, (1,) + g.input_shape).astype(np.float32),
        cfg=engine.NV_FULL)
    art = pipe.run()                       # full pipeline incl. the VP oracle
    mc = art.cost
    ex = create_executor("baremetal", art)
    x = pipe.sample_input
    tol = net_tolerance(art.kernel_plan)
    got = ex.run(x)                        # warm-up: compiles the program
    # parity gate: a bf16 result outside the documented bounds fails the
    # benchmark loudly instead of publishing a wrong-latency row
    assert_close(got.output, art.vp_output, tol, f"table3 {name}")
    rel = max_rel_err(got.output, art.vp_output)
    iters = 3 if fast else 10
    t0 = time.perf_counter()
    for _ in range(iters):
        ex.run(x)
    us = (time.perf_counter() - t0) * 1e6 / iters
    pc, pms = PAPER[name]
    kernels = ",".join(sorted({e["kernel"] for e in art.kernel_plan
                               if e["unit"] in ("CONV", "FC")}))
    return {
        "name": f"table3_nvfull/{name}",
        "us_per_call": us,
        # wider per-row budget than the global gate (same mechanism as the
        # table-5 load rows): these rows were seeded on different hardware
        # than the table-2/4 baselines and are dispatch-dominated at LeNet
        # scale, so only collapse-scale regressions (e.g. recompiling per
        # call) should fail; declaring a wide budget also excludes them from
        # electing the --normalize machine-speed median
        "tolerance": 0.6,
        "derived": (f"live_bf16 rel_err={rel:.1e} rtol={tol.rtol:.1e} "
                    f"kernels={kernels} modeled_cycles={mc.total_cycles} "
                    f"paper_cycles={pc} modeled_ms={mc.ms_at_clock:.1f} "
                    f"paper_ms={pms} cycle_ratio={mc.total_cycles/pc:.2f} "
                    f"macs_M={g.macs()/1e6:.0f} dominant={mc.dominant()}"),
    }


def _model_row(name: str) -> dict:
    g = graph.BUILDERS[name]()
    t0 = time.perf_counter()
    rng = np.random.default_rng(1)
    pipe = CompilerPipeline(
        g, g.init_params(0),
        rng.normal(0, 1, (1,) + g.input_shape).astype(np.float32),
        cfg=engine.NV_FULL, use_cache=False)
    # staged pipeline: cost_model depends only on the loadable, so the
    # VP / trace / assembly stages never run for these rows
    mc = pipe.run_stage("cost_model")
    us = (time.perf_counter() - t0) * 1e6
    pc, pms = PAPER[name]
    return {
        "name": f"table3_nvfull/{name}",
        "us_per_call": us,
        "derived": (f"cost_model_only modeled_cycles={mc.total_cycles} "
                    f"paper_cycles={pc} modeled_ms={mc.ms_at_clock:.1f} "
                    f"paper_ms={pms} cycle_ratio={mc.total_cycles/pc:.2f} "
                    f"macs_M={g.macs()/1e6:.0f} dominant={mc.dominant()}"),
    }


def run(fast: bool = False):
    models = MODELS[:2] if fast else MODELS
    return [(_live_row(n, fast) if n in LIVE else _model_row(n))
            for n in models]
