"""Benchmark harness: one module per paper table.  Prints name,us_per_call,derived.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--table N]

Tables:
  1  storage / resource accounting of the bare-metal artifacts   (paper Table I)
  2  nv_small INT8 inference latency + bare-metal vs linux-stack (paper Table II)
  3  nv_full bf16 cycle counts, six networks                     (paper Table III)
  4  serving microbenchmarks: arena residency + batched Session  (runtime layer)
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small subset (CI); full run covers all models")
    ap.add_argument("--table", type=int, default=0, help="run one table only")
    args = ap.parse_args()

    from benchmarks import (table1_storage, table2_nvsmall, table3_nvfull,
                            table4_serving)
    tables = {1: table1_storage, 2: table2_nvsmall, 3: table3_nvfull,
              4: table4_serving}
    picked = [tables[args.table]] if args.table else list(tables.values())

    print("name,us_per_call,derived")
    ok = True
    for mod in picked:
        try:
            for row in mod.run(fast=args.fast):
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        except Exception as e:                      # pragma: no cover
            ok = False
            print(f"{mod.__name__},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
