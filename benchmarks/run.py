"""Benchmark harness: one module per paper table.  Prints name,us_per_call,derived.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke] [--table N]
                                            [--out DIR] [--model SPEC]...

Tables:
  1  storage / resource accounting of the bare-metal artifacts   (paper Table I)
  2  nv_small INT8 inference latency + bare-metal vs linux-stack (paper Table II)
  3  nv_full bf16: LIVE executor latency (LeNet-5, ResNet-18) with
     VP tolerance-parity gate + cycle model, six networks         (paper Table III)
  4  serving microbenchmarks: arena residency, batching, coalesced
     submit through the Session scheduler                        (runtime layer)
  5  serving front-end: open-loop Poisson mixed-priority load over the
     in-process ServeClient — per-priority p50/p99, goodput, FIFO A/B,
     per-net dispatcher isolation                                (serve layer)
  6  saturation search: MLPerf-style offline throughput + binary-searched
     max_rps_under_slo (declared p99 + error-rate SLO judged by the
     windowed telemetry; gated inverted — lower RPS regresses)  (slo layer)
  7  chaos soak: the table-5 trace under injected fault storms —
     goodput retained, watchdog hang containment (hang_count must
     be 0), circuit-breaker outage recovery_ms                   (fault layer)
  8  observability: request-tracing overhead (sampled mode gated
     under its budget), per-layer profiled-path cost, perf-model
     calibration fidelity; --smoke also writes the captured Chrome
     trace as TRACE_table8.json                                  (obs layer)

``--smoke`` runs every table in reduced-size mode (implies ``--fast``) and
writes one ``BENCH_table<N>.json`` per table into ``--out`` (default ``.``) —
CI uploads these as workflow artifacts so perf history rides along with every
run.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small subset (CI); full run covers all models")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-size run of every table + BENCH_*.json files")
    ap.add_argument("--table", type=int, default=0, help="run one table only")
    ap.add_argument("--model", action="append", default=[], metavar="SPEC",
                    help="extra net for the storage table: builder name or "
                         "ONNX/JSON model file (repro.frontend; repeatable)")
    ap.add_argument("--out", default=".",
                    help="directory for --smoke JSON output")
    args = ap.parse_args()
    fast = args.fast or args.smoke

    from benchmarks import (table1_storage, table2_nvsmall, table3_nvfull,
                            table4_serving, table5_serving_frontend,
                            table6_saturation, table7_chaos,
                            table8_observability)
    tables = {1: table1_storage, 2: table2_nvsmall, 3: table3_nvfull,
              4: table4_serving, 5: table5_serving_frontend,
              6: table6_saturation, 7: table7_chaos,
              8: table8_observability}
    picked = {args.table: tables[args.table]} if args.table else tables

    out_dir = pathlib.Path(args.out)
    if args.smoke:
        out_dir.mkdir(parents=True, exist_ok=True)

    print("name,us_per_call,derived")
    ok = True
    for num, mod in picked.items():
        try:
            kw = {"fast": fast}
            if num == 1 and args.model:
                kw["extra_models"] = args.model
            if num == 8 and args.smoke:
                # ship the captured Chrome trace next to the BENCH files so
                # CI uploads an openable timeline of its own traffic
                kw["trace_out"] = out_dir / "TRACE_table8.json"
            rows = mod.run(**kw)
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
            if args.smoke:
                (out_dir / f"BENCH_table{num}.json").write_text(
                    json.dumps({"table": num, "mode": "smoke", "rows": rows},
                               indent=1))
        except Exception as e:                      # pragma: no cover
            ok = False
            print(f"{mod.__name__},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
