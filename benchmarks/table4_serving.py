"""Serving microbenchmarks: arena residency + batched execution (Session API).

Two effects the runtime layer is built around, measured on LeNet-5 (nv_small,
bare-metal backend):

  * ``arena_residency`` — per-call latency with the preloaded DRAM arena kept
    resident on device (a non-donated buffer the program reads; only the
    input surface transfers per call) vs the old behaviour of re-materialising
    the whole arena host->device on every ``run``.
  * ``batched`` — ``session.run_batch`` (one vmapped XLA program per batch)
    vs N sequential ``run`` calls; the paper's deployment serves one image at
    a time, batching is what production-scale serving adds on top.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session

BATCH = 8


def _bench(fn, iters: int) -> float:
    fn()                                        # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run(fast: bool = False):
    g = graph.lenet5()
    art = CompilerPipeline(g).run()
    ses = Session(art)
    ex = ses.executor()
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    X = rng.normal(0, 1, (BATCH,) + g.input_shape).astype(np.float32)
    iters = 10 if fast else 30

    # -- arena residency: steady-state vs per-call re-materialisation --------
    steady_us = _bench(lambda: ses.run(x), iters)

    def rematerialise():
        ex.reset_arena()                        # forces host->device arena copy
        ex.run(x)
    cold_us = _bench(rematerialise, iters)

    # -- batching: one vmapped program vs N sequential calls -----------------
    seq_out = np.stack([ses.run(xi).output_int8 for xi in X])
    bit_exact = bool(np.array_equal(ses.run_batch(X).output_int8, seq_out))
    seq_us = _bench(lambda: [ses.run(xi) for xi in X], max(3, iters // 3))
    batch_us = _bench(lambda: ses.run_batch(X), max(3, iters // 3))

    return [
        {
            "name": "table4_serving/arena_residency",
            "us_per_call": steady_us,
            "derived": (f"rematerialise_us={cold_us:.0f} "
                        f"resident_speedup={cold_us/steady_us:.2f}x "
                        f"arena_bytes={ex.size}"),
        },
        {
            "name": f"table4_serving/batched_n{BATCH}",
            "us_per_call": batch_us / BATCH,
            "derived": (f"sequential_us_per_img={seq_us/BATCH:.0f} "
                        f"batch_throughput_speedup={seq_us/batch_us:.2f}x "
                        f"bit_exact_vs_sequential={bit_exact}"),
        },
    ]
