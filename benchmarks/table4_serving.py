"""Serving microbenchmarks: arena residency, batching, coalesced submit.

Three effects the runtime layer is built around, measured on LeNet-5
(nv_small, bare-metal backend):

  * ``arena_residency`` — per-call latency with the preloaded DRAM arena kept
    resident on device (a non-donated buffer the program reads; only the
    input surface transfers per call) vs the old behaviour of re-materialising
    the whole arena host->device on every ``run``.
  * ``batched`` — the explicit executor ``run_batch`` (one vmapped XLA
    program per batch) vs N sequential ``run`` calls — the PR 1 path.
  * ``coalesced_submit`` — a loaded server: INFLIGHT individual
    ``Session.submit`` futures in flight at once, coalesced by the scheduler
    into large padded vmapped batches (client code never formed a batch);
    reports the adaptive micro-batcher's counters (coalesce size, queue
    depth, p50/p99 latency) from ``NetStats``.  Throughput target: >= the
    explicit client-side ``run_batch`` at batch 8 — the scheduler wins by
    forming *bigger* batches than the client's natural grouping, which more
    than pays its queue/future overhead.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session, SchedulerConfig

BATCH = 8          # the client-side batch of the PR 1 explicit path
INFLIGHT = 32      # concurrent submits offered to the scheduler


def _bench(fn, iters: int) -> float:
    """Median per-call latency in us (robust to GC/scheduler blips on the
    small shared CI boxes this runs on)."""
    fn()                                        # warmup/compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def run(fast: bool = False):
    g = graph.lenet5()
    art = CompilerPipeline(g).run()
    # a wide hold window keeps coalescing deterministic on small/contended
    # boxes (the window closes early the moment max_batch requests arrive)
    ses = Session(art, scheduler=SchedulerConfig(max_batch=INFLIGHT,
                                                 max_wait_us=5000.0))
    ex = ses.executor()
    caps = ex.capabilities()
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    X = rng.normal(0, 1, (BATCH,) + g.input_shape).astype(np.float32)
    XL = rng.normal(0, 1, (INFLIGHT,) + g.input_shape).astype(np.float32)
    iters = 10 if fast else 30

    # -- arena residency: steady-state vs per-call re-materialisation --------
    steady_us = _bench(lambda: ex.run(x), iters)
    if caps.resident_arena:
        def rematerialise():
            ex.reset_arena()                    # forces host->device arena copy
            ex.run(x)
        cold_us = _bench(rematerialise, iters)
    else:
        cold_us = steady_us

    # -- batching: one vmapped program vs N sequential calls (PR 1 path) -----
    seq_out = np.stack([ex.run(xi).output_int8 for xi in X])
    batch_exact = bool(np.array_equal(ex.run_batch(X).output_int8, seq_out))
    seq_us = _bench(lambda: [ex.run(xi) for xi in X], max(3, iters // 3))
    batch_us = _bench(lambda: ex.run_batch(X), max(3, iters // 3))

    # -- coalesced submit under load: INFLIGHT futures -> big batches --------
    def submit_all():
        futs = [ses.submit(xi) for xi in XL]
        return [f.result() for f in futs]

    # Warm every power-of-two bucket program (partial coalesces early in a
    # burst dispatch at smaller buckets) and let the adaptive EMA observe
    # concurrency, so the timed loop measures steady-state dispatch only.
    k = 1
    while k <= INFLIGHT:
        ex.run_batch(XL[:k])
        k *= 2
    for _ in range(3):
        submit_all()

    seq_long = np.stack([ex.run(xi).output_int8 for xi in XL])
    submit_exact = bool(np.array_equal(
        np.stack([r.output_int8 for r in submit_all()]), seq_long))
    submit_us = _bench(submit_all, max(3, iters // 3))
    st = ses.stats()

    rows = [
        {
            "name": "table4_serving/arena_residency",
            "us_per_call": steady_us,
            "derived": (f"rematerialise_us={cold_us:.0f} "
                        f"resident_speedup={cold_us/steady_us:.2f}x "
                        f"arena_bytes={ex.size}"),
        },
        {
            "name": f"table4_serving/batched_n{BATCH}",
            "us_per_call": batch_us / BATCH,
            "derived": (f"sequential_us_per_img={seq_us/BATCH:.0f} "
                        f"batch_throughput_speedup={seq_us/batch_us:.2f}x "
                        f"bit_exact_vs_sequential={batch_exact}"),
        },
        {
            "name": f"table4_serving/coalesced_submit_inflight{INFLIGHT}",
            "us_per_call": submit_us / INFLIGHT,
            "derived": (f"vs_explicit_run_batch_n{BATCH}="
                        f"{(batch_us / BATCH) / (submit_us / INFLIGHT):.2f}x "
                        f"coalesce_mean={st.coalesce_mean:.1f} "
                        f"coalesce_max={st.coalesce_max} "
                        f"queue_depth_peak={st.queue_depth_peak} "
                        f"latency_p50_us={st.latency_us(50):.0f} "
                        f"latency_p99_us={st.latency_us(99):.0f} "
                        f"bit_exact_vs_sequential={submit_exact}"),
        },
    ]
    ses.close()
    return rows
