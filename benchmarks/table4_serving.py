"""Serving microbenchmarks: arena residency, batching, fused buckets,
coalesced submit.

Four effects the runtime layer is built around, measured on LeNet-5
(nv_small, bare-metal backend):

  * ``arena_residency`` — per-call latency with the preloaded DRAM arena kept
    resident on device (a non-donated buffer the program reads; only the
    input surface transfers per call) vs the old behaviour of re-materialising
    the whole arena host->device on every ``run``.  Measured interleaved —
    one steady call and one rematerialising call per loop iteration — so
    slow drift on a shared box cancels out of the ratio.
  * ``batched`` — the explicit executor ``run_batch`` (one vmapped XLA
    program per batch) vs N sequential ``run`` calls — the PR 1 path.
  * ``batched_fused`` — the natively batched fused launch (lanes folded onto
    the GEMM N axis, weights streamed once per bucket) vs the vmapped
    single-image program at bucket INFLIGHT, A/B'd with the executor's
    ``native_batch`` lever and checked bit-exact.  The per-bucket cost model
    picks between the two styles per platform; the row reports which style
    the shipped plan selected here.
  * ``coalesced_submit`` — a loaded server: INFLIGHT individual
    ``Session.submit`` futures in flight at once, coalesced by the scheduler
    onto the bucket ladder (client code never formed a batch); reports the
    micro-batcher's counters (coalesce size, queue depth, p50/p99 latency)
    plus the warmup/compile observability counters from ``NetStats``.  The
    session is constructed with ``warmup=True``, so every ladder bucket is
    precompiled before the first timed request — the loop measures
    steady-state dispatch, never compilation.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import graph
from repro.core.pipeline import CompilerPipeline
from repro.runtime import Session, SchedulerConfig, create_executor

BATCH = 8          # the client-side batch of the PR 1 explicit path
INFLIGHT = 32      # concurrent submits offered to the scheduler


def _bench(fn, iters: int) -> float:
    """Median per-call latency in us (robust to GC/scheduler blips on the
    small shared CI boxes this runs on)."""
    fn()                                        # warmup/compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def _bench_ab(fn_a, fn_b, iters: int) -> tuple:
    """Interleaved medians for an A/B pair: each loop iteration times one
    call of each, so machine-load drift hits both sides equally and the
    ratio stays meaningful even when the box speed wanders between loops."""
    fn_a(), fn_b()                              # warmup/compile
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)) * 1e6, float(np.median(tb)) * 1e6


def run(fast: bool = False):
    g = graph.lenet5()
    art = CompilerPipeline(g).run()
    # a wide hold window keeps coalescing deterministic on small/contended
    # boxes (the window closes early the moment max_batch requests arrive);
    # warmup=True precompiles the single-image program and every ladder
    # bucket before anything is measured
    ses = Session(art, scheduler=SchedulerConfig(max_batch=INFLIGHT,
                                                 max_wait_us=5000.0),
                  warmup=True)
    ex = ses.executor()
    caps = ex.capabilities()
    warm = ses.stats().snapshot()
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    X = rng.normal(0, 1, (BATCH,) + g.input_shape).astype(np.float32)
    XL = rng.normal(0, 1, (INFLIGHT,) + g.input_shape).astype(np.float32)
    iters = 10 if fast else 30

    # -- arena residency: steady-state vs per-call re-materialisation --------
    if caps.resident_arena:
        def rematerialise():
            ex.reset_arena()                    # forces host->device arena copy
            ex.run(x)
        steady_us, cold_us = _bench_ab(lambda: ex.run(x), rematerialise, iters)
    else:
        steady_us = _bench(lambda: ex.run(x), iters)
        cold_us = steady_us

    # -- batching: one vmapped program vs N sequential calls (PR 1 path) -----
    seq_out = np.stack([ex.run(xi).output_int8 for xi in X])
    batch_exact = bool(np.array_equal(ex.run_batch(X).output_int8, seq_out))
    seq_us = _bench(lambda: [ex.run(xi) for xi in X], max(3, iters // 3))
    batch_us = _bench(lambda: ex.run_batch(X), max(3, iters // 3))

    # -- fused bucket: native batch kernels vs the vmapped oracle ------------
    # Both executors share the artifacts; ``native_batch`` pins the style so
    # the A/B isolates the fold itself from the cost model's platform choice.
    ex_fused = create_executor("baremetal", art, native_batch="force")
    ex_vmap = create_executor("baremetal", art, native_batch=False)
    fused_exact = bool(np.array_equal(ex_fused.run_batch(XL).output_int8,
                                      ex_vmap.run_batch(XL).output_int8))
    # full iteration count: this row's committed value is an A/B ratio, and
    # a 3-iter median is too noisy to gate a ~unity ratio meaningfully
    fused_us, vmap_us = _bench_ab(lambda: ex_fused.run_batch(XL),
                                  lambda: ex_vmap.run_batch(XL), iters)
    plan32 = ex.batched_kernel_plan(INFLIGHT)
    plan_native = sum(1 for c in plan32 if c.batched)
    plan_gemm = sum(1 for d, c in zip(ex.descs, plan32)
                    if d.unit in ("CONV", "FC"))

    # -- coalesced submit under load: INFLIGHT futures -> ladder buckets -----
    def submit_all():
        futs = [ses.submit(xi) for xi in XL]
        return [f.result() for f in futs]

    # warmup already precompiled every ladder bucket; two settle passes let
    # the dispatcher observe the burst concurrency before the timed loop
    for _ in range(2):
        submit_all()

    seq_long = np.stack([ex.run(xi).output_int8 for xi in XL])
    submit_exact = bool(np.array_equal(
        np.stack([r.output_int8 for r in submit_all()]), seq_long))
    submit_us = _bench(submit_all, max(3, iters // 3))
    st = ses.stats()
    snap = st.snapshot()
    # compiles after warmup mean a request paid a compile stall mid-loop —
    # the invariant the warmup tentpole exists to enforce
    stalls = snap["compile_count"] - warm["compile_count"]
    buckets = ",".join(f"{b}:{c}" for b, c in
                       sorted(snap["bucket_launches"].items()))

    rows = [
        {
            "name": "table4_serving/arena_residency",
            "us_per_call": steady_us,
            "derived": (f"rematerialise_us={cold_us:.0f} "
                        f"resident_speedup={cold_us/steady_us:.2f}x "
                        f"arena_bytes={ex.size} "
                        f"cause=remat_pays_arena_h2d_copy_per_call "
                        f"(interleaved medians; an earlier 0.91x baseline "
                        f"was cross-loop drift on a shared box)"),
        },
        {
            "name": f"table4_serving/batched_n{BATCH}",
            "us_per_call": batch_us / BATCH,
            "derived": (f"sequential_us_per_img={seq_us/BATCH:.0f} "
                        f"batch_throughput_speedup={seq_us/batch_us:.2f}x "
                        f"bit_exact_vs_sequential={batch_exact}"),
        },
        {
            "name": f"table4_serving/batched_fused_bucket{INFLIGHT}",
            "us_per_call": fused_us / INFLIGHT,
            "derived": (f"vmapped_us_per_img={vmap_us/INFLIGHT:.0f} "
                        f"native_vs_vmapped={vmap_us/fused_us:.2f}x "
                        f"bit_exact_vs_vmapped={fused_exact} "
                        f"plan_native_ops={plan_native}/{plan_gemm} "
                        f"(cost model: on vmap_folds substrates XLA's "
                        f"batching rule already folds the broadcast-weight "
                        f"GEMMs, so the styles tie on CPU and the fold's "
                        f"amortisation pays off on the Pallas TPU path)"),
        },
        {
            "name": f"table4_serving/coalesced_submit_inflight{INFLIGHT}",
            "us_per_call": submit_us / INFLIGHT,
            "derived": (f"vs_explicit_run_batch_n{BATCH}="
                        f"{(batch_us / BATCH) / (submit_us / INFLIGHT):.2f}x "
                        f"coalesce_mean={st.coalesce_mean:.1f} "
                        f"coalesce_max={st.coalesce_max} "
                        f"queue_depth_peak={st.queue_depth_peak} "
                        f"latency_p50_us={st.latency_us(50):.0f} "
                        f"latency_p99_us={st.latency_us(99):.0f} "
                        f"warmup_ms={snap['warmup_ms']:.0f} "
                        f"compile_count={snap['compile_count']} "
                        f"compile_stalls_after_warmup={stalls} "
                        f"bucket_launches={buckets} "
                        f"bit_exact_vs_sequential={submit_exact}"),
        },
    ]
    ses.close()
    return rows
