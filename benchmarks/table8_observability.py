"""Observability overhead + perf-model fidelity (the repro.obs plane).

Four questions the tracing/profiling tentpole must answer with numbers:

  * ``tracing_off`` / ``tracing_sampled`` / ``tracing_full`` — what does
    request tracing cost?  Per-call latency through a ``Session`` with the
    tracer disabled, sampling every ``SAMPLE_EVERY``-th request (the
    production setting), and tracing everything.  Measured interleaved
    (one traced call and one untraced call per loop iteration) so machine
    drift cancels out of the overhead ratio.  The sampled row publishes
    ``tracing_overhead_pct`` with an ABSOLUTE ``overhead_budget_pct``
    gate in ``check_regression.py``: sampled tracing past a few percent
    is a bug, on any machine.
  * ``profiled_run`` — what does the per-layer profiled path cost?  The
    stepwise individually-timed kernels vs the fused program (bit-exact
    by construction; the slowdown is the price of per-op timing, which is
    why profiling is opt-in and rides the sampler).
  * ``fidelity_*`` — does calibration work?  Per-layer measured timings
    feed ``perfmodel.calibrate``; the row reports the mean |log error| of
    the uncalibrated cost model (best global scale already divided out)
    against the calibrated fit.  ``err_cal < err_uncal`` is the
    ROADMAP's perf-model fidelity item becoming measurable.

``run(trace_out=...)`` additionally dumps the fully-traced session's ring
buffer as Chrome trace-event JSON — CI uploads it as a workflow artifact,
so every CI run ships an openable timeline of its own benchmark traffic.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import graph, perfmodel
from repro.core.pipeline import CompilerPipeline
from repro.obs import TraceConfig, fidelity_report, profile_layers
from repro.runtime import Session, create_executor

SAMPLE_EVERY = 16          # the production sampling rate the gate protects


def _bench_ab(fn_a, fn_b, iters: int) -> tuple:
    """Interleaved medians: each loop iteration times one call of each arm,
    so machine-load drift hits both sides equally and the overhead ratio
    stays meaningful on small shared CI boxes."""
    fn_a(), fn_b()                              # warmup/compile
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)) * 1e6, float(np.median(tb)) * 1e6


def _fidelity_row(name: str, art, fast: bool) -> dict:
    ex = create_executor("baremetal", art)
    samples = profile_layers(ex, iters=2 if fast else 5, warmup=1)
    cal = perfmodel.calibrate(samples, ex.descs, dtype=ex.cfg.dtype)
    rep = fidelity_report(ex, samples, cal)
    improved = rep["err_cal"] <= rep["err_uncal"] + 1e-9
    return {
        "name": f"table8_obs/fidelity_{name}",
        # summed per-layer medians: a stable proxy for one profiled pass
        "us_per_call": float(sum(s["us"] for s in samples)),
        # per-op profiled timings on shared boxes are noisy; this row's
        # committed value exists for the derived fidelity fields, so it
        # gets a wide relative budget like the table-5 load rows
        "tolerance": 2.5,
        "derived": (f"err_uncal={rep['err_uncal']:.3f} "
                    f"err_cal={rep['err_cal']:.3f} "
                    f"calibration_improves={improved} "
                    f"gemm_layers={rep['gemm_layers']} "
                    f"families={len(cal.families)} "
                    f"(mean |log measured/modeled| over CONV/FC layers; "
                    f"the uncalibrated model is charged AFTER its best "
                    f"global scale is divided out, so the fit must win on "
                    f"shape, not units)"),
    }


def run(fast: bool = False, trace_out=None):
    g = graph.lenet5()
    art = CompilerPipeline(g).run()
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    # the overhead A/B gates a few-percent effect: give it enough iters
    # that the interleaved medians resolve it even in smoke mode
    ab_iters = 100 if fast else 250

    ses_off = Session(art, trace=TraceConfig(enabled=False), warmup=True)
    ses_s = Session(art, trace=TraceConfig(sample_rate=SAMPLE_EVERY),
                    warmup=True)
    ses_full = Session(art, trace=TraceConfig(sample_rate=1), warmup=True)
    try:
        off_us, sampled_us = _bench_ab(lambda: ses_off.run(x),
                                       lambda: ses_s.run(x), ab_iters)
        off2_us, full_us = _bench_ab(lambda: ses_off.run(x),
                                     lambda: ses_full.run(x), ab_iters)
        sampled_pct = (sampled_us / off_us - 1.0) * 100.0
        full_pct = (full_us / off2_us - 1.0) * 100.0

        # profiled path: stepwise per-op timing vs the fused program
        ex = ses_full.executor()
        prof_exact = bool(np.array_equal(
            np.asarray(ex.run_profiled(x)[0].output_int8),
            np.asarray(ex.run(x).output_int8)))
        run_us, prof_us = _bench_ab(lambda: ex.run(x),
                                    lambda: ex.run_profiled(x),
                                    max(10, ab_iters // 5))
        n_traces = len(ses_full.tracer.traces())
        if trace_out is not None:
            ses_full.tracer.to_file(trace_out)
    finally:
        ses_off.close()
        ses_s.close()
        ses_full.close()

    rows = [
        {
            "name": "table8_obs/tracing_off",
            "us_per_call": off_us,
            "derived": f"tracer_disabled iters={ab_iters} (overhead A/B "
                       f"baseline; ids still assigned, nothing recorded)",
        },
        {
            "name": f"table8_obs/tracing_sampled{SAMPLE_EVERY}",
            "us_per_call": sampled_us,
            "tracing_overhead_pct": sampled_pct,
            "overhead_budget_pct": 5.0,
            "derived": (f"overhead_vs_off={sampled_pct:+.2f}% "
                        f"budget=5% sample_rate={SAMPLE_EVERY} "
                        f"(absolute gate in check_regression.py: sampled "
                        f"tracing past the budget fails CI on any machine)"),
        },
        {
            "name": "table8_obs/tracing_full",
            "us_per_call": full_us,
            "derived": (f"overhead_vs_off={full_pct:+.2f}% sample_rate=1 "
                        f"traces_recorded={n_traces} (informational: the "
                        f"every-request ceiling, not the production mode)"),
        },
        {
            "name": "table8_obs/profiled_run",
            "us_per_call": prof_us,
            "tolerance": 2.5,
            "derived": (f"fused_us={run_us:.0f} "
                        f"profiled_slowdown={prof_us/run_us:.2f}x "
                        f"bit_exact_vs_fused={prof_exact} "
                        f"layers_timed={len(ex.descs)} (the cost of timing "
                        f"each descriptor's kernel individually — why "
                        f"profiling is opt-in and rides the sampler)"),
        },
        _fidelity_row("lenet5", art, fast),
        _fidelity_row("resnet18", CompilerPipeline(graph.resnet18()).run(),
                      fast),
    ]
    return rows
