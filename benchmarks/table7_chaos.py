"""Chaos soak: the serving front-end under injected fault storms (table 7).

Replays the table-5 open-loop Poisson trace through the in-process
``ServeClient`` three times, against the fault-tolerance subsystem:

  * **storm** — the same two-net trace, with every primary executor wrapped
    in a seeded ~1% ``FaultPlan`` (crashes, slow calls, poisoned arenas —
    every *recoverable* kind).  Reported: p99 latency, goodput, and
    **goodput retained** versus a fault-free replay of the identical trace.
    Every admitted future must resolve (``hang_count == 0``) and every
    completed response must stay bit-exact versus ``Session.run`` — the
    supervisor's retries and arena restores must never leak wrong bytes.
  * **watchdog** — scripted indefinite hangs against a tight per-launch
    watchdog: the hung launches are abandoned and retried, so every future
    still resolves (the paper's bare-metal framing: a wedged accelerator
    must never wedge the host).
  * **recovery** — a scripted primary outage trips the circuit breaker
    (closed -> open); the ``ref`` fallback absorbs traffic as ``degraded``
    responses while half-open probes re-test the primary, and
    **recovery_ms** measures outage start -> breaker closed on the healed
    primary.  ``check_regression`` gates recovery_ms growth and
    ``hang_count != 0`` absolutely.

Self-gating: the run itself raises (CI-fatal) on any unresolved future, a
non-degraded bit-exactness miss, goodput retained < 0.8, or a breaker that
never re-closes.
"""

from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.loadgen import drive, goodput, percentile
from benchmarks.table5_serving_frontend import (_make_schedule, _SHAPES,
                                                _fast_net, _slow_net, POOL)
from repro.core.pipeline import CompilerPipeline
from repro.runtime import (FaultPlan, FaultSpec, FaultyExecutor, Session,
                           SchedulerConfig)
from repro.serve.client import ServeClient, ServeError

OVERLOAD = 2.0                  # offered load vs capacity: queueing without
                                # drowning the fault signal in backlog
STORM_SEED = 13
# ~1% of calls fault, split across every recoverable kind (hangs are storm-
# excluded: they are scripted in the watchdog phase against a tight timeout;
# corrupt_output is excluded by design — it is the one *silent* kind, and
# this phase asserts bit-exactness of everything that completes)
STORM_SPECS = (FaultSpec("error", probability=0.004),
               FaultSpec("slow", probability=0.003, delay_s=0.002),
               FaultSpec("corrupt_arena", probability=0.003))
RETAINED_FLOOR = 0.8            # acceptance: goodput retained under the storm


def _sum_stats(ses, key):
    return sum(ses.stats(n).snapshot()[key] for n in ses.networks)


def _capacity_interarrival_us(ses, inputs) -> float:
    per_img_us = {}
    for name in ses.networks:
        X = np.stack(inputs[name])
        t0 = time.perf_counter()
        for _ in range(3):
            ses.run_batch(X, net=name)
        per_img_us[name] = (time.perf_counter() - t0) / (3 * POOL) * 1e6
    return float(np.mean(list(per_img_us.values()))) / OVERLOAD


def _warm_buckets(ses, inputs, max_batch):
    for name in ses.networks:
        k = 1
        while k <= max_batch:
            ses.run_batch(np.stack((inputs[name] * 2)[:k]), net=name)
            k *= 2


def _replay(ses, schedule, inputs, refs):
    """One SLA-honoring trace replay -> (records, wall_s)."""
    records, wall, _ = drive(ServeClient(ses), schedule, inputs, refs,
                             honor_sla=True)
    return records, wall


def _storm_phases(arts, inputs, refs, schedule, reps):
    """Fault-free and ~1%-storm replays of the same trace."""
    clean_gp, clean_p99 = [], []
    storm_gp, storm_p99 = [], []
    hang_count, inexact, all_faults = 0, 0, 0
    retr = fails = resets = 0
    for _ in range(reps):
        cfg = SchedulerConfig(max_batch=8, max_wait_us=1000.0, max_queue=4096)
        ses = Session(scheduler=cfg)
        for art in arts.values():
            ses.load(art)
        _warm_buckets(ses, inputs, cfg.max_batch)
        recs, wall = _replay(ses, schedule, inputs, refs)
        clean_gp.append(goodput(recs, wall))
        clean_p99.append(percentile([r.latency_us for r in recs if r.ok], 99))
        hang_count += sum(1 for r in recs if r.t_done == 0.0)
        inexact += sum(1 for r in recs if r.ok and not r.exact)
        ses.close()

        # identical trace, primaries wrapped in the seeded ~1% fault plan;
        # the supervisor (watchdog + retries + arena checksum) absorbs it
        storm_cfg = SchedulerConfig(max_batch=8, max_wait_us=1000.0,
                                    max_queue=4096, max_retries=3,
                                    retry_backoff_s=5e-4,
                                    breaker_threshold=None)
        ses = Session(scheduler=storm_cfg)
        plan = FaultPlan(specs=STORM_SPECS, seed=STORM_SEED)
        for art in arts.values():
            ses.load(art, fault_plan=plan)
        _warm_buckets(ses, inputs, storm_cfg.max_batch)
        recs, wall = _replay(ses, schedule, inputs, refs)
        storm_gp.append(goodput(recs, wall))
        storm_p99.append(percentile([r.latency_us for r in recs if r.ok], 99))
        hang_count += sum(1 for r in recs if r.t_done == 0.0)
        inexact += sum(1 for r in recs if r.ok and not r.exact)
        all_faults += _sum_stats(ses, "faults_injected")
        retr += _sum_stats(ses, "retries")
        fails += _sum_stats(ses, "backend_failures")
        resets += _sum_stats(ses, "arena_resets")
        ses.close()
    return {"clean_gp": float(np.median(clean_gp)),
            "storm_gp": float(np.median(storm_gp)),
            "clean_p99": float(np.median(clean_p99)),
            "storm_p99": float(np.median(storm_p99)),
            "hang_count": hang_count, "inexact": inexact,
            "faults": all_faults, "retries": retr,
            "backend_failures": fails, "arena_resets": resets}


def _watchdog_phase(art, n_requests):
    """Scripted indefinite hangs vs a tight watchdog: all futures resolve."""
    cfg = SchedulerConfig(max_batch=4, max_wait_us=200.0,
                          watchdog_timeout_s=2.0, max_retries=2,
                          retry_backoff_s=1e-3, breaker_threshold=None,
                          close_timeout_s=10.0)
    ses = Session(art, scheduler=cfg)
    net = ses._resolve(None)
    # warm OUTSIDE the watchdog (a cold compile would trip a 2s budget),
    # then wrap: the first and fourth post-warm launches wedge forever
    net.executor.run(np.zeros(_SHAPES["fastnet"], np.float32))
    net.executor.run_batch(
        np.zeros((4,) + _SHAPES["fastnet"], np.float32), lanes=4)
    faulty = FaultyExecutor(net.executor, FaultPlan(specs=(
        FaultSpec("hang", schedule=(0, 3), max_faults=2),)))
    net.executor = faulty
    rng = np.random.default_rng(3)
    t0 = time.perf_counter()
    futs = [ses.submit(rng.normal(0, 1, _SHAPES["fastnet"])
                       .astype(np.float32)) for _ in range(n_requests)]
    lats = []
    for f in futs:
        try:
            f.result(timeout=60.0)
            lats.append((time.perf_counter() - t0) * 1e6)
        except Exception:                       # typed failure still resolves
            pass
    unresolved = sum(1 for f in futs if not f.done())
    timeouts = ses.stats().snapshot()["watchdog_timeouts"]
    faulty.release_hangs()
    ses.close()
    return {"p99_us": percentile(lats, 99), "hang_count": unresolved,
            "watchdog_timeouts": timeouts, "resolved": len(futs) - unresolved,
            "n": len(futs)}


def _recovery_phase(art, refs0):
    """Scripted primary outage -> breaker opens -> ref fallback absorbs
    traffic (degraded) -> half-open probes re-close on the healed primary."""
    cfg = SchedulerConfig(max_batch=4, max_wait_us=0.0, max_retries=0,
                          retry_backoff_s=1e-3, breaker_threshold=3,
                          breaker_reset_s=0.25, close_timeout_s=10.0)
    ses = Session(scheduler=cfg)
    # outage: the next 5 primary launches crash (3 trip the breaker open,
    # 2 fail half-open probes), then the primary heals
    plan = FaultPlan(specs=(FaultSpec("error", probability=1.0,
                                      max_faults=5),))
    ses.load(art, fallback_backend="ref", fault_plan=plan)
    net = ses._resolve(None)
    net.fallback.run(np.zeros(_SHAPES["fastnet"], np.float32))  # pre-warm
    client = ServeClient(ses, timeout_s=30.0)
    x = refs0["input"]
    t_outage = time.perf_counter()
    failed = degraded = served = 0
    exact = True
    recovery_ms = None
    deadline = t_outage + 30.0
    while time.perf_counter() < deadline:
        try:
            res = client.infer(None, x)
        except ServeError:
            failed += 1
            continue
        served += 1
        if getattr(res, "degraded", False):
            degraded += 1
        exact &= bool(np.array_equal(np.asarray(res.output_int8),
                                     refs0["ref"]))
        if not getattr(res, "degraded", False) \
                and ses.health()["fastnet"]["state"] == "healthy":
            recovery_ms = (time.perf_counter() - t_outage) * 1e3
            break
        time.sleep(0.002)                       # steady feed, not a busy spin
    opens = ses.stats().snapshot()["circuit_opens"]
    ses.close()
    if recovery_ms is None:
        raise RuntimeError("circuit never re-closed within 30s: the breaker "
                           "half-open probe path is broken")
    return {"recovery_ms": recovery_ms, "failed": failed,
            "degraded": degraded, "served": served, "exact": exact,
            "circuit_opens": opens}


def run(fast: bool = False):
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)
    try:
        return _run(fast)
    finally:
        sys.setswitchinterval(old_switch)


def _run(fast: bool):
    n_total = 320 if fast else 960
    reps = 1 if fast else 3
    arts = {"fastnet": CompilerPipeline(_fast_net()).run(),
            "slownet": CompilerPipeline(_slow_net()).run()}
    rng = np.random.default_rng(0)
    inputs = {name: [rng.normal(0, 1, _SHAPES[name]).astype(np.float32)
                     for _ in range(POOL)] for name in arts}

    # fault-free oracle + capacity estimate on a throwaway clean session
    with Session() as ses:
        for art in arts.values():
            ses.load(art)
        refs = {name: [np.asarray(ses.run(x, net=name).output_int8)
                       for x in xs] for name, xs in inputs.items()}
        mean_interarrival_us = _capacity_interarrival_us(ses, inputs)
    schedule = _make_schedule(11, n_total, mean_interarrival_us)

    storm = _storm_phases(arts, inputs, refs, schedule, reps)
    watchdog = _watchdog_phase(arts["fastnet"], n_requests=12 if fast else 24)
    recovery = _recovery_phase(
        arts["fastnet"],
        {"input": inputs["fastnet"][0], "ref": refs["fastnet"][0]})

    retained = (storm["storm_gp"] / storm["clean_gp"]
                if storm["clean_gp"] else 0.0)
    hang_total = storm["hang_count"] + watchdog["hang_count"]
    # hard acceptance gates — a chaos soak that hangs, leaks wrong bytes, or
    # loses most of its goodput must fail the run, not just dent a number
    if hang_total:
        raise RuntimeError(f"{hang_total} future(s) never resolved under "
                           f"chaos — the supervisor leaked a hang")
    if storm["inexact"]:
        raise RuntimeError(f"{storm['inexact']} non-degraded response(s) "
                           f"were not bit-exact under the fault storm")
    if retained < RETAINED_FLOOR:
        raise RuntimeError(f"goodput retained {retained:.2f} under the ~1% "
                           f"storm (floor {RETAINED_FLOOR}) — recovery is "
                           f"eating the serving capacity")
    if not recovery["exact"]:
        raise RuntimeError("a degraded (fallback) response was not bit-exact "
                           "versus the ref oracle")

    # chaos rows inherit the table-5 load-test noise budget: queueing delay
    # amplifies ambient machine noise superlinearly
    tol = 2.5
    rows = [
        {
            "name": "table7_chaos/storm",
            "us_per_call": storm["storm_p99"],
            "goodput": storm["storm_gp"],
            "hang_count": storm["hang_count"],
            "tolerance": tol,
            "derived": (f"retained={retained:.2f} "
                        f"clean_goodput_rps={storm['clean_gp']:.0f} "
                        f"clean_p99_us={storm['clean_p99']:.0f} "
                        f"faults_injected={storm['faults']} "
                        f"retries={storm['retries']} "
                        f"arena_resets={storm['arena_resets']} "
                        f"bit_exact=True hang_count=0"),
        },
        {
            "name": "table7_chaos/watchdog",
            "us_per_call": watchdog["p99_us"],
            "hang_count": watchdog["hang_count"],
            "tolerance": tol,
            "derived": (f"watchdog_timeouts={watchdog['watchdog_timeouts']} "
                        f"resolved={watchdog['resolved']}/{watchdog['n']} "
                        f"hang_count=0"),
        },
        {
            "name": "table7_chaos/recovery",
            "us_per_call": recovery["recovery_ms"] * 1e3,
            "recovery_ms": recovery["recovery_ms"],
            "hang_count": 0,
            "tolerance": tol,
            "derived": (f"recovery_ms={recovery['recovery_ms']:.0f} "
                        f"degraded_served={recovery['degraded']} "
                        f"failed={recovery['failed']} "
                        f"circuit_opens={recovery['circuit_opens']} "
                        f"fallback=ref bit_exact={recovery['exact']}"),
        },
    ]
    return rows
